"""Roofline analysis from dry-run artifacts (assignment §ROOFLINE).

Per (arch × shape) on the single-pod 16x16 mesh:
  compute term    = HLO_FLOPs_per_dev / peak_FLOP/s        (197 TF bf16, v5e)
  memory term     = HLO_bytes_per_dev / HBM_bw             (819 GB/s)
  collective term = collective_bytes_per_dev / link_bw     (~50 GB/s/link ICI)
plus the dominant bottleneck, MODEL_FLOPS (6·N·D train / 2·N·D inference,
N_active for MoE), and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs ×
chips) — which catches remat/redundancy waste.

HLO_FLOPs are loop-corrected (XLA cost_analysis counts while bodies once;
see launch/hlo_analysis.py) and are a matmul floor — elementwise FLOPs are
excluded, so treat ratios >1 as exact-matmul accounting.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def load_records(mesh: str = "16x16") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("status") == "ok":
            out.append(r)
    return out


def terms(rec: dict) -> dict:
    flops = rec["flops_per_device"]
    byts = rec["bytes_accessed_per_device"]
    coll = rec["collective_bytes_per_device"].get("total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    n = rec["n_chips"]
    model_flops = None
    useful = None
    if "model_params" in rec:
        kind = rec.get("kind", "train")
        tokens = rec["global_batch"] * (rec["seq_len"]
                                        if kind in ("train", "prefill") else 1)
        n_active = rec.get("active_params") or rec["model_params"]
        model_flops = (6.0 if kind == "train" else 2.0) * n_active * tokens
        useful = model_flops / max(flops * n, 1.0)
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0], "bound_s": bound,
        "model_flops": model_flops, "useful_ratio": useful,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }


def table(mesh: str = "16x16") -> List[dict]:
    rows = []
    for rec in load_records(mesh):
        t = terms(rec)
        rows.append({"arch": rec["arch"], "shape": rec["shape"], **t,
                     "fits": rec["memory"]["fits_16gb_v5e"],
                     "resident_gib":
                         rec["memory"]["resident_bytes_per_chip"] / 2**30})
    return rows


def run():
    rows = table()
    if not rows:
        print("roofline_no_artifacts,0,run_python_-m_repro.launch.dryrun_--sweep")
        return rows
    print("name,us_per_call,derived")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "n/a"
        print(f"roofline_{r['arch']}_{r['shape']},"
              f"{r['bound_s']*1e6:.0f},"
              f"dom={r['dominant']};comp_s={r['compute_s']:.4f};"
              f"mem_s={r['memory_s']:.4f};coll_s={r['collective_s']:.4f};"
              f"useful={ur};fits={r['fits']};"
              f"frac={r['roofline_fraction']:.2f}")
    return rows


if __name__ == "__main__":
    run()
