"""Table 2 analogue: distributed TPC-H with compute/exchange/other breakdown.

Runs Q1/Q3/Q6 (the paper's distributed subset) + Q12 (ours) on an N-shard
mesh in a subprocess (forced host devices), reporting the same three-way time
decomposition as the paper — and reproducing its headline observation that
exchange dominates Q3 while Q1/Q6 are coordinator/'other'-bound at small
scale.  Queries go through the generic ``run_plan`` path (exchange placement
+ fragment cutting), not hand-built programs.

With ``json_path`` the per-query totals are merged into the BENCH json as a
``"distributed"`` section, which ``scripts/profile_diff.py`` gates alongside
the single-node profiles.  Each query entry also embeds its per-exchange
``{bytes_per_shard, skew_ratio}`` rows (``eng.exchange_summary()``) and the
journal's per-query event summary, so skew regressions show up in BENCH
diffs without re-running the mesh.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={shards}"
import json, sys
sys.path.insert(0, {src!r})
from repro.core.distributed import DistributedEngine
from repro.data.tpch import generate

from repro.observability.journal import JOURNAL

db = generate({sf})
eng = DistributedEngine(db, n_shards={shards})
out = []
for qid in (1, 3, 6, 12):
    eng.run_query(qid)              # warm (compile)
    eng.run_query(qid)
    t = dict(eng.timers)
    out.append({{"qid": qid, "compute": t.get("compute", 0.0),
                "exchange": t.get("exchange", 0.0),
                "other": t.get("other", 0.0), "total": t.get("total", 0.0),
                "compile": t.get("compile", 0.0),
                "exchanges": eng.exchange_summary(),
                "journal": JOURNAL.summary(eng.last_query_id)}})
print("RESULT " + json.dumps(out))
"""


def run(scale_factor: float = 0.01, n_shards: int = 8,
        json_path: str | None = None):
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = _WORKER.format(src=src, sf=scale_factor, shards=n_shards)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=1800)
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    if not line:
        print(f"bench_distributed_failed,0,{proc.stderr[-400:]!r}")
        return []
    rows = json.loads(line[0][len("RESULT "):])
    print("name,us_per_call,derived")
    for r in rows:
        print(f"dist_q{r['qid']},{r['total']*1e6:.0f},"
              f"compute_ms={r['compute']*1e3:.1f};"
              f"exchange_ms={r['exchange']*1e3:.1f};"
              f"other_ms={r['other']*1e3:.1f}")
    q3 = next(r for r in rows if r["qid"] == 3)
    print(f"dist_summary,0,q3_exchange_dominates="
          f"{q3['exchange'] > q3['compute']}")
    if json_path:
        data = {}
        if os.path.exists(json_path):
            with open(json_path) as f:
                data = json.load(f)
        data["distributed"] = {
            "shards": n_shards,
            "scale_factor": scale_factor,
            "queries": {f"q{r['qid']}": {
                k: r[k] for k in ("total", "compute", "exchange", "other",
                                  "compile", "exchanges", "journal")
                if k in r}
                for r in rows},
        }
        with open(json_path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path} (distributed section)")
    return rows


if __name__ == "__main__":
    run()
