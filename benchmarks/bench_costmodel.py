"""Table 1 / §4.2 cost-efficiency analogue: projected accelerator speedup at
equal rental cost.

The paper's headline: 7× over DuckDB at the same $/hour (GH200 vs
m7i.16xlarge).  No accelerator exists in this container, so this benchmark
PROJECTS (clearly labeled): it takes the *measured* host-baseline TPC-H times
and the dry-run roofline times of the SQL fragments (per-chip bytes/flops vs
v5e bandwidths from artifacts), normalizes by rental cost, and reports the
projected ratio.  Methodology and constants are in EXPERIMENTS.md.

Rental constants: v5e on-demand ≈ $1.2/chip-hour; c6a.metal-class CPU at
$7.344/h (paper Table 1).  A 6-chip v5e slice ≈ the CPU node's cost.
"""
from __future__ import annotations

import json
import os
import time

CPU_COST_PER_H = 7.344
V5E_CHIP_COST_PER_H = 1.2
CHIPS_AT_EQUAL_COST = max(int(CPU_COST_PER_H / V5E_CHIP_COST_PER_H), 1)

# v5e per chip
PEAK = 197e12
HBM = 819e9
# c6a.metal-class CPU node (paper Table 1): ~400 GB/s memory bw
CPU_MEM_BW = 400e9

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def run(scale_factor: float = 0.02):
    from repro.core.fallback import FallbackEngine
    from repro.data.tpch import generate
    from repro.data.tpch_queries import QUERIES

    db = generate(scale_factor)
    fb = FallbackEngine(db)
    lineitem_rows = len(db["lineitem"]["l_orderkey"])

    # measured host baseline (per-row-normalized so we can scale to SF100)
    host_times = {}
    for qid in (1, 3, 6):
        fb.execute(QUERIES[qid]())
        t0 = time.perf_counter()
        fb.execute(QUERIES[qid]())
        host_times[qid] = time.perf_counter() - t0

    sf100_rows = 600_037_902
    scale = sf100_rows / lineitem_rows

    # analytic CPU floor: a perfectly memory-bound CPU engine at 400 GB/s
    bytes_per_row = {1: 30, 3: 44, 6: 28}   # touched cols (encoded widths)
    print("name,us_per_call,derived")
    results = {}
    for qid in (1, 3, 6):
        cpu_measured_sf100 = host_times[qid] * scale
        cpu_floor_sf100 = sf100_rows * bytes_per_row[qid] / CPU_MEM_BW
        # accelerator projection from the dry-run fragment artifact
        art = os.path.join(ARTIFACT_DIR,
                           f"sirius-tpch__q{qid}_sf100__16x16.json")
        if os.path.exists(art):
            with open(art) as f:
                rec = json.load(f)
            per_chip = max(rec["bytes_accessed_per_device"] / HBM,
                           rec["flops_per_device"] / PEAK,
                           rec["collective_bytes_per_device"]["total"] / 50e9)
            # equal-cost slice = 6 chips → scale per-chip time by 256/6
            tpu_equal_cost = per_chip * (rec["n_chips"] / CHIPS_AT_EQUAL_COST)
            results[qid] = (cpu_measured_sf100, cpu_floor_sf100,
                            tpu_equal_cost)
            print(f"costmodel_q{qid},{tpu_equal_cost*1e6:.0f},"
                  f"PROJECTED_equalcost_speedup_vs_cpu_floor="
                  f"{cpu_floor_sf100/tpu_equal_cost:.1f}x;"
                  f"vs_measured_numpy_scaled="
                  f"{cpu_measured_sf100/tpu_equal_cost:.1f}x")
        else:
            print(f"costmodel_q{qid},0,no_dryrun_artifact")
    return results


if __name__ == "__main__":
    run()
