"""Optimizer plan-quality benchmark: rows flowing through each operator.

For every SQL-text TPC-H query, execute the *naive* lowered plan and the
*optimized* plan on the numpy host engine with per-operator row counting,
and report the reduction in total rows materialized between operators — the
plan-quality metric the paper's host-optimizer (DuckDB) contributes before
Sirius ever sees the plan.  Also prints the optimizer's estimated vs actual
cardinalities for the root operator (EXPLAIN-level observability).

Run:  PYTHONPATH=src python benchmarks/bench_optimizer.py [scale_factor]
"""
from __future__ import annotations

import sys
import time
from collections import defaultdict
from typing import Dict, Tuple

from repro.core.fallback import FallbackEngine, _num_rows
from repro.core.plan import Rel


class RowCountingEngine(FallbackEngine):
    """FallbackEngine that records output rows per plan-operator type."""

    def __init__(self, tables):
        super().__init__(tables)
        self.per_op: Dict[str, int] = defaultdict(int)
        self.total_rows = 0
        self.op_count = 0

    def execute(self, plan: Rel):
        out = super().execute(plan)
        n = _num_rows(out)
        self.per_op[type(plan).__name__] += n
        self.total_rows += n
        self.op_count += 1
        return out


def _run_counted(db, plan: Rel):
    eng = RowCountingEngine(db)
    t0 = time.perf_counter()
    eng.execute(plan)
    dt = time.perf_counter() - t0
    return eng, dt


def run(scale_factor: float = 0.02):
    from repro.data.tpch import generate
    from repro.data.tpch_queries import SQL_QUERIES
    from repro.sql import sql_to_plan

    db = generate(scale_factor)
    print(f"TPC-H SF{scale_factor} — rows flowing through plan operators, "
          "optimizer rules off vs on\n")
    header = (f"{'query':>6} {'naive rows':>14} {'opt rows':>14} "
              f"{'reduction':>10} {'naive s':>9} {'opt s':>9}")
    print(header)
    print("-" * len(header))

    tot_naive = tot_opt = 0
    engines: Dict[int, Tuple[RowCountingEngine, RowCountingEngine]] = {}
    for qid in sorted(SQL_QUERIES):
        naive_plan = sql_to_plan(SQL_QUERIES[qid], optimize=False)
        opt_plan = sql_to_plan(SQL_QUERIES[qid], optimize=True)
        naive, t_n = _run_counted(db, naive_plan)
        opt, t_o = _run_counted(db, opt_plan)
        red = (1 - opt.total_rows / naive.total_rows) if naive.total_rows \
            else 0.0
        tot_naive += naive.total_rows
        tot_opt += opt.total_rows
        engines[qid] = (naive, opt)
        print(f"Q{qid:>5} {naive.total_rows:>14,} {opt.total_rows:>14,} "
              f"{red:>9.1%} {t_n:>9.3f} {t_o:>9.3f}")

    print("-" * len(header))
    total_red = (1 - tot_opt / tot_naive) if tot_naive else 0.0
    print(f"{'total':>6} {tot_naive:>14,} {tot_opt:>14,} {total_red:>9.1%}")

    # per-operator breakdown for the heaviest query
    qid = max(engines, key=lambda q: engines[q][0].total_rows)
    naive, opt = engines[qid]
    print(f"\nper-operator rows for Q{qid} (heaviest naive plan):")
    ops = sorted(set(naive.per_op) | set(opt.per_op))
    for op in ops:
        print(f"  {op:<14} naive={naive.per_op.get(op, 0):>12,} "
              f"opt={opt.per_op.get(op, 0):>12,}")
    return {"total_naive": tot_naive, "total_opt": tot_opt,
            "reduction": total_red}


if __name__ == "__main__":
    sf = float(sys.argv[1]) if len(sys.argv) > 1 else 0.02
    run(sf)
