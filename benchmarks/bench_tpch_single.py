"""Figure 4 analogue: single-node TPC-H, accelerator engine vs host baseline.

The paper compares Sirius-on-GH200 against DuckDB-on-CPU at equal rental
cost.  This container has no accelerator, so the measured comparison is the
jnp pipeline engine (hot run, data cached by the buffer manager) against the
pure-numpy host engine — a *structure* validation (same plans, same results,
per-query timings).  The cost-normalized accelerator projection lives in
bench_costmodel.py.
"""
from __future__ import annotations

import json
import time

import numpy as np


def run(scale_factor: float = 0.02, repeats: int = 2,
        json_path: str | None = None, use_kernels: bool = False):
    from repro.core import instrument
    from repro.core.executor import SiriusEngine
    from repro.core.fallback import FallbackEngine
    from repro.data.tpch import generate, load_into_engine
    from repro.data.tpch_queries import QUERIES

    db = generate(scale_factor)
    eng = SiriusEngine(use_kernels=use_kernels)
    t0 = time.perf_counter()
    load_into_engine(eng, db)
    cold_load_s = time.perf_counter() - t0
    fb = FallbackEngine(db)

    rows = []
    cold = {}
    for qid in sorted(QUERIES):
        # cold run: parse-free plan, but pays lowering + region traces +
        # scalar syncs and records the executable plan.  Its wall time and
        # trace/compile attribution are kept (satellite of the warm-path
        # work: compile cost lands on the query that incurred it) — the
        # timed repeats below replay the plan cache, which is the
        # steady-state number the paper's warm path argues for.
        t0 = time.perf_counter()
        eng.execute(QUERIES[qid]())
        cold[qid] = {"cold_s": time.perf_counter() - t0,
                     "compile_s": eng.executor.last_compile_seconds}
        # dispatch budget telemetry around the warm repeats: barrier count
        # (contract: one per query) and buffer-ledger transfer bytes
        # (contract: zero once warm) — profile_diff.py hard-gates both.
        syncs0 = instrument.sync_barriers.value
        xfer0 = eng.buffers.host_transfer_bytes
        t0 = time.perf_counter()
        for _ in range(repeats):
            eng.execute(QUERIES[qid]())
        t_eng = (time.perf_counter() - t0) / repeats
        cold[qid]["dispatch"] = {
            "syncs_per_query":
                (instrument.sync_barriers.value - syncs0) / repeats,
            "transfer_bytes_per_query":
                (eng.buffers.host_transfer_bytes - xfer0) / repeats,
        }
        cold[qid]["plan_cache_hit"] = eng.executor.last_plan_cache_hit

        fb.execute(QUERIES[qid]())
        t0 = time.perf_counter()
        for _ in range(repeats):
            fb.execute(QUERIES[qid]())
        t_fb = (time.perf_counter() - t0) / repeats
        rows.append((qid, t_eng, t_fb))

    print(f"# tpch_single sf={scale_factor} cold_load_s={cold_load_s:.2f}")
    print("name,us_per_call,derived")
    for qid, t_eng, t_fb in rows:
        print(f"tpch_q{qid}_engine,{t_eng*1e6:.0f},host_over_engine="
              f"{t_fb/t_eng:.2f}x")
        print(f"tpch_q{qid}_hostbaseline,{t_fb*1e6:.0f},")
    tot_e = sum(r[1] for r in rows)
    tot_f = sum(r[2] for r in rows)
    geo = float(np.exp(np.mean([np.log(r[2] / r[1]) for r in rows])))
    print(f"tpch_total_engine,{tot_e*1e6:.0f},total_ratio={tot_f/tot_e:.2f}x")
    print(f"tpch_total_hostbaseline,{tot_f*1e6:.0f},geomean_ratio={geo:.2f}x")

    if json_path:
        # the perf-trajectory artifact tracked from PR 2 onward: per-query
        # wall time plus kernel/fallback hit counts.  Timings come from the
        # engine configured above (default: fused jnp path — the number that
        # must never regress); kernel-route hit counts are sampled from a
        # use_kernels engine on representative queries when the timed engine
        # doesn't carry a backend (interpret-mode kernels are exact but slow
        # on CPU-only containers, so they are not the timed path here).
        # hybrid-router view of every query: fraction of plan rels the
        # device engine owns after capability routing (1.0 = the paper's
        # fully device-resident happy path; anything lower means host
        # fragments ran on the fallback oracle)
        from repro.substrait import HybridRouter
        router = HybridRouter(eng)
        frac = {qid: router.device_fragment_fraction(QUERIES[qid]())
                for qid in sorted(QUERIES)}
        # kernel-tier coverage: run EVERY query once on a fresh use_kernels
        # engine and record the per-query kernel-route hit deltas (filter /
        # probe / agg / expand / topk).  A fresh engine keeps attribution
        # honest — its plan cache is cold, so prepare-time probe lowering
        # counts too.  Interpret-mode kernels are exact but slow on
        # CPU-only containers, so this stays out of the timed path.
        keng = SiriusEngine(use_kernels=True)
        load_into_engine(keng, db)
        kernel_hits = {"per_query": {}}
        for qid in sorted(QUERIES):
            before = keng.backend.hit_counts()
            fb_before = keng.executor.fallback_queries
            keng.execute(QUERIES[qid]())
            after = keng.backend.hit_counts()
            kernel_hits["per_query"][f"q{qid}"] = dict(
                {k: after[k] - before[k] for k in after},
                fallback=keng.executor.fallback_queries - fb_before)
        kernel_hits["totals"] = keng.backend.hit_counts()
        # per-query EXPLAIN ANALYZE profiles, embedded so profile_diff.py
        # can attribute any BENCH regression to the operator that moved.
        # Collected after the timing loops (the analyze barriers must never
        # touch the timed path); caches are warm, so these are steady-state
        # operator timings, not first-trace compile noise.
        profiles = {}
        for qid in sorted(QUERIES):
            eng.execute(QUERIES[qid](), analyze=True,
                        query_text=f"tpch q{qid}")
            profiles[f"q{qid}"] = eng.last_profile.to_dict()
        payload = {
            "scale_factor": scale_factor,
            "repeats": repeats,
            "use_kernels": use_kernels,
            "cold_load_s": round(cold_load_s, 4),
            "queries": {f"q{qid}": {"engine_s": round(t_eng, 6),
                                    "host_s": round(t_fb, 6),
                                    "cold_s": round(cold[qid]["cold_s"], 6),
                                    "compile_s_cold":
                                        round(cold[qid]["compile_s"], 6),
                                    "plan_cache_hit":
                                        cold[qid]["plan_cache_hit"],
                                    "dispatch": cold[qid]["dispatch"],
                                    "device_fragment_fraction": frac[qid],
                                    "profile": profiles[f"q{qid}"]}
                        for qid, t_eng, t_fb in rows},
            "total_engine_s": round(tot_e, 6),
            "total_host_s": round(tot_f, 6),
            "total_cold_s": round(sum(c["cold_s"] for c in cold.values()), 6),
            "kernel_hits": kernel_hits,
            "plan_cache": dict(eng.executor.plan_cache.stats),
            "fallback_queries": eng.executor.fallback_queries,
            "compiler": dict(eng.compiler.stats),
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    run()
