"""Benchmark harness — one benchmark per paper table/figure.

  bench_tpch_single   Figure 4: single-node TPC-H, engine vs host baseline
  bench_clickbench    ClickBench hits sample, engine vs host baseline
  bench_breakdown     Figure 5: per-operator breakdown
  bench_distributed   Table 2: distributed Q1/Q3/Q6(+Q12), compute/exchange/other
  bench_costmodel     Table 1/SS4.2: equal-rental-cost projection (labeled)
  roofline            assignment SSRoofline: terms from dry-run artifacts
  bench_kernels       Pallas kernel microbenches (interpret-mode, vs jnp ref)

Prints ``name,us_per_call,derived`` CSV per section.
Usage: PYTHONPATH=src python -m benchmarks.run [section ...] [--shards N]

``--shards N`` sizes the distributed mesh and records the per-query
compute/exchange/other totals into BENCH_tpch.json's ``distributed``
section (given alone it runs just the distributed section).
"""
import sys
import time


def _section(title):
    print(f"\n### {title} " + "#" * max(10, 60 - len(title)))


def bench_kernels():
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")

    def timeit(fn, reps=3):
        fn()  # warm/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn()
        import jax
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / reps

    n = 200_000
    g = jnp.asarray(rng.integers(0, 512, n))
    v = jnp.asarray(rng.normal(size=(n, 1)).astype(np.float32))
    t_k = timeit(lambda: ops.groupby_sum(g, v, 512))
    t_r = timeit(lambda: ref.groupby_sum_ref(g, v, 512))
    print(f"kernel_groupby_sum,{t_k*1e6:.0f},interpret_vs_ref={t_k/t_r:.1f}x")

    cols = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    lo = jnp.array([-1.0, -1.0, -1.0], jnp.float32)
    hi = jnp.array([1.0, 1.0, 1.0], jnp.float32)
    t_k = timeit(lambda: ops.filter_mask_counts(cols, lo, hi))
    t_r = timeit(lambda: ref.filter_mask_counts_ref(cols, lo, hi))
    print(f"kernel_filter,{t_k*1e6:.0f},interpret_vs_ref={t_k/t_r:.1f}x")

    bk = rng.choice(np.arange(4 * 50_000, dtype=np.int64), 50_000, False)
    pk = rng.choice(bk, n)
    b32, p32 = ops.factorize_keys_int32(bk, pk)
    sk, sr, _ = ops.build_table32(jnp.asarray(b32))
    pj = jnp.asarray(p32)
    t_k = timeit(lambda: ops.hash_probe(pj, sk, sr))
    t_r = timeit(lambda: ref.hash_probe_ref(pj, sk, sr))
    print(f"kernel_hash_probe,{t_k*1e6:.0f},interpret_vs_ref={t_k/t_r:.1f}x")

    b, h, kvh, d, s = 2, 8, 4, 64, 2048
    q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    lens = jnp.asarray([s, s // 2])
    t_k = timeit(lambda: ops.decode_attention(q, k, vv, lens))
    t_r = timeit(lambda: ref.decode_attention_ref(q, k, vv, lens))
    print(f"kernel_decode_attn,{t_k*1e6:.0f},interpret_vs_ref={t_k/t_r:.1f}x")


def main() -> None:
    from . import (bench_breakdown, bench_clickbench, bench_costmodel,
                   bench_distributed, bench_tpch_single, roofline)
    argv = sys.argv[1:]
    shards = None
    if "--shards" in argv:
        i = argv.index("--shards")
        shards = int(argv[i + 1])
        del argv[i:i + 2]
    sections = {
        "tpch_single": lambda: bench_tpch_single.run(
            json_path="BENCH_tpch.json"),
        "clickbench": lambda: bench_clickbench.run(
            json_path="BENCH_clickbench.json"),
        "breakdown": lambda: bench_breakdown.run(),
        # --shards N sizes the mesh and records totals into BENCH_tpch.json
        "distributed": lambda: bench_distributed.run(
            n_shards=shards or 8,
            json_path="BENCH_tpch.json" if shards else None),
        "costmodel": lambda: bench_costmodel.run(),
        "roofline": lambda: roofline.run(),
        "kernels": bench_kernels,
    }
    # --shards N alone means "the distributed section, recorded"
    wanted = argv or (["distributed"] if shards else list(sections))
    for name in wanted:
        _section(name)
        t0 = time.time()
        try:
            sections[name]()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_SECTION_FAILED,0,{type(e).__name__}:{e}")
        print(f"# section {name} took {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
