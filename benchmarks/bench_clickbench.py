"""ClickBench analogue of bench_tpch_single: hits-table sample, accelerator
engine (hot run) vs the pure-numpy host engine, per query.

The paper's second headline number is 7.4x cost efficiency on ClickBench;
this container has no accelerator, so — exactly like BENCH_tpch.json — the
artifact is a *structure* validation: same SQL, same results, per-query
timings for the fused jnp path, plus compiler/string-subsystem statistics
showing the string predicates stayed on the device path (see DESIGN.md
"Benchmark protocol").
"""
from __future__ import annotations

import json
import time

import numpy as np


def run(n_rows: int = 200_000, repeats: int = 2,
        json_path: str | None = None, use_kernels: bool = False):
    from repro.core import instrument
    from repro.core.executor import SiriusEngine
    from repro.core.fallback import FallbackEngine
    from repro.data import clickbench as cb
    from repro.relational import strings
    from repro.sql import sql_to_plan

    db = cb.generate(n_rows)
    catalog = cb.clickbench_catalog(n_rows)
    eng = SiriusEngine(use_kernels=use_kernels)
    t0 = time.perf_counter()
    cb.load_into_engine(eng, db)
    cold_load_s = time.perf_counter() - t0
    fb = FallbackEngine(db)

    rows = []
    cold = {}
    for qid, sql in cb.CLICKBENCH_QUERIES.items():
        plan = sql_to_plan(sql, catalog)
        # cold run records the executable plan (and pays the region
        # traces); the timed repeats below are plan-cache replays — the
        # steady-state warm path.  Trace/compile time is attributed to
        # the query that incurred it.
        t0 = time.perf_counter()
        eng.execute(plan)
        cold[qid] = {"cold_s": time.perf_counter() - t0,
                     "compile_s": eng.executor.last_compile_seconds}
        # fresh plan objects per repeat (built outside the timed window):
        # warm hits must come from the structural signature, never object
        # identity — the same contract the TPC-H bench exercises
        warm_plans = [sql_to_plan(sql, catalog) for _ in range(repeats)]
        syncs0 = instrument.sync_barriers.value
        xfer0 = eng.buffers.host_transfer_bytes
        t0 = time.perf_counter()
        for p in warm_plans:
            eng.execute(p)
        t_eng = (time.perf_counter() - t0) / repeats
        cold[qid]["dispatch"] = {
            "syncs_per_query":
                (instrument.sync_barriers.value - syncs0) / repeats,
            "transfer_bytes_per_query":
                (eng.buffers.host_transfer_bytes - xfer0) / repeats,
        }
        cold[qid]["plan_cache_hit"] = eng.executor.last_plan_cache_hit

        fb.execute(plan)
        t0 = time.perf_counter()
        for _ in range(repeats):
            fb.execute(plan)
        t_fb = (time.perf_counter() - t0) / repeats
        rows.append((qid, t_eng, t_fb))

    print(f"# clickbench rows={n_rows} cold_load_s={cold_load_s:.2f}")
    print("name,us_per_call,derived")
    for qid, t_eng, t_fb in rows:
        print(f"clickbench_{qid}_engine,{t_eng*1e6:.0f},host_over_engine="
              f"{t_fb/t_eng:.2f}x")
        print(f"clickbench_{qid}_hostbaseline,{t_fb*1e6:.0f},")
    tot_e = sum(r[1] for r in rows)
    tot_f = sum(r[2] for r in rows)
    geo = float(np.exp(np.mean([np.log(r[2] / r[1]) for r in rows])))
    print(f"clickbench_total_engine,{tot_e*1e6:.0f},"
          f"total_ratio={tot_f/tot_e:.2f}x")
    print(f"clickbench_total_hostbaseline,{tot_f*1e6:.0f},"
          f"geomean_ratio={geo:.2f}x")

    if json_path:
        # per-query EXPLAIN ANALYZE profiles for profile_diff.py, collected
        # after the timing loops on warm caches (never in the timed path)
        profiles = {}
        for qid, sql in cb.CLICKBENCH_QUERIES.items():
            eng.execute(sql_to_plan(sql, catalog), analyze=True,
                        query_text=f"clickbench {qid}")
            profiles[qid] = eng.last_profile.to_dict()
        # kernel-tier coverage over every ClickBench query on a fresh
        # use_kernels engine (cold plan cache, honest per-query deltas);
        # interpret-mode kernels stay out of the timed path
        keng = SiriusEngine(use_kernels=True)
        cb.load_into_engine(keng, db)
        kernel_hits = {"per_query": {}}
        for qid, sql in cb.CLICKBENCH_QUERIES.items():
            before = keng.backend.hit_counts()
            fb_before = keng.executor.fallback_queries
            keng.execute(sql_to_plan(sql, catalog))
            after = keng.backend.hit_counts()
            kernel_hits["per_query"][qid] = dict(
                {k: after[k] - before[k] for k in after},
                fallback=keng.executor.fallback_queries - fb_before)
        kernel_hits["totals"] = keng.backend.hit_counts()
        payload = {
            "workload": "clickbench",
            "rows": n_rows,
            "repeats": repeats,
            "use_kernels": use_kernels,
            "cold_load_s": round(cold_load_s, 4),
            "queries": {qid: {"engine_s": round(t_eng, 6),
                              "host_s": round(t_fb, 6),
                              "cold_s": round(cold[qid]["cold_s"], 6),
                              "compile_s_cold":
                                  round(cold[qid]["compile_s"], 6),
                              "plan_cache_hit": cold[qid]["plan_cache_hit"],
                              "dispatch": cold[qid]["dispatch"],
                              "profile": profiles[qid]}
                        for qid, t_eng, t_fb in rows},
            "total_engine_s": round(tot_e, 6),
            "total_host_s": round(tot_f, 6),
            "total_cold_s": round(sum(c["cold_s"] for c in cold.values()), 6),
            "kernel_hits": kernel_hits,
            "plan_cache": dict(eng.executor.plan_cache.stats),
            "string_subsystem": dict(strings.stats),
            "compiler": dict(eng.compiler.stats),
            "fallback_queries": eng.executor.fallback_queries,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    run(json_path="BENCH_clickbench.json")
