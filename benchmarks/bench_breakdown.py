"""Figure 5 analogue: per-operator time breakdown across TPC-H queries.

The paper's finding: joins dominate join-heavy queries (Q2-Q5, Q7-Q8,
Q20-Q22), group-by matters for Q1/Q10/Q16/Q18, filters dominate Q6/Q19/Q13.
This benchmark reports the same decomposition from ``QueryProfile`` — one
format for both execution modes:

  * ``profile=True`` engine — the pre-fusion eager path with per-op
    barriers (the original Figure-5 protocol);
  * default fused engine with ``analyze=True`` — the production path with
    opt-in per-region barriers, where fused regions report under the
    "fused" category and scans/sinks stay attributable.

It also runs every query once on the *default* fused engine under the
host-transfer counter, proving the compiled data path keeps columns
device-resident end to end (the §3.2 residency claim as a number: 0).
"""
from __future__ import annotations

CATS = ("filter", "join", "groupby", "orderby", "project", "other")


def _shares(totals: dict) -> tuple[float, dict]:
    total = sum(totals.values()) or 1e-12
    return total, {c: totals.get(c, 0.0) / total for c in totals}


def run(scale_factor: float = 0.02):
    from repro.core import instrument
    from repro.core.executor import SiriusEngine
    from repro.data.tpch import generate, load_into_engine
    from repro.data.tpch_queries import QUERIES

    db = generate(scale_factor)
    eng = SiriusEngine(profile=True)
    load_into_engine(eng, db)

    print("name,us_per_call,derived")
    dominant = {}
    for qid in sorted(QUERIES):
        eng.execute(QUERIES[qid]())              # warm
        eng.execute(QUERIES[qid]())
        # per-operator numbers come from the unified QueryProfile record
        # (profile=True keeps a live builder on every query)
        totals = dict(eng.last_profile.operator_totals)
        total, shares = _shares(totals)
        top = max((c for c in CATS), key=lambda c: shares.get(c, 0.0))
        dominant[qid] = top
        detail = ";".join(f"{c}={shares[c]*100:.0f}%" for c in CATS
                          if shares.get(c, 0.0) >= 0.005)
        print(f"breakdown_q{qid},{total*1e6:.0f},dominant={top};{detail}")

    join_heavy = [q for q in (3, 5, 7, 8, 9, 10, 21) if dominant[q] == "join"]
    print(f"breakdown_summary,0,join_dominant_in={len(join_heavy)}of7_joinheavy"
          f";q6_dominant={dominant[6]};q1_groupby_or_filter={dominant[1]}")

    # same decomposition from the *fused* production path via analyze=True —
    # identical QueryProfile format, fused regions land under "fused"
    fused = SiriusEngine()
    load_into_engine(fused, db)
    for qid in sorted(QUERIES):
        fused.execute(QUERIES[qid]())            # warm/compile
    for qid in sorted(QUERIES):
        fused.execute(QUERIES[qid](), analyze=True)
        totals = dict(fused.last_profile.operator_totals)
        total, shares = _shares(totals)
        detail = ";".join(
            f"{c}={s*100:.0f}%" for c, s in
            sorted(shares.items(), key=lambda kv: -kv[1]) if s >= 0.005)
        print(f"breakdown_fused_q{qid},{total*1e6:.0f},{detail}")

    # device residency on the default fused engine: must read 0 transfers
    with instrument.track_transfers() as counter:
        for qid in sorted(QUERIES):
            fused.execute(QUERIES[qid]())
    print(f"breakdown_host_transfers,{counter.in_pipeline},"
          f"in_pipeline={counter.in_pipeline};total={counter.total};"
          f"regions={fused.compiler.stats['region_calls']}")
    return dominant


if __name__ == "__main__":
    run()
