"""Figure 5 analogue: per-operator time breakdown across TPC-H queries.

The paper's finding: joins dominate join-heavy queries (Q2-Q5, Q7-Q8,
Q20-Q22), group-by matters for Q1/Q10/Q16/Q18, filters dominate Q6/Q19/Q13.
This benchmark reports the same decomposition from the pipeline executor's
per-operator timers (``profile=True`` — the only mode that inserts per-op
barriers) and checks the headline pattern.

It also runs every query once on the *default* fused engine under the
host-transfer counter, proving the compiled data path keeps columns
device-resident end to end (the §3.2 residency claim as a number: 0).
"""
from __future__ import annotations

CATS = ("filter", "join", "groupby", "orderby", "project", "other")


def run(scale_factor: float = 0.02):
    from repro.core import instrument
    from repro.core.executor import SiriusEngine
    from repro.data.tpch import generate, load_into_engine
    from repro.data.tpch_queries import QUERIES

    db = generate(scale_factor)
    eng = SiriusEngine(profile=True)
    load_into_engine(eng, db)

    print("name,us_per_call,derived")
    dominant = {}
    for qid in sorted(QUERIES):
        eng.execute(QUERIES[qid]())              # warm
        eng.executor.op_times.clear()
        eng.execute(QUERIES[qid]())
        times = dict(eng.executor.op_times)
        total = sum(times.values()) or 1e-12
        shares = {c: times.get(c, 0.0) / total for c in CATS}
        top = max(shares, key=shares.get)
        dominant[qid] = top
        detail = ";".join(f"{c}={shares[c]*100:.0f}%" for c in CATS
                          if shares[c] >= 0.005)
        print(f"breakdown_q{qid},{total*1e6:.0f},dominant={top};{detail}")

    join_heavy = [q for q in (3, 5, 7, 8, 9, 10, 21) if dominant[q] == "join"]
    print(f"breakdown_summary,0,join_dominant_in={len(join_heavy)}of7_joinheavy"
          f";q6_dominant={dominant[6]};q1_groupby_or_filter={dominant[1]}")

    # device residency on the default fused engine: must read 0 transfers
    fused = SiriusEngine()
    load_into_engine(fused, db)
    for qid in sorted(QUERIES):
        fused.execute(QUERIES[qid]())            # warm/compile
    with instrument.track_transfers() as counter:
        for qid in sorted(QUERIES):
            fused.execute(QUERIES[qid]())
    print(f"breakdown_host_transfers,{counter.in_pipeline},"
          f"in_pipeline={counter.in_pipeline};total={counter.total};"
          f"regions={fused.compiler.stats['region_calls']}")
    return dominant


if __name__ == "__main__":
    run()
