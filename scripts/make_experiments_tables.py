"""Generate the §Dry-run and §Roofline markdown tables from artifacts.

Usage: PYTHONPATH=src python scripts/make_experiments_tables.py
Writes artifacts/roofline_table.md + artifacts/dryrun_table.md.
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.roofline import PEAK_FLOPS, HBM_BW, ICI_BW, terms  # noqa: E402

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts")


def load(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", "*.json"))):
        r = json.load(open(p))
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def main():
    # -- roofline table (single-pod) ------------------------------------------
    rows = []
    for rec in load("16x16"):
        if rec.get("status") != "ok":
            rows.append((rec["arch"], rec["shape"], None, rec.get("error", "")))
            continue
        t = terms(rec)
        rows.append((rec["arch"], rec["shape"], t, rec))

    with open(os.path.join(ART, "roofline_table.md"), "w") as f:
        f.write("| arch | shape | compute | memory | collective | dominant | "
                "useful | roofline-frac | fits 16GB | resident/chip |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for arch, shape, t, rec in rows:
            if t is None:
                f.write(f"| {arch} | {shape} | FAILED | | | | | | | |\n")
                continue
            ur = f"{t['useful_ratio']:.2f}" if t["useful_ratio"] else "n/a"
            gib = rec["memory"]["resident_bytes_per_chip"] / 2**30
            f.write(f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
                    f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                    f"**{t['dominant']}** | {ur} | "
                    f"{t['roofline_fraction']:.2f} | "
                    f"{'✓' if rec['memory']['fits_16gb_v5e'] else '✗'} | "
                    f"{gib:.1f} GiB |\n")

    # -- dry-run status table (both meshes) ------------------------------------
    with open(os.path.join(ART, "dryrun_table.md"), "w") as f:
        f.write("| arch | shape | 16x16 | 2x16x16 | FLOPs/dev (16x16) | "
                "coll B/dev | compile s |\n|---|---|---|---|---|---|---|\n")
        single = {(r["arch"], r["shape"]): r for r in load("16x16")}
        multi = {(r["arch"], r["shape"]): r for r in load("2x16x16")}
        for key in sorted(set(single) | set(multi)):
            s = single.get(key)
            m = multi.get(key)

            def st(r):
                if r is None:
                    return "—"
                return "OK" if r["status"] == "ok" else "FAIL"

            fl = f"{s['flops_per_device']:.2e}" if s and s["status"] == "ok" \
                else ""
            cb = (f"{s['collective_bytes_per_device']['total']:.2e}"
                  if s and s.get("status") == "ok" else "")
            ct = f"{s.get('compile_time_s', '')}" if s else ""
            f.write(f"| {key[0]} | {key[1]} | {st(s)} | {st(m)} | {fl} | "
                    f"{cb} | {ct} |\n")

    n_ok_s = sum(1 for r in load("16x16") if r["status"] == "ok")
    n_ok_m = sum(1 for r in load("2x16x16") if r["status"] == "ok")
    print(f"tables written; ok cells: 16x16={n_ok_s} 2x16x16={n_ok_m}")


if __name__ == "__main__":
    main()
