#!/usr/bin/env python
"""Render a distributed query's journal as timeline / Chrome trace / skew.

Runs distributed TPC-H (default Q3) on a forced host mesh, then serves the
query journal four ways and cross-checks it:

* text timeline of the merged span tree (coordinator + fragments +
  replicas + per-shard engine runs + exchanges, one tree per query ID);
* top-operators table (wall time aggregated by span name);
* per-exchange bytes/skew report;
* ``--chrome out.json`` — Chrome trace-event JSON loadable in Perfetto /
  chrome://tracing (coordinator = pid 0, shard *s* = pid *s*+1).

Verification (exit 1 on failure):

* ``verify_tree`` structural/temporal checks over the warm run's tree;
* warm root-span wall vs the engine's own ``timers["total"]``;
* single-node ``engine.execute`` journal span vs ``QueryProfile``
  ``total_seconds`` (tolerance: 10% + 25 ms each).

``--jsonl FILE`` skips the live run and reads a journal sink written via
``REPRO_JOURNAL_SINK`` / ``attach_sink`` instead (rendering + structural
checks only — engine timers are not in the file).

Run:  PYTHONPATH=src python scripts/trace_report.py [--shards N] [--sf SF]
          [--qid N] [--chrome OUT.json] [--jsonl IN.jsonl] [--query-id ID]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--shards", type=int, default=4)
ap.add_argument("--sf", type=float, default=0.004)
ap.add_argument("--qid", type=int, default=3, help="TPC-H query number")
ap.add_argument("--chrome", metavar="OUT.json",
                help="write Chrome trace-event JSON here")
ap.add_argument("--jsonl", metavar="IN.jsonl",
                help="analyze an existing journal sink instead of running")
ap.add_argument("--query-id", help="query ID to report (default: last)")
ap.add_argument("--top", type=int, default=15)
ARGS = ap.parse_args()

os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ARGS.shards}")

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.observability.dist import (  # noqa: E402
    exchange_report, query_wall, render_exchange_report, render_timeline,
    render_top_operators, top_operators, verify_tree)
from repro.observability.journal import (  # noqa: E402
    JOURNAL, load_jsonl, to_chrome)

TOLERANCE_FRAC = 0.10
TOLERANCE_S = 0.025


def close_enough(a: float, b: float) -> bool:
    return abs(a - b) <= TOLERANCE_FRAC * max(a, b) + TOLERANCE_S


def report(events, query_id, epoch: float, failures) -> None:
    print(f"\n== timeline for {query_id} ==")
    print(render_timeline(events, query_id, epoch=epoch))
    print(f"\n== top operators ==")
    print(render_top_operators(top_operators(events, query_id, n=ARGS.top)))
    print(f"\n== exchanges ==")
    print(render_exchange_report(exchange_report(events, query_id)))
    errors = verify_tree(events, query_id)
    if errors:
        failures.append(f"verify_tree({query_id}): {len(errors)} violations")
        for e in errors[:10]:
            print(f"  VIOLATION: {e}")
    else:
        print(f"\nverify_tree({query_id}): ok")


def main() -> int:
    failures = []

    if ARGS.jsonl:
        events = load_jsonl(ARGS.jsonl)
        if not events:
            print(f"error: no events in {ARGS.jsonl}", file=sys.stderr)
            return 2
        qids = []
        for e in events:
            if e["query_id"] not in qids:
                qids.append(e["query_id"])
        qid = ARGS.query_id or qids[-1]
        epoch = min(e["ts"] for e in events)
        report(events, qid, epoch, failures)
        if ARGS.chrome:
            with open(ARGS.chrome, "w") as f:
                json.dump(to_chrome(
                    [e for e in events if e["query_id"] == qid],
                    epoch=epoch), f)
            print(f"chrome trace -> {ARGS.chrome}")
        if failures:
            print(f"\nFAIL: {failures}")
            return 1
        print("\nOK")
        return 0

    from repro.core.distributed import DistributedEngine  # noqa: E402
    from repro.core.executor import SiriusEngine  # noqa: E402
    from repro.data.tpch import generate, load_into_engine  # noqa: E402
    from repro.data.tpch_queries import QUERIES  # noqa: E402

    db = generate(ARGS.sf)
    eng = DistributedEngine(db, n_shards=ARGS.shards)
    plan_fn = QUERIES[ARGS.qid]

    print(f"distributed q{ARGS.qid} on {ARGS.shards} shards "
          f"(sf {ARGS.sf}): cold + warm run ...")
    eng.run_plan(plan_fn())            # cold: compiles, may speculate
    eng.run_plan(plan_fn())            # warm: the run we verify
    qid = ARGS.query_id or eng.last_query_id
    events = JOURNAL.events()

    report(events, qid, JOURNAL.epoch, failures)

    # cross-check 1: warm root span wall vs the engine's own total timer
    wall, root = query_wall(events, qid)
    total = eng.timers.get("total", 0.0)
    ok = root is not None and close_enough(wall, total)
    print(f"\nroot span {wall * 1e3:.2f} ms vs engine timers total "
          f"{total * 1e3:.2f} ms: {'ok' if ok else 'MISMATCH'}")
    if not ok:
        failures.append("root span wall vs engine timers total")

    # cross-check 2: single-node engine.execute span vs QueryProfile
    seng = SiriusEngine()
    load_into_engine(seng, db)
    seng.execute(plan_fn())            # cold
    seng.execute(plan_fn(), analyze=True)
    sqid, prof = seng.last_query_id, seng.last_profile
    span_evs = [e for e in JOURNAL.events(sqid)
                if e["name"] == "engine.execute" and e["kind"] == "span"]
    if span_evs and prof is not None:
        span_s = max(e["dur"] for e in span_evs)
        ok = close_enough(span_s, prof.total_seconds)
        print(f"single-node engine.execute span {span_s * 1e3:.2f} ms vs "
              f"QueryProfile total {prof.total_seconds * 1e3:.2f} ms: "
              f"{'ok' if ok else 'MISMATCH'}")
        if not ok:
            failures.append("engine.execute span vs QueryProfile total")
    else:
        failures.append("no single-node engine.execute span / profile")

    if ARGS.chrome:
        with open(ARGS.chrome, "w") as f:
            json.dump(to_chrome(JOURNAL.events(qid), epoch=JOURNAL.epoch), f)
        print(f"chrome trace -> {ARGS.chrome}")

    if failures:
        print(f"\nFAIL: {failures}")
        return 1
    print(f"\nOK: journal tree verified for {qid}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
