"""SQL-frontend smoke check: parse, optimize and EXPLAIN every SQL-text
TPC-H query *without executing it* (no data generation, no engine).

Exit code is non-zero if any query fails to parse/bind/lower/optimize, if
the optimized plan fails to round-trip through the JSON wire format, or if
predicate pushdown failed to land a filter in a ReadRel where one is
expected.  This is the fast CI job guarding the frontend.

Run:  PYTHONPATH=src python scripts/sql_smoke.py [-v]
"""
from __future__ import annotations

import sys


def main(verbose: bool = False) -> int:
    from repro.core.plan import (
        ReadRel, explain, plan_equal, plan_from_json, plan_to_json, walk,
    )
    from repro.data.tpch_queries import SQL_PUSHDOWN_QIDS, SQL_QUERIES
    from repro.sql import sql_to_plan

    failures = 0
    for qid in sorted(SQL_QUERIES):
        try:
            naive = sql_to_plan(SQL_QUERIES[qid], optimize=False)
            opt = sql_to_plan(SQL_QUERIES[qid], optimize=True)
            restored = plan_from_json(plan_to_json(opt))
            assert plan_equal(restored, opt), "wire-format round-trip drifted"
            pushed = [r for r in walk(opt)
                      if isinstance(r, ReadRel) and r.filter is not None]
            if qid in SQL_PUSHDOWN_QIDS:
                assert pushed, "predicate pushdown reached no ReadRel"
            n_ops = sum(1 for _ in walk(opt))
            print(f"Q{qid:>2}: ok — {n_ops} operators, "
                  f"{len(pushed)} scan filter(s)")
            if verbose:
                print(explain(opt))
                print()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"Q{qid:>2}: FAIL — {type(e).__name__}: {e}")
    total = len(SQL_QUERIES)
    print(f"\n{total - failures}/{total} SQL TPC-H queries parse, optimize "
          "and explain cleanly")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(verbose="-v" in sys.argv[1:]))
