"""SQL-frontend smoke check: parse, optimize and EXPLAIN every SQL-text
query of a workload *without executing it* (no data generation, no engine).

Workloads:
  * ``tpch``       — all 22 TPC-H queries (``SQL_QUERIES``)
  * ``clickbench`` — the ClickBench hits-table query set
  * ``all``        — both (the CI default)

Exit code is non-zero if any query fails to parse/bind/lower/optimize, if
the optimized plan fails to round-trip through the JSON wire format, or if
predicate pushdown failed to land a filter in a ReadRel where one is
expected.  This is the fast CI job guarding the frontend.

``--analyze`` instead runs EXPLAIN ANALYZE end-to-end on one TPC-H query
(Q6) and one ClickBench query against tiny generated data, validates the
emitted profile JSON against the schema, and writes the profiles to
``--artifacts-dir`` (default ``profile_artifacts/``) for CI upload.

Run:  PYTHONPATH=src python scripts/sql_smoke.py [--workload tpch|clickbench|all] [-v]
      PYTHONPATH=src python scripts/sql_smoke.py --analyze [--artifacts-dir DIR]
"""
from __future__ import annotations

import os
import sys


def check_workload(name: str, queries: dict, pushdown_qids, catalog,
                   verbose: bool = False) -> int:
    from repro.core.plan import (
        ReadRel, explain, plan_equal, plan_from_json, plan_to_json, walk,
    )
    from repro.sql import sql_to_plan

    failures = 0
    for qid in queries:
        try:
            sql_to_plan(queries[qid], catalog, optimize=False)
            opt = sql_to_plan(queries[qid], catalog, optimize=True)
            restored = plan_from_json(plan_to_json(opt))
            assert plan_equal(restored, opt), "wire-format round-trip drifted"
            pushed = [r for r in walk(opt)
                      if isinstance(r, ReadRel) and r.filter is not None]
            if qid in pushdown_qids:
                assert pushed, "predicate pushdown reached no ReadRel"
            n_ops = sum(1 for _ in walk(opt))
            print(f"{name} {qid!s:>4}: ok — {n_ops} operators, "
                  f"{len(pushed)} scan filter(s)")
            if verbose:
                print(explain(opt))
                print()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name} {qid!s:>4}: FAIL — {type(e).__name__}: {e}")
    total = len(queries)
    print(f"{total - failures}/{total} {name} queries parse, optimize "
          "and explain cleanly\n")
    return failures


def analyze_smoke(artifacts_dir: str = "profile_artifacts") -> int:
    """EXPLAIN ANALYZE one TPC-H + one ClickBench query on tiny data,
    validate the profile JSON schema, and write the artifacts."""
    from repro.core.executor import SiriusEngine
    from repro.data import clickbench as cb
    from repro.data import tpch
    from repro.data.tpch_queries import SQL_QUERIES
    from repro.observability import QueryProfile, validate_profile

    os.makedirs(artifacts_dir, exist_ok=True)
    failures = 0

    def run_one(name: str, engine, sql: str, catalog) -> None:
        nonlocal failures
        prof = engine.sql("EXPLAIN ANALYZE " + sql, catalog=catalog)
        errors = validate_profile(prof.to_dict())
        # the export must also survive a JSON round-trip unchanged
        restored = QueryProfile.from_json(prof.to_json())
        if restored.to_json() != prof.to_json():
            errors.append("to_json round-trip drifted")
        path = os.path.join(artifacts_dir, f"profile_{name}.json")
        with open(path, "w") as f:
            f.write(prof.to_json())
        if errors:
            failures += 1
            print(f"{name}: FAIL — {errors}")
        else:
            n_ops = sum(len(p.operators) for p in prof.pipelines)
            print(f"{name}: ok — {prof.total_seconds * 1e3:.1f} ms, "
                  f"{len(prof.pipelines)} pipeline(s), {n_ops} operator(s) "
                  f"-> {path}")
            print(prof.pretty())
            print()

    eng = SiriusEngine()
    tpch.load_into_engine(eng, tpch.generate(0.001))
    run_one("tpch_q6", eng, SQL_QUERIES[6], None)

    cb_eng = SiriusEngine()
    cb.load_into_engine(cb_eng, cb.generate(5_000))
    run_one("clickbench_q2", cb_eng, cb.CLICKBENCH_QUERIES["q2"],
            cb.clickbench_catalog(5_000))

    print(f"{2 - failures}/2 EXPLAIN ANALYZE smoke queries produced "
          "schema-valid profiles")
    return 1 if failures else 0


def main(workload: str = "all", verbose: bool = False) -> int:
    if workload not in ("tpch", "clickbench", "all"):
        print(f"unknown workload {workload!r}: expected tpch|clickbench|all")
        return 2
    failures = 0
    if workload in ("tpch", "all"):
        from repro.data.tpch_queries import SQL_PUSHDOWN_QIDS, SQL_QUERIES
        failures += check_workload("tpch", dict(sorted(SQL_QUERIES.items())),
                                   SQL_PUSHDOWN_QIDS, None, verbose)
    if workload in ("clickbench", "all"):
        from repro.data.clickbench import (
            CLICKBENCH_QUERIES, CLICKBENCH_STRING_QIDS, clickbench_catalog,
        )
        # every string-predicate query must land its filter in the scan
        pushdown = tuple(q for q in CLICKBENCH_STRING_QIDS if q != "q44x")
        failures += check_workload("clickbench", CLICKBENCH_QUERIES,
                                   pushdown, clickbench_catalog(), verbose)
    return 1 if failures else 0


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--analyze" in args:
        out_dir = "profile_artifacts"
        if "--artifacts-dir" in args:
            i = args.index("--artifacts-dir")
            if i + 1 >= len(args):
                print("--artifacts-dir requires a path")
                sys.exit(2)
            out_dir = args[i + 1]
        sys.exit(analyze_smoke(out_dir))
    wl = "all"
    if "--workload" in args:
        i = args.index("--workload")
        if i + 1 >= len(args):
            print("--workload requires a value: tpch|clickbench|all")
            sys.exit(2)
        wl = args[i + 1]
    sys.exit(main(wl, verbose="-v" in args))
