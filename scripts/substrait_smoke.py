"""Substrait interchange smoke: the drop-in boundary, proven end to end.

For every workload query this script

  1. produces the wire plan from the SQL frontend (``sql_to_wire``) and
     checks its canonical bytes against the checked-in golden file
     (``tests/golden/substrait/``) — the serialization-stability contract;
  2. re-ingests the wire and asserts structural round-trip exactness
     (``plan_equal``) plus byte-stable re-emission;
  3. (unless ``--no-exec``) writes the wire plans and reference result rows
     to a scratch directory and spawns a **fresh python process** that never
     sees the SQL text: the child regenerates the deterministic dataset,
     ingests each wire file, executes it through both engines —
     ``SiriusEngine.accelerate`` (the drop-in front door) and the numpy
     ``FallbackEngine`` — and validates row-exact results against the
     reference.  That is the proof the interface boundary is real, not an
     in-memory shortcut.

Run:  PYTHONPATH=src python scripts/substrait_smoke.py
          [--workload tpch|clickbench|all] [--update-golden] [--no-exec] [-v]

``--update-golden`` rewrites the golden wire files from the current
frontend output (review the diff before committing).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "tests", "golden", "substrait")
TPCH_SF = 0.01
CLICKBENCH_ROWS = 20_000


def _workload_items(workload: str):
    """Yields (name, sql, catalog_kind) per query."""
    items = []
    if workload in ("tpch", "all"):
        from repro.data.tpch_queries import SQL_QUERIES
        items += [(f"tpch_q{qid}", SQL_QUERIES[qid], "tpch")
                  for qid in sorted(SQL_QUERIES)]
    if workload in ("clickbench", "all"):
        from repro.data.clickbench import CLICKBENCH_QUERIES
        items += [(f"clickbench_{qid}", CLICKBENCH_QUERIES[qid], "clickbench")
                  for qid in sorted(CLICKBENCH_QUERIES)]
    return items


def _catalog(kind: str):
    if kind == "tpch":
        from repro.sql.binder import DEFAULT_CATALOG
        return DEFAULT_CATALOG
    from repro.data.clickbench import clickbench_catalog
    return clickbench_catalog()


def _host_result_to_jsonable(t: dict) -> dict:
    import numpy as np
    out = {}
    for k, v in t.items():
        v = np.asarray(v)
        if v.dtype.kind == "M":
            out[k] = [str(x) for x in v.astype("datetime64[D]")]
        elif v.dtype.kind in "UO":
            out[k] = [str(x) for x in v]
        elif v.dtype.kind == "f":
            out[k] = [float(x) for x in v]
        elif v.dtype.kind == "b":
            out[k] = [bool(x) for x in v]
        else:
            out[k] = [int(x) for x in v]
    return out


def _assert_rows_equal(name: str, got: dict, ref: dict, rtol=1e-6, atol=1e-6):
    import numpy as np
    got = _host_result_to_jsonable(got)
    assert set(got) == set(ref), \
        f"{name}: columns differ: {sorted(got)} vs {sorted(ref)}"
    for k in ref:
        a, b = got[k], ref[k]
        assert len(a) == len(b), f"{name}.{k}: {len(a)} vs {len(b)} rows"
        if a and isinstance(b[0], float):
            np.testing.assert_allclose(np.asarray(a, float),
                                       np.asarray(b, float),
                                       rtol=rtol, atol=atol,
                                       err_msg=f"{name}.{k}")
        else:
            assert a == b, f"{name}.{k}: first diff at " \
                f"{next(i for i, (x, y) in enumerate(zip(a, b)) if x != y)}"


# ---------------------------------------------------------------------------
# parent: golden check + round-trip + scratch emission
# ---------------------------------------------------------------------------


def run_parent(workload: str, update_golden: bool, execute: bool,
               verbose: bool) -> int:
    from repro.core.plan import plan_equal
    from repro.sql import sql_to_plan, sql_to_wire
    from repro.substrait import ingest, wire_bytes

    failures = 0
    wires = {}
    for name, sql, kind in _workload_items(workload):
        cat = _catalog(kind)
        try:
            wire = sql_to_wire(sql, cat)
            blob = wire_bytes(wire)
            golden_path = os.path.join(GOLDEN_DIR, f"{name}.json")
            if update_golden:
                os.makedirs(GOLDEN_DIR, exist_ok=True)
                with open(golden_path, "wb") as f:
                    f.write(blob)
                status = "golden updated"
            else:
                with open(golden_path, "rb") as f:
                    golden = f.read()
                assert blob == golden, \
                    "wire bytes drifted from checked-in golden file " \
                    f"({golden_path}); run --update-golden and review"
                status = "golden ok"
            restored = ingest(wire)
            assert plan_equal(restored, sql_to_plan(sql, cat)), \
                "ingest(emit(plan)) is not structurally equal to plan"
            assert wire_bytes(sql_to_wire(sql, cat)) == blob, \
                "re-emission is not byte-stable"
            wires[name] = (blob, sql, kind)
            print(f"{name:>16}: {status}, round-trip exact, "
                  f"{len(blob)} canonical bytes")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name:>16}: FAIL — {type(e).__name__}: {e}")
    total = len(_workload_items(workload))
    print(f"{total - failures}/{total} wire plans round-trip "
          "emit->ingest->emit byte-stable\n")
    if failures or not execute:
        return failures

    # -- cross-process execution proof ------------------------------------
    with tempfile.TemporaryDirectory(prefix="substrait_smoke_") as scratch:
        manifest = {"tpch_sf": TPCH_SF, "clickbench_rows": CLICKBENCH_ROWS,
                    "queries": []}
        from repro.core.fallback import FallbackEngine
        dbs = {}
        for name, (blob, sql, kind) in wires.items():
            if kind not in dbs:
                dbs[kind] = _generate_db(kind)
            ref = FallbackEngine(dbs[kind]).execute(
                sql_to_plan(sql, _catalog(kind)))
            wire_file = os.path.join(scratch, f"{name}.wire.json")
            ref_file = os.path.join(scratch, f"{name}.ref.json")
            with open(wire_file, "wb") as f:
                f.write(blob)
            with open(ref_file, "w") as f:
                json.dump(_host_result_to_jsonable(ref), f)
            manifest["queries"].append(
                {"name": name, "workload": kind,
                 "wire": wire_file, "ref": ref_file})
        with open(os.path.join(scratch, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        print(f"spawning fresh consumer process over {len(wires)} wire "
              "plans (no SQL crosses the boundary) ...")
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--child",
             os.path.join(scratch, "manifest.json")] + (["-v"] if verbose else []),
            env=dict(os.environ,
                     PYTHONPATH=os.pathsep.join(
                         p for p in ("src", os.environ.get("PYTHONPATH", ""))
                         if p)),
            cwd=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             os.pardir))
        if proc.returncode != 0:
            print("consumer process FAILED")
            return 1
    return 0


def _generate_db(kind: str):
    if kind == "tpch":
        from repro.data.tpch import generate
        return generate(TPCH_SF)
    from repro.data.clickbench import generate
    return generate(CLICKBENCH_ROWS)


# ---------------------------------------------------------------------------
# child: the consumer on the far side of the process boundary
# ---------------------------------------------------------------------------


def run_child(manifest_path: str, verbose: bool) -> int:
    from repro.core.fallback import FallbackEngine
    from repro.core.executor import SiriusEngine
    from repro.substrait import ingest

    with open(manifest_path) as f:
        manifest = json.load(f)
    engines = {}
    failures = 0
    for q in manifest["queries"]:
        name, kind = q["name"], q["workload"]
        if kind not in engines:
            db = _generate_db(kind)
            eng = SiriusEngine()
            if kind == "tpch":
                from repro.data.tpch import load_into_engine
            else:
                from repro.data.clickbench import load_into_engine
            load_into_engine(eng, db)
            engines[kind] = (eng, db)
        eng, db = engines[kind]
        try:
            with open(q["wire"], "rb") as f:
                blob = f.read()
            with open(q["ref"]) as f:
                ref = json.load(f)
            plan = ingest(blob)
            host_res = FallbackEngine(db).execute(plan)
            _assert_rows_equal(name + "[oracle]", host_res, ref)
            acc = eng.accelerate(blob)
            report = eng.last_accelerate_report
            assert report["device_rel_fraction"] == 1.0, \
                f"expected a fully device-resident plan, got {report}"
            _assert_rows_equal(name + "[engine]", acc.to_host(), ref)
            print(f"{name:>16}: ingested + executed row-exact on both "
                  f"engines ({report['device_fragments']} device fragment)")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name:>16}: FAIL — {type(e).__name__}: {e}")
    total = len(manifest["queries"])
    print(f"{total - failures}/{total} ingested wire plans row-exact "
          "on SiriusEngine.accelerate and the numpy oracle")
    return failures


def main(argv) -> int:
    if "--child" in argv:
        i = argv.index("--child")
        return 1 if run_child(argv[i + 1], "-v" in argv) else 0
    workload = "all"
    if "--workload" in argv:
        i = argv.index("--workload")
        if i + 1 >= len(argv):
            print("--workload requires a value: tpch|clickbench|all")
            return 2
        workload = argv[i + 1]
    if workload not in ("tpch", "clickbench", "all"):
        print(f"unknown workload {workload!r}: expected tpch|clickbench|all")
        return 2
    failures = run_parent(workload, "--update-golden" in argv,
                          "--no-exec" not in argv, "-v" in argv)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
