#!/usr/bin/env python
"""Distributed smoke: generic run_plan over a forced 4-device host mesh.

Must run in its own process (sets XLA_FLAGS before importing jax): forces
four host devices, builds the shard mesh, and runs a representative slice
of both workloads through exchange placement → fragment cutting →
shard_map collectives, checking row-exactness against the numpy oracle.

Run:  PYTHONPATH=src python scripts/distributed_smoke.py [--shards N]
                                  [--sf SF] [--trace-out OUT.json] [-v]
Exit status: 0 all queries match, 1 otherwise.  ``--trace-out`` dumps the
merged Chrome trace (all smoke queries, one tree each) for CI artifacts.
"""
from __future__ import annotations

import argparse
import os
import sys

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--shards", type=int, default=4)
ap.add_argument("--sf", type=float, default=0.004)
ap.add_argument("--trace-out", metavar="OUT.json",
                help="write the merged Chrome trace of every smoke query")
ap.add_argument("-v", "--verbose", action="store_true")
ARGS = ap.parse_args()

os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ARGS.shards}")

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.core.distributed import DistributedEngine  # noqa: E402
from repro.core.fallback import FallbackEngine  # noqa: E402
from repro.data import clickbench as cb  # noqa: E402
from repro.data.tpch import generate  # noqa: E402
from repro.data.tpch_queries import QUERIES  # noqa: E402
from repro.sql import sql_to_plan  # noqa: E402

TPCH_QIDS = (1, 3, 6, 12, 13, 18)       # agg, joins, exists/anti, group-top
CLICKBENCH_QIDS = ("q1", "q8", "q12")   # filter-count, distinct, string group
CB_ROWS = 2000


def canon(v):
    v = np.asarray(v)
    if v.dtype.kind == "M":
        return v.astype("datetime64[D]").astype("int64")
    if v.dtype.kind in "UO":
        return np.asarray(v, "U")
    return v


def tables_match(got, ref):
    if set(got) != set(ref):
        return False, f"columns {sorted(got)} vs {sorted(ref)}"
    for k in got:
        a, b = canon(got[k]), canon(ref[k])
        if len(a) != len(b):
            return False, f"{k}: rows {len(a)} vs {len(b)}"
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            if not np.allclose(a.astype(float), b.astype(float),
                               rtol=2e-5, atol=1e-6):
                return False, f"{k}: values"
        elif not (a == b).all():
            return False, f"{k}: values"
    return True, ""


def main() -> int:
    failures = []

    db = generate(ARGS.sf)
    fb = FallbackEngine(db)
    eng = DistributedEngine(db, n_shards=ARGS.shards)
    for qid in TPCH_QIDS:
        got = eng.run_plan(QUERIES[qid]())
        ref = fb.execute(QUERIES[qid]())
        ok, why = tables_match(got, ref)
        if ARGS.verbose or not ok:
            print(f"tpch q{qid}: {'ok' if ok else 'MISMATCH ' + why} "
                  f"({len(eng.program_names(qid))} fragments)")
        if not ok:
            failures.append(f"tpch q{qid}")

    cdb = cb.generate(CB_ROWS)
    cat = cb.clickbench_catalog(CB_ROWS)
    cfb = FallbackEngine(cdb)
    ceng = DistributedEngine(cdb, n_shards=ARGS.shards)
    for qid in CLICKBENCH_QIDS:
        plan = sql_to_plan(cb.CLICKBENCH_QUERIES[qid], catalog=cat)
        got = ceng.run_plan(plan)
        ref = cfb.execute(sql_to_plan(cb.CLICKBENCH_QUERIES[qid],
                                      catalog=cat))
        ok, why = tables_match(got, ref)
        if ARGS.verbose or not ok:
            print(f"clickbench {qid}: {'ok' if ok else 'MISMATCH ' + why}")
        if not ok:
            failures.append(f"clickbench {qid}")

    if ARGS.trace_out:
        import json

        from repro.observability.journal import JOURNAL, to_chrome
        out_dir = os.path.dirname(ARGS.trace_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(ARGS.trace_out, "w") as f:
            json.dump(to_chrome(JOURNAL.events(), epoch=JOURNAL.epoch), f)
        print(f"merged chrome trace ({len(JOURNAL.query_ids())} queries) "
              f"-> {ARGS.trace_out}")

    n = len(TPCH_QIDS) + len(CLICKBENCH_QIDS)
    if failures:
        print(f"FAIL: {len(failures)}/{n} distributed queries mismatched: "
              f"{failures}")
        return 1
    print(f"OK: {n} queries row-exact on a {ARGS.shards}-shard mesh")
    return 0


if __name__ == "__main__":
    sys.exit(main())
