#!/usr/bin/env python
"""Compare two EXPLAIN ANALYZE profile JSONs and name what moved.

    python scripts/profile_diff.py old.json new.json [--threshold 1.5]
                                                     [--min-delta-ms 2]

Inputs are either single ``QueryProfile`` JSON files (``profile.to_json()``,
CI smoke artifacts) or BENCH_*.json files whose ``queries`` entries embed a
``"profile"`` dict — in which case each query present in both files is
diffed.  An operator/phase **regresses** when it slowed by more than
``threshold``× AND by more than ``min-delta-ms`` wall milliseconds (both
gates, so microsecond-scale noise never fails a build).

Three additional BENCH-level gates (each applies only when the inputs
carry the data):

* kernel hits — a query whose ``kernel_hits.per_query`` device-kernel
  count drops to zero between the two files regresses (silent fallback);
* dispatch budgets — any query in the new file whose ``dispatch``
  telemetry shows more than one sync per warm query or nonzero host
  transfer bytes regresses (the paper's dispatch contract);
* distributed — per-query totals gated as above, plus a per-exchange
  skew table printed from the new file's ``distributed.queries.*.exchanges``.

Exit status: 0 clean, 1 regression(s) found, 2 usage/input error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.observability import diff_profiles, validate_profile  # noqa: E402


def _load_raw(path: str) -> dict:
    """→ whole BENCH dict ({} when unreadable or not an object)."""
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    return d if isinstance(d, dict) else {}


def _load_distributed(path: str) -> dict:
    """→ BENCH ``distributed`` section ({} when absent or not a BENCH file)."""
    sec = _load_raw(path).get("distributed")
    return sec if isinstance(sec, dict) else {}


def _diff_distributed(old: dict, new: dict, threshold: float,
                      min_delta_ms: float):
    """Gate the distributed per-query totals with the same two-sided rule
    as operator phases (ratio AND absolute wall-delta)."""
    regressions, report = [], []
    shared = sorted(set(old.get("queries", {})) & set(new.get("queries", {})))
    if old.get("shards") != new.get("shards") and shared:
        report.append(f"note: shard counts differ "
                      f"({old.get('shards')} vs {new.get('shards')}); "
                      "totals not compared")
        return regressions, report
    for q in shared:
        a = float(old["queries"][q].get("total", 0.0))
        b = float(new["queries"][q].get("total", 0.0))
        delta_ms = (b - a) * 1e3
        line = (f"distributed {q}: total {a*1e3:.1f} ms -> {b*1e3:.1f} ms")
        if a > 0 and b / a > threshold and delta_ms > min_delta_ms:
            regressions.append(q)
            line = "REGRESSION " + line + f" ({b/a:.2f}x)"
        report.append(line)
    return regressions, report


def _diff_kernel_hits(old_raw: dict, new_raw: dict):
    """Flag queries whose device-kernel coverage collapsed to zero.

    Compared only when BOTH BENCH files carry ``kernel_hits.per_query``.
    A query regresses when the old run had at least one non-fallback
    kernel hit and the new run has none — the tiered-kernel equivalent
    of silently falling back to the reference path."""
    regressions, report = [], []
    o = old_raw.get("kernel_hits", {}).get("per_query")
    n = new_raw.get("kernel_hits", {}).get("per_query")
    if not isinstance(o, dict) or not isinstance(n, dict):
        return regressions, report

    def hits(per_kernel: dict) -> int:
        return sum(int(v) for k, v in per_kernel.items()
                   if k != "fallback" and isinstance(v, (int, float)))

    for q in sorted(set(o) & set(n)):
        a, b = hits(o[q]), hits(n[q])
        if a > 0 and b == 0:
            regressions.append(q)
            report.append(f"REGRESSION kernel_hits {q}: {a} device kernel "
                          f"hit(s) -> 0 (fell back to reference path)")
    return regressions, report


def _render_skew_table(dist_new: dict) -> list:
    """Per-exchange skew table from the new BENCH distributed section
    (``queries.qN.exchanges`` rows embedded by bench_distributed)."""
    lines = []
    for q, entry in sorted(dist_new.get("queries", {}).items()):
        exchanges = entry.get("exchanges") if isinstance(entry, dict) else None
        if not isinstance(exchanges, list) or not exchanges:
            continue
        if not lines:
            lines.append(f"{'query':<6} {'fragment':<22} {'kind':<10} "
                         f"{'bytes':>12} {'skew':>6}")
        for ex in exchanges:
            bps = ex.get("bytes_per_shard", []) or []
            lines.append(f"{q:<6} {str(ex.get('fragment', '?')):<22} "
                         f"{str(ex.get('kind', '?')):<10} "
                         f"{int(sum(bps)):>12} "
                         f"{float(ex.get('skew_ratio', 1.0)):>6.2f}")
    if lines:
        lines.insert(0, "per-exchange skew (new file):")
    return lines


def _check_dispatch_budgets(new_raw: dict):
    """Hard budgets on the new file's per-query dispatch telemetry:
    more than one device sync per warm query, or any host transfer
    bytes inside the pipeline, breaks the paper's dispatch contract."""
    regressions, report = [], []
    for q, entry in sorted(new_raw.get("queries", {}).items()):
        disp = entry.get("dispatch") if isinstance(entry, dict) else None
        if not isinstance(disp, dict):
            continue
        syncs = float(disp.get("syncs_per_query", 0.0))
        xfer = float(disp.get("transfer_bytes_per_query", 0.0))
        if syncs > 1.0 + 1e-9:
            regressions.append(q)
            report.append(f"REGRESSION dispatch {q}: {syncs:g} syncs/query "
                          "(budget: 1)")
        if xfer > 0:
            regressions.append(q)
            report.append(f"REGRESSION dispatch {q}: {xfer:g} host transfer "
                          "bytes/query (budget: 0)")
    return regressions, report


def _load_profiles(path: str) -> dict:
    """→ {label: profile dict}.  Single-profile files get the label ''."""
    with open(path) as f:
        d = json.load(f)
    if not isinstance(d, dict):
        raise ValueError(f"{path}: expected a JSON object")
    if "schema_version" in d:
        errors = validate_profile(d)
        if errors:
            raise ValueError(f"{path}: invalid profile: " + "; ".join(errors))
        return {"": d}
    queries = d.get("queries")
    if not isinstance(queries, dict):
        raise ValueError(f"{path}: neither a QueryProfile JSON nor a "
                         "BENCH_*.json with a 'queries' map")
    out = {}
    for name, entry in sorted(queries.items()):
        prof = entry.get("profile") if isinstance(entry, dict) else None
        if prof is not None:
            errors = validate_profile(prof)
            if errors:
                raise ValueError(f"{path}: query {name!r} profile invalid: "
                                 + "; ".join(errors))
            out[name] = prof
    if not out:
        raise ValueError(f"{path}: no embedded profiles found (re-run the "
                         "benchmark with profile embedding enabled)")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline profile JSON (or BENCH_*.json)")
    ap.add_argument("new", help="candidate profile JSON (or BENCH_*.json)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="slowdown ratio gate (default 1.5x)")
    ap.add_argument("--min-delta-ms", type=float, default=2.0,
                    help="absolute wall-time gate in ms (default 2)")
    args = ap.parse_args(argv)

    try:
        old, new = _load_profiles(args.old), _load_profiles(args.new)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    shared = sorted(set(old) & set(new))
    if not shared:
        print("error: no queries in common between the two files",
              file=sys.stderr)
        return 2
    only_old, only_new = sorted(set(old) - set(new)), sorted(set(new) - set(old))
    if only_old:
        print(f"note: only in {args.old}: {only_old}")
    if only_new:
        print(f"note: only in {args.new}: {only_new}")

    any_regression = False
    for name in shared:
        regressions, report = diff_profiles(
            old[name], new[name], threshold=args.threshold,
            min_delta_s=args.min_delta_ms / 1e3)
        label = name or "query"
        if not report:
            print(f"{label}: no movement above "
                  f"{args.min_delta_ms:g} ms")
            continue
        print(f"{label}:")
        for line in report:
            print("  " + line)
        any_regression |= bool(regressions)

    # distributed BENCH entries: compared only when both files carry the
    # section (CI perf-smoke regenerates BENCH files without it)
    dist_old, dist_new = _load_distributed(args.old), _load_distributed(args.new)
    if dist_old and dist_new:
        regressions, report = _diff_distributed(
            dist_old, dist_new, args.threshold, args.min_delta_ms)
        for line in report:
            print(line)
        any_regression |= bool(regressions)
        for line in _render_skew_table(dist_new):
            print(line)

    old_raw, new_raw = _load_raw(args.old), _load_raw(args.new)
    regressions, report = _diff_kernel_hits(old_raw, new_raw)
    for line in report:
        print(line)
    any_regression |= bool(regressions)

    regressions, report = _check_dispatch_budgets(new_raw)
    for line in report:
        print(line)
    any_regression |= bool(regressions)

    if any_regression:
        print("\nFAIL: regressions found (see REGRESSION lines above)")
        return 1
    print("\nOK: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
