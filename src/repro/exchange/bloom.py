"""Predicate transfer via Bloom filters (beyond-paper optimization).

The paper lists predicate transfer [29,30] as future work for cutting
distributed shuffle volume; we implement it: before shuffling the probe side
of a distributed join, each shard builds a Bloom filter over its (already
filtered) build-side keys; the filters are OR-combined across shards with one
small collective (pmax on bit bytes), and probe rows that cannot match are
dropped *before* the all_to_all — directly attacking the collective roofline
term that dominates Q3 (paper Table 2).

False positives only cost wasted shuffle bytes (the join rejects them);
false negatives cannot occur.  Double hashing (h1 + i·h2) gives k probes
from two 64-bit mixes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MIX_A = -7046029254386353131          # golden ratio (build hash family)
MIX_B = -4417276706812531889          # splitmix64 constant


def _h2(keys: jnp.ndarray, mix: int) -> jnp.ndarray:
    h = keys.astype(jnp.int64) * jnp.int64(mix)
    h = h ^ (h >> 31)
    return h


def bloom_build(keys: jnp.ndarray, valid: jnp.ndarray, m_bits: int,
                k_hashes: int = 7) -> jnp.ndarray:
    """→ uint8[m_bits] local Bloom filter (1 byte per bit: pmax-combinable)."""
    h1 = _h2(keys, MIX_A)
    h2 = _h2(keys, MIX_B) | 1          # odd stride
    bits = jnp.zeros((m_bits,), jnp.uint8)
    for i in range(k_hashes):
        idx = ((h1 + i * h2) % m_bits + m_bits) % m_bits
        idx = jnp.where(valid, idx, m_bits)       # invalid rows dropped
        bits = bits.at[idx].max(jnp.uint8(1), mode="drop")
    return bits


def bloom_or_across(bits: jnp.ndarray, axes) -> jnp.ndarray:
    """OR-combine shard-local filters (pmax over the mesh axes)."""
    for ax in axes:
        bits = jax.lax.pmax(bits, ax)
    return bits


def bloom_maybe_contains(bits: jnp.ndarray, keys: jnp.ndarray,
                         k_hashes: int = 7) -> jnp.ndarray:
    """Conservative membership: True ⇒ maybe present, False ⇒ surely absent."""
    m_bits = bits.shape[0]
    h1 = _h2(keys, MIX_A)
    h2 = _h2(keys, MIX_B) | 1
    hit = jnp.ones(keys.shape, bool)
    for i in range(k_hashes):
        idx = ((h1 + i * h2) % m_bits + m_bits) % m_bits
        hit = hit & (jnp.take(bits, idx) > 0)
    return hit
