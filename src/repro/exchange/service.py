"""Exchange service layer (paper §3.2.4) on jax.lax collectives.

Exchange is modeled as dedicated physical operators — broadcast, shuffle,
merge, multicast — NCCL primitives in the paper, `shard_map` + `jax.lax`
collectives here (the TPU ICI schedule the roofline analysis reads).

Everything operates on **static-shape shard frames**: per-shard fixed-capacity
column arrays plus a validity mask (the TPU adaptation of dynamic row counts,
DESIGN.md §2).  These helpers are called *inside* a shard_map region; the
distributed executor owns the shard_map wrapper so whole fragments lower to
one XLA program (scan→filter→join→exchange→agg fuse into a single compiled
fragment — the paper's pipeline, compiled).

Overflow contract: shuffles write into fixed receive buckets; an overflow
counter is returned and checked by the coordinator (real engines size exchange
buffers the same way and repartition on overflow).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import compat

MIX64 = -7046029254386353131  # golden-ratio mix


@dataclasses.dataclass
class Frame:
    """Per-shard static-capacity columnar batch (used inside shard_map)."""

    columns: Dict[str, jnp.ndarray]   # each (cap, ...) — row-major leading dim
    valid: jnp.ndarray                # (cap,) bool

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def count(self) -> jnp.ndarray:
        return self.valid.sum()

    def with_mask(self, mask: jnp.ndarray) -> "Frame":
        return Frame(self.columns, self.valid & mask)

    def select(self, names) -> "Frame":
        return Frame({n: self.columns[n] for n in names}, self.valid)

    def with_columns(self, **cols) -> "Frame":
        out = dict(self.columns)
        out.update(cols)
        return Frame(out, self.valid)

    def take(self, idx: jnp.ndarray, taken_valid: jnp.ndarray) -> "Frame":
        return Frame({n: jnp.take(c, idx, axis=0)
                      for n, c in self.columns.items()}, taken_valid)


def partition_hash(keys: jnp.ndarray, n_parts: int) -> jnp.ndarray:
    h = keys.astype(jnp.int64) * MIX64
    h = (h >> 33) ^ h
    return (h % n_parts + n_parts).astype(jnp.int32) % n_parts


# ---------------------------------------------------------------------------
# exchange operators (call inside shard_map)
# ---------------------------------------------------------------------------


def shuffle(frame: Frame, keys: jnp.ndarray, axis: str, out_cap: int
            ) -> Tuple[Frame, jnp.ndarray]:
    """Hash-repartition rows by ``keys`` across the ``axis`` shards."""
    n = compat.axis_size(axis)
    dest = jnp.where(frame.valid, partition_hash(keys, n), n)
    return shuffle_by_dest(frame, dest, axis, out_cap)


def shuffle_hierarchical(frame: Frame, key_name: str, pod_axis: str,
                         data_axis: str, out_cap_pod: int, out_cap_data: int):
    """Pod-aware two-stage shuffle (beyond-paper, DESIGN.md §7).

    Rows first cross the inter-pod links bucketed by destination pod (few,
    large messages over the slow axis), then fan out intra-pod — cutting the
    per-link byte volume on the cross-pod dimension versus a flat all_to_all
    over pod×data shards.  ``key_name`` must be a frame column so the second
    stage can re-derive destinations after the first exchange.
    """
    p = compat.axis_size(pod_axis)
    d = compat.axis_size(data_axis)
    g = partition_hash(frame.columns[key_name], p * d)
    fr, ov1 = shuffle_by_dest(frame, g // d, pod_axis, out_cap_pod)
    g2 = partition_hash(fr.columns[key_name], p * d) % d
    fr2, ov2 = shuffle_by_dest(fr, g2, data_axis, out_cap_data)
    return fr2, ov1 + ov2


def shuffle_by_dest(frame: Frame, dest: jnp.ndarray, axis: str, out_cap: int
                    ) -> Tuple[Frame, jnp.ndarray]:
    """Repartition rows to explicit destinations over ``axis``.

    Per shard: rows are grouped by destination (stable argsort — the TPU
    compaction idiom), packed into (n_shards, out_cap) send buckets, exchanged
    with one `all_to_all`, and flattened into a (n_shards*out_cap,) frame.
    Returns (received frame, overflow count).  Invalid rows must carry
    dest >= n.
    """
    n = compat.axis_size(axis)
    cap = frame.capacity
    dest = jnp.where(frame.valid, dest, n)

    order = jnp.argsort(dest, stable=True)           # group rows by destination
    dest_sorted = jnp.take(dest, order)
    # position of each row within its destination group
    start = jnp.searchsorted(dest_sorted, jnp.arange(n + 1))
    pos_in_group = jnp.arange(cap) - jnp.take(start, dest_sorted)
    counts = start[1:] - start[:-1]                  # rows per destination (n+1 grp)
    overflow = jnp.maximum(counts[:n] - out_cap, 0).sum()

    in_bucket = (dest_sorted < n) & (pos_in_group < out_cap)
    slot = jnp.where(in_bucket, dest_sorted * out_cap + pos_in_group,
                     n * out_cap)                    # dumped past the end

    def scatter(col):
        src = jnp.take(col, order, axis=0)
        buf_shape = (n * out_cap + 1,) + col.shape[1:]
        buf = jnp.zeros(buf_shape, col.dtype).at[slot].set(
            src, mode="drop")
        return buf[:-1].reshape((n, out_cap) + col.shape[1:])

    sent_valid = jnp.zeros((n * out_cap + 1,), bool).at[slot].set(
        in_bucket, mode="drop")[:-1].reshape(n, out_cap)

    def exchange(buf):
        r = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                               tiled=False)
        return r.reshape((n * out_cap,) + r.shape[2:])

    recv_valid = exchange(sent_valid)
    recv_cols = {name: exchange(scatter(col))
                 for name, col in frame.columns.items()}
    return Frame(recv_cols, recv_valid), jax.lax.psum(overflow, axis)


def broadcast(frame: Frame, axis: str) -> Frame:
    """All shards receive every shard's rows (build-side replication)."""
    n = compat.axis_size(axis)
    cap = frame.capacity
    cols = {name: jax.lax.all_gather(col, axis, tiled=True)
            for name, col in frame.columns.items()}
    valid = jax.lax.all_gather(frame.valid, axis, tiled=True)
    return Frame(cols, valid)


def merge(frame: Frame, axis: str) -> Frame:
    """Gather all rows everywhere; the coordinator reads shard 0's copy.

    (With jax collectives a true root-only gather is an all_gather whose
    result is discarded on non-roots; XLA DCEs the unused copies.)
    """
    return broadcast(frame, axis)


def multicast(frame: Frame, axis: str, group_size: int) -> Frame:
    """Replicate rows within disjoint shard groups (paper's multi-cast)."""
    idx = jax.lax.axis_index(axis)
    n = compat.axis_size(axis)
    full = broadcast(frame, axis)
    cap = frame.capacity
    group = idx // group_size
    member_ids = group * group_size + jnp.arange(group_size)
    mask = jnp.zeros((n,), bool).at[member_ids].set(True)
    keep = jnp.repeat(mask, cap, total_repeat_length=n * cap)
    return Frame(full.columns, full.valid & keep)


def all_reduce_sum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    return jax.lax.psum(x, axis)


def compiled_shard_map(fn, mesh, in_specs, out_specs,
                       label: Optional[str] = None):
    """jit(shard_map(fn)) through the jax-version compat shim.

    The one wrapper the distributed executor uses for every collective
    step; replication checking stays off (exchange steps mix per-shard
    buffers with psum'd overflow scalars).

    With ``label``, every invocation journals a ``collective:<label>``
    span measuring the host-side **dispatch wall** (enqueue, not device
    completion — the caller's own barrier times that); spans are dropped
    outside a query context, so the label costs nothing standalone.
    """
    from ..core.compat import shard_map as _compat_shard_map
    from ..observability.journal import JOURNAL
    compiled = jax.jit(_compat_shard_map(fn, mesh, in_specs=in_specs,
                                         out_specs=out_specs))
    if label is None:
        return compiled

    def dispatch(*args):
        with JOURNAL.span(f"collective:{label}", "collective",
                          shards=len(mesh.devices.reshape(-1))):
            return compiled(*args)
    return dispatch
