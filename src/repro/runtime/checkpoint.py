"""Checkpoint/restore for the exchange registry and LM train state.

Format: one .npz per snapshot (atomic rename), holding flat arrays plus a
JSON manifest.  Registry snapshots store *compacted valid rows* with their
partition key, so restore can re-shard onto a different mesh size — this is
what makes elastic downsizing after a node failure possible (lineage-consistent
restart from the last completed fragment).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import numpy as np


def save_npz(path: str, arrays: Dict[str, np.ndarray],
             manifest: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    payload = dict(arrays)
    if manifest is not None:
        payload["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)
    np.savez(tmp, **payload)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_npz(path: str):
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files if k != "__manifest__"}
        manifest = None
        if "__manifest__" in z.files:
            manifest = json.loads(bytes(z["__manifest__"]).decode())
    return arrays, manifest


class RegistryCheckpointer:
    """Snapshots the exchange temp-table registry after each fragment."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, fragment: str) -> str:
        return os.path.join(self.directory, f"registry_{fragment}.npz")

    def save(self, fragment: str, registry: Dict[str, dict]) -> None:
        arrays = {}
        manifest = {"fragment": fragment, "tables": {}}
        for tname, entry in registry.items():
            manifest["tables"][tname] = {
                "partition_key": entry["partition_key"],
                "columns": list(entry["rows"].keys()),
            }
            for cname, arr in entry["rows"].items():
                key = f"{tname}::{cname}"
                a = np.asarray(arr)
                if a.dtype.kind == "O":
                    a = np.asarray(a, "U")   # npz stores unicode natively
                arrays[key] = a
        save_npz(self._path(fragment), arrays, manifest)

    def load_latest(self, fragments_in_order) -> Optional[tuple]:
        """→ (fragment_name, registry) for the newest existing snapshot."""
        for fragment in reversed(list(fragments_in_order)):
            p = self._path(fragment)
            if os.path.exists(p):
                arrays, manifest = load_npz(p)
                registry: Dict[str, dict] = {}
                for tname, meta in manifest["tables"].items():
                    rows = {c: arrays[f"{tname}::{c}"] for c in meta["columns"]}
                    registry[tname] = {"rows": rows,
                                       "partition_key": meta["partition_key"]}
                return manifest["fragment"], registry
        return None
