"""Distributed control plane (simulated): heartbeats, failures, stragglers.

The paper delegates the control plane to the host database's coordinator
(§3.2.1): liveness via heartbeat, fragment scheduling, partitioning decisions,
global metadata.  This module provides that substrate for our coordinator,
plus the fault-tolerance hooks the paper lists as future work (§3.4) — which
we implement: fragment retry, checkpoint/restart, elastic downsizing and
speculative straggler re-execution.

Hardware failures cannot occur in a CPU container, so failures/stragglers are
*injected* deterministically; the recovery machinery they exercise is real.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Set


class SimulatedNodeFailure(RuntimeError):
    def __init__(self, node: int, fragment: str):
        super().__init__(f"node {node} failed during fragment {fragment!r}")
        self.node = node
        self.fragment = fragment


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault schedule: fail `node` when `fragment` runs."""

    fragment: str
    node: int = 0
    times: int = 1            # how many executions of that fragment to kill
    delay_s: float = 0.0      # straggler injection instead of failure

    def is_failure(self) -> bool:
        return self.delay_s == 0.0


class FaultInjector:
    def __init__(self, plans: Optional[List[FaultPlan]] = None):
        self.plans = list(plans or [])
        self.tripped: List[str] = []

    def before_fragment(self, fragment: str) -> None:
        for p in self.plans:
            if p.fragment == fragment and p.times > 0 and p.is_failure():
                p.times -= 1
                self.tripped.append(fragment)
                raise SimulatedNodeFailure(p.node, fragment)

    def straggle(self, fragment: str) -> float:
        """Returns injected delay (seconds) for this fragment, if any."""
        for p in self.plans:
            if p.fragment == fragment and p.times > 0 and not p.is_failure():
                p.times -= 1
                self.tripped.append(fragment)
                return p.delay_s
        return 0.0


class HeartbeatMonitor:
    """Liveness registry for logical nodes (paper §3.2.1 'identify active
    nodes via heartbeat').  Nodes post beats; the failure detector marks a
    node dead after `timeout_s` of silence or an explicit kill."""

    def __init__(self, n_nodes: int, timeout_s: float = 5.0):
        self.timeout_s = timeout_s
        self.last_beat: Dict[int, float] = {i: time.monotonic()
                                            for i in range(n_nodes)}
        self.killed: Set[int] = set()
        self._lock = threading.Lock()

    def beat(self, node: int) -> None:
        with self._lock:
            if node not in self.killed:
                self.last_beat[node] = time.monotonic()

    def kill(self, node: int) -> None:
        with self._lock:
            self.killed.add(node)

    def revive_all(self) -> None:
        with self._lock:
            self.killed.clear()
            now = time.monotonic()
            for k in self.last_beat:
                self.last_beat[k] = now

    def live_nodes(self) -> List[int]:
        now = time.monotonic()
        with self._lock:
            return [n for n, t in self.last_beat.items()
                    if n not in self.killed and now - t < self.timeout_s]


class SpeculativeRunner:
    """Straggler mitigation: run the fragment; if it exceeds `budget_s`,
    launch a backup replica and take whichever finishes first (fragments are
    deterministic, so either result is valid)."""

    def __init__(self, budget_factor: float = 3.0, min_budget_s: float = 0.5):
        self.budget_factor = budget_factor
        self.min_budget_s = min_budget_s
        self.history: Dict[str, float] = {}
        self.speculated: List[str] = []

    def run(self, name: str, fn: Callable[[], object],
            injected_delay_s: float = 0.0,
            wrap: Optional[Callable[[str, Callable[[], object]], object]] = None):
        """``wrap``, when given, is called as ``wrap(who, fn)`` on the
        replica's own thread — the hook the coordinator uses to carry its
        journal trace context onto primary/backup threads (fragments run
        on spawned threads, so ambient thread-local context doesn't
        follow by itself)."""
        budget = max(self.min_budget_s,
                     self.budget_factor * self.history.get(name, 0.0))
        result: Dict[str, object] = {}
        done = threading.Event()

        def runner(who: str, delay: float):
            def go():
                if delay:
                    time.sleep(delay)
                try:
                    r = wrap(who, fn) if wrap is not None else fn()
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    if not done.is_set():
                        result.setdefault("error", e)
                        result.setdefault("who", who)
                        done.set()
                    return
                if not done.is_set():
                    result.setdefault("value", r)
                    result.setdefault("who", who)
                    done.set()
            return go

        t0 = time.monotonic()
        pthread = threading.Thread(target=runner("primary", injected_delay_s),
                                   daemon=True)
        pthread.start()
        pthread.join(timeout=budget)
        if not done.is_set():
            # primary is straggling → speculative backup (no injected delay)
            self.speculated.append(name)
            bthread = threading.Thread(target=runner("backup", 0.0),
                                       daemon=True)
            bthread.start()
            done.wait()
        elapsed = time.monotonic() - t0
        # track the non-straggling duration estimate
        self.history[name] = min(self.history.get(name, elapsed), elapsed)
        if "error" in result:
            # fragments are deterministic: first finisher's error is the
            # fragment's error (coordinator handles retry/elastic)
            raise result["error"]
        return result["value"], result.get("who", "primary")
