"""Buffer manager (paper §3.2.3).

Two regions:

* **caching region** — pre-sized budget holding base-table columns resident on
  device ("hot run" semantics of §4.1).  Insertion from the host format is the
  cold-run deep copy; eviction spills LRU tables back to pinned host memory
  (numpy here) and re-promotion is transparent.
* **processing region** — an accounting pool for intermediates (hash tables,
  join outputs).  XLA owns real allocation; the pool tracks bytes so queries
  can be admission-controlled and peak usage reported, mirroring the RMM pool.

Also owns columnar format conversion host<->device (Arrow-derived zero-copy in
the paper; an explicit `device_put` here).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import numpy as np

from ..observability.metrics import METRICS
from ..relational.table import Column, Table


class BufferError(RuntimeError):
    pass


class _CacheEntry:
    __slots__ = ("table", "nbytes", "last_used", "on_device", "host_copy", "meta")

    def __init__(self, table: Table, nbytes: int):
        self.table = table
        self.nbytes = nbytes
        self.last_used = time.monotonic()
        self.on_device = True
        self.host_copy: Optional[Dict[str, np.ndarray]] = None


class BufferManager:
    def __init__(self, caching_bytes: int = 8 << 30, processing_bytes: int = 8 << 30):
        self.caching_capacity = caching_bytes
        self.processing_capacity = processing_bytes
        self._cache: Dict[str, _CacheEntry] = {}
        # per-table write generation: bumped on every (re-)cache so the
        # executable-plan cache can detect that a recorded plan read data
        # that has since been replaced (see core.plan_cache)
        self.table_epochs: Dict[str, int] = {}
        self.caching_used = 0
        self.processing_used = 0
        self.processing_peak = 0
        self.spill_count = 0
        self.promote_count = 0
        # host<->device traffic ledger: after the cold-run deep copy, the
        # only legitimate crossings are spills/promotions — pipeline
        # execution itself must contribute nothing (see core.instrument)
        self.cold_copy_bytes = 0
        self.host_transfer_bytes = 0
        # hybrid-router fragment boundary traffic (substrait.router): tables
        # handed between device fragments and host-fallback fragments.
        # Pure-device plans must leave both at zero.
        self.boundary_to_host_bytes = 0
        self.boundary_to_device_bytes = 0

    # -- caching region -----------------------------------------------------
    def cache_table(self, name: str, table: Table) -> Table:
        """Cold-run load: deep-copy host columns into the device cache."""
        self.table_epochs[name] = self.table_epochs.get(name, 0) + 1
        nbytes = table.nbytes
        self._make_room(nbytes)
        dev = Table({
            n: Column(jax.device_put(c.data), c.kind, c.dictionary)
            for n, c in table.columns.items()
        })
        if name in self._cache:
            self.caching_used -= self._cache[name].nbytes
        self._cache[name] = _CacheEntry(dev, nbytes)
        self.caching_used += nbytes
        self.cold_copy_bytes += nbytes
        METRICS.counter("buffers.cold_copy_bytes").inc(nbytes)
        return dev

    def get(self, name: str) -> Table:
        e = self._cache.get(name)
        if e is None:
            raise BufferError(f"table {name!r} not cached")
        e.last_used = time.monotonic()
        if not e.on_device:
            self._promote(name, e)
        return e.table

    def has(self, name: str) -> bool:
        return name in self._cache

    def drop(self, name: str) -> None:
        e = self._cache.pop(name, None)
        if e and e.on_device:
            self.caching_used -= e.nbytes

    def _make_room(self, nbytes: int) -> None:
        if nbytes > self.caching_capacity:
            raise BufferError(
                f"table of {nbytes} bytes exceeds caching region "
                f"({self.caching_capacity})")
        while self.caching_used + nbytes > self.caching_capacity:
            victims = [(e.last_used, n) for n, e in self._cache.items() if e.on_device]
            if not victims:
                raise BufferError("caching region full and nothing to spill")
            _, victim = min(victims)
            self._spill(victim)

    def _spill(self, name: str) -> None:
        e = self._cache[name]
        e.host_copy = {
            n: np.asarray(c.data) for n, c in e.table.columns.items()
        }
        e.meta = {n: (c.kind, c.dictionary) for n, c in e.table.columns.items()}
        e.table = None  # release device refs
        e.on_device = False
        self.caching_used -= e.nbytes
        self.spill_count += 1
        self.host_transfer_bytes += e.nbytes
        METRICS.counter("buffers.spill_bytes").inc(e.nbytes)

    def _promote(self, name: str, e: _CacheEntry) -> None:
        self._make_room(e.nbytes)
        cols = {}
        for n, host in e.host_copy.items():
            kind, dictionary = e.meta[n]
            cols[n] = Column(jax.device_put(host), kind, dictionary)
        e.table = Table(cols)
        e.host_copy = None
        e.on_device = True
        self.caching_used += e.nbytes
        self.promote_count += 1
        self.host_transfer_bytes += e.nbytes
        METRICS.counter("buffers.promote_bytes").inc(e.nbytes)

    # -- hybrid fragment boundary accounting ----------------------------------
    def account_boundary_to_host(self, nbytes: int) -> None:
        """A device fragment's output crossed to a host fragment."""
        self.boundary_to_host_bytes += nbytes
        self.host_transfer_bytes += nbytes
        METRICS.counter("buffers.boundary_to_host_bytes").inc(nbytes)

    def account_boundary_to_device(self, nbytes: int) -> None:
        """A host fragment's output crossed back onto the device."""
        self.boundary_to_device_bytes += nbytes
        self.host_transfer_bytes += nbytes
        METRICS.counter("buffers.boundary_to_device_bytes").inc(nbytes)

    # -- processing region ----------------------------------------------------
    def alloc_processing(self, nbytes: int) -> None:
        if self.processing_used + nbytes > self.processing_capacity:
            raise BufferError(
                f"processing region overflow: {self.processing_used + nbytes} "
                f"> {self.processing_capacity}")
        self.processing_used += nbytes
        self.processing_peak = max(self.processing_peak, self.processing_used)
        METRICS.gauge("buffers.processing_used").set(self.processing_used)

    def free_processing(self, nbytes: int) -> None:
        self.processing_used = max(0, self.processing_used - nbytes)

    def watermarks(self) -> dict:
        """Host-side ledger sample the query journal attaches to each
        query span: enough to spot a transfer or memory-pressure
        regression per query without any device interaction (all plain
        ints — never triggers a sync)."""
        return dict(
            host_transfer_bytes=self.host_transfer_bytes,
            caching_used=self.caching_used,
            processing_peak=self.processing_peak,
        )

    def stats(self) -> dict:
        return dict(
            caching_used=self.caching_used,
            caching_capacity=self.caching_capacity,
            processing_peak=self.processing_peak,
            spills=self.spill_count,
            promotions=self.promote_count,
            cold_copy_bytes=self.cold_copy_bytes,
            host_transfer_bytes=self.host_transfer_bytes,
            boundary_to_host_bytes=self.boundary_to_host_bytes,
            boundary_to_device_bytes=self.boundary_to_device_bytes,
            cached_tables=sorted(self._cache),
        )
