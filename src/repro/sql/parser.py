"""Recursive-descent SQL parser.

Grammar (the TPC-H-sufficient subset demanded by the paper's drop-in claim):

  select    := SELECT [DISTINCT] items FROM tables [WHERE expr]
               [GROUP BY expr_list] [HAVING expr]
               [ORDER BY order_list] [LIMIT n]
  items     := '*' | item (',' item)*          item := expr [[AS] ident]
  tables    := table (',' table | [INNER|LEFT [OUTER]] JOIN table ON expr)*
  expr      := or_expr                          (precedence climbing below)

Expression precedence (loosest first): OR, AND, NOT, predicates
(comparison / BETWEEN / IN / LIKE / IS), additive, multiplicative, unary.
"""
from __future__ import annotations

from typing import List, Optional

from ..relational.expressions import (
    Between, BinOp, Case, Cast, DateLit, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp,
)
from .lexer import EOF, IDENT, KW, NUM, OP, STR, SqlError, Token, tokenize
from .nodes import (
    AGG_FUNCS, IntervalLit, OrderItem, SelectItem, SelectStmt, SqlCol,
    SqlExists, SqlFunc, SqlInSubquery, SqlSubquery, Star, TableRef,
)

_CMP_OPS = ("=", "<>", "!=", "<", "<=", ">", ">=")
_CAST_TYPES = {
    "double": "float64", "float": "float32", "real": "float32",
    "int": "int64", "integer": "int64", "bigint": "int64",
}


class Parser:
    def __init__(self, sql: str):
        self.sql = sql
        self.toks = tokenize(sql)
        self.i = 0

    # -- token plumbing ----------------------------------------------------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def advance(self) -> Token:
        t = self.cur
        self.i += 1
        return t

    def accept_kw(self, *names: str) -> bool:
        if self.cur.is_kw(*names):
            self.i += 1
            return True
        return False

    def accept_op(self, *ops: str) -> bool:
        if self.cur.is_op(*ops):
            self.i += 1
            return True
        return False

    def expect_kw(self, name: str) -> None:
        if not self.accept_kw(name):
            self.error(f"expected {name.upper()}")

    def expect_op(self, op: str) -> None:
        if not self.accept_op(op):
            self.error(f"expected {op!r}")

    def expect_ident(self) -> str:
        if self.cur.kind != IDENT:
            self.error("expected identifier")
        return self.advance().value

    def error(self, msg: str):
        got = self.cur.value if self.cur.kind != EOF else "<end of input>"
        raise SqlError(f"{msg}, got {got!r}", self.sql, self.cur.pos)

    # -- statement ---------------------------------------------------------
    def parse(self) -> SelectStmt:
        stmt = self.parse_select()
        self.accept_op(";")
        if self.cur.kind != EOF:
            self.error("trailing input after statement")
        return stmt

    def parse_select(self) -> SelectStmt:
        self.expect_kw("select")
        distinct = self.accept_kw("distinct")
        items = self.parse_items()
        self.expect_kw("from")
        tables, join_conds, left_joins = self.parse_tables()
        where = self.parse_expr() if self.accept_kw("where") else None
        for cond in join_conds:       # JOIN ... ON conditions fold into WHERE
            where = cond if where is None else BinOp("and", where, cond)
        group_by: List[Expr] = []
        if self.accept_kw("group"):
            self.expect_kw("by")
            group_by.append(self.parse_expr())
            while self.accept_op(","):
                group_by.append(self.parse_expr())
        having = self.parse_expr() if self.accept_kw("having") else None
        order_by: List[OrderItem] = []
        if self.accept_kw("order"):
            self.expect_kw("by")
            order_by.append(self.parse_order_item())
            while self.accept_op(","):
                order_by.append(self.parse_order_item())
        limit = None
        if self.accept_kw("limit"):
            if self.cur.kind != NUM or not isinstance(self.cur.value, int):
                self.error("LIMIT expects an integer")
            limit = self.advance().value
        return SelectStmt(items, tables, where, group_by, having, order_by,
                          limit, distinct, left_joins)

    def parse_items(self) -> List[SelectItem]:
        if self.accept_op("*"):
            return [SelectItem(Star())]
        items = [self.parse_item()]
        while self.accept_op(","):
            items.append(self.parse_item())
        return items

    def parse_item(self) -> SelectItem:
        e = self.parse_expr()
        alias = None
        if self.accept_kw("as"):
            alias = self.expect_ident()
        elif self.cur.kind == IDENT:
            alias = self.advance().value
        return SelectItem(e, alias)

    def parse_tables(self):
        """→ (tables, inner-join ON conds, [(left-join table, ON cond)])."""
        tables = [self.parse_table_ref()]
        join_conds: List[Expr] = []
        left_joins = []
        while True:
            if self.accept_op(","):
                tables.append(self.parse_table_ref())
                continue
            if self.cur.is_kw("join", "inner", "left"):
                if self.accept_kw("left"):
                    self.accept_kw("outer")
                    self.expect_kw("join")
                    t = self.parse_table_ref()
                    self.expect_kw("on")
                    left_joins.append((t, self.parse_expr()))
                    continue
                self.accept_kw("inner")
                self.expect_kw("join")
                tables.append(self.parse_table_ref())
                self.expect_kw("on")
                join_conds.append(self.parse_expr())
                continue
            return tables, join_conds, left_joins

    def parse_table_ref(self) -> TableRef:
        if self.cur.is_op("("):
            self.advance()
            if not self.cur.is_kw("select"):
                self.error("expected SELECT in derived table")
            sub = self.parse_select()
            self.expect_op(")")
            self.accept_kw("as")
            if self.cur.kind != IDENT:
                self.error("derived table requires an alias")
            alias = self.advance().value
            return TableRef(alias, alias, subquery=sub)
        name = self.expect_ident()
        alias = None
        if self.accept_kw("as"):
            alias = self.expect_ident()
        elif self.cur.kind == IDENT:
            alias = self.advance().value
        return TableRef(name, alias)

    def parse_order_item(self) -> OrderItem:
        e = self.parse_expr()
        asc = True
        if self.accept_kw("desc"):
            asc = False
        else:
            self.accept_kw("asc")
        return OrderItem(e, asc)

    # -- expressions -------------------------------------------------------
    def parse_expr(self) -> Expr:
        return self.parse_or()

    def parse_or(self) -> Expr:
        e = self.parse_and()
        while self.accept_kw("or"):
            e = BinOp("or", e, self.parse_and())
        return e

    def parse_and(self) -> Expr:
        e = self.parse_not()
        while self.accept_kw("and"):
            e = BinOp("and", e, self.parse_not())
        return e

    def parse_not(self) -> Expr:
        if self.accept_kw("not"):
            inner = self.parse_not()
            if isinstance(inner, SqlExists):
                inner.negate = not inner.negate
                return inner
            return UnOp("not", inner)
        return self.parse_predicate()

    def parse_predicate(self) -> Expr:
        e = self.parse_additive()
        negate = False
        if self.cur.is_kw("not"):
            nxt = self.toks[self.i + 1]
            if nxt.is_kw("between", "in", "like"):
                self.advance()
                negate = True
        if self.accept_kw("between"):
            lo = self.parse_additive()
            self.expect_kw("and")
            hi = self.parse_additive()
            out: Expr = Between(e, lo, hi)
            return UnOp("not", out) if negate else out
        if self.accept_kw("in"):
            self.expect_op("(")
            if self.cur.is_kw("select"):
                sub = self.parse_select()
                self.expect_op(")")
                return SqlInSubquery(e, sub, negate)
            values = [self.parse_literal_value()]
            while self.accept_op(","):
                values.append(self.parse_literal_value())
            self.expect_op(")")
            return InList(e, values, negate)
        if self.accept_kw("like"):
            if self.cur.kind != STR:
                self.error("LIKE expects a string literal pattern")
            return Like(e, self.advance().value, negate)
        if negate:
            self.error("expected BETWEEN / IN / LIKE after NOT")
        if self.accept_kw("is"):
            neg = self.accept_kw("not")
            self.expect_kw("null")
            # engine has no NULLs: IS NULL is constant false, IS NOT NULL true
            return Lit(bool(neg))
        for op in _CMP_OPS:
            if self.accept_op(op):
                rhs = self.parse_additive()
                canon = {"=": "==", "<>": "!="}.get(op, op)
                return BinOp(canon, e, rhs)
        return e

    def parse_literal_value(self):
        neg = self.accept_op("-")
        t = self.cur
        if t.kind == NUM:
            self.advance()
            return -t.value if neg else t.value
        if t.kind == STR and not neg:
            self.advance()
            return t.value
        self.error("expected literal in IN list")

    def parse_additive(self) -> Expr:
        e = self.parse_multiplicative()
        while True:
            if self.accept_op("+"):
                e = BinOp("+", e, self.parse_multiplicative())
            elif self.accept_op("-"):
                e = BinOp("-", e, self.parse_multiplicative())
            else:
                return e

    def parse_multiplicative(self) -> Expr:
        e = self.parse_unary()
        while True:
            if self.accept_op("*"):
                e = BinOp("*", e, self.parse_unary())
            elif self.accept_op("/"):
                e = BinOp("/", e, self.parse_unary())
            else:
                return e

    def parse_unary(self) -> Expr:
        if self.accept_op("-"):
            inner = self.parse_unary()
            if isinstance(inner, Lit) and inner.kind is None:
                return Lit(-inner.value)
            return UnOp("-", inner)
        self.accept_op("+")
        return self.parse_primary()

    # -- primaries ---------------------------------------------------------
    def parse_primary(self) -> Expr:
        t = self.cur
        if t.kind == NUM:
            self.advance()
            return Lit(t.value)
        if t.kind == STR:
            self.advance()
            return Lit(t.value)
        if t.is_kw("true"):
            self.advance()
            return Lit(True)
        if t.is_kw("false"):
            self.advance()
            return Lit(False)
        if t.is_kw("date"):
            self.advance()
            if self.cur.kind != STR:
                self.error("DATE expects a 'yyyy-mm-dd' string")
            return DateLit(self.advance().value)
        if t.is_kw("interval"):
            self.advance()
            if self.cur.kind != STR:
                self.error("INTERVAL expects a quoted amount")
            amount = int(self.advance().value)
            if not self.cur.is_kw("year", "month", "day"):
                self.error("INTERVAL unit must be YEAR, MONTH or DAY")
            return IntervalLit(amount, self.advance().value)
        if t.is_kw("case"):
            return self.parse_case()
        if t.is_kw("exists"):
            self.advance()
            self.expect_op("(")
            sub = self.parse_select()
            self.expect_op(")")
            return SqlExists(sub)
        if t.is_kw("extract"):
            self.advance()
            self.expect_op("(")
            if not self.accept_kw("year"):
                self.error("only EXTRACT(YEAR FROM ...) is supported")
            self.expect_kw("from")
            e = self.parse_expr()
            self.expect_op(")")
            return ExtractYear(e)
        if t.is_kw("substring"):
            self.advance()
            self.expect_op("(")
            e = self.parse_expr()
            if self.accept_kw("from"):
                start = self.parse_int("SUBSTRING start")
                self.expect_kw("for")
                length = self.parse_int("SUBSTRING length")
            else:
                self.expect_op(",")
                start = self.parse_int("SUBSTRING start")
                self.expect_op(",")
                length = self.parse_int("SUBSTRING length")
            self.expect_op(")")
            return Substr(e, start, length)
        if t.is_kw("cast"):
            self.advance()
            self.expect_op("(")
            e = self.parse_expr()
            self.expect_kw("as")
            tyname = self.expect_ident() if self.cur.kind == IDENT else None
            if tyname not in _CAST_TYPES:
                self.error(f"unsupported CAST target {tyname!r}")
            self.expect_op(")")
            return Cast(e, _CAST_TYPES[tyname])
        if t.is_op("("):
            self.advance()
            if self.cur.is_kw("select"):
                sub = self.parse_select()
                self.expect_op(")")
                return SqlSubquery(sub)
            e = self.parse_expr()
            self.expect_op(")")
            return e
        if t.kind == IDENT:
            name = self.advance().value
            if self.cur.is_op("("):                      # function call
                return self.parse_func(name)
            if self.accept_op("."):
                col = self.expect_ident()
                return SqlCol(name, col)
            return SqlCol(None, name)
        self.error("expected expression")

    def parse_int(self, what: str) -> int:
        if self.cur.kind != NUM or not isinstance(self.cur.value, int):
            self.error(f"{what} must be an integer literal")
        return self.advance().value

    def parse_func(self, name: str) -> Expr:
        if name == "starts_with":
            # starts_with(string_expr, 'prefix'): prefix predicate — lowers
            # to a contiguous code-range compare on the sorted dictionary
            self.expect_op("(")
            e = self.parse_expr()
            self.expect_op(",")
            if self.cur.kind != STR:
                self.error("starts_with expects a string literal prefix")
            prefix = self.advance().value
            self.expect_op(")")
            return StartsWith(e, prefix)
        if name == "substr":
            self.expect_op("(")
            e = self.parse_expr()
            self.expect_op(",")
            start = self.parse_int("substr start")
            self.expect_op(",")
            length = self.parse_int("substr length")
            self.expect_op(")")
            return Substr(e, start, length)
        if name not in AGG_FUNCS:
            self.error(f"unknown function {name!r}")
        self.expect_op("(")
        if name == "count" and self.accept_op("*"):
            self.expect_op(")")
            return SqlFunc("count", None)
        distinct = self.accept_kw("distinct")
        arg = self.parse_expr()
        self.expect_op(")")
        return SqlFunc(name, arg, distinct)

    def parse_case(self) -> Expr:
        self.expect_kw("case")
        whens = []
        while self.accept_kw("when"):
            cond = self.parse_expr()
            self.expect_kw("then")
            whens.append((cond, self.parse_expr()))
        if not whens:
            self.error("CASE requires at least one WHEN")
        default: Expr = Lit(0)
        if self.accept_kw("else"):
            default = self.parse_expr()
        self.expect_kw("end")
        return Case(whens, default)


def parse_sql(sql: str) -> SelectStmt:
    return Parser(sql).parse()
