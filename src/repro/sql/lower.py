"""AST → plan-IR lowering (the "standard plan" half of the drop-in pipeline).

Produces a deliberately *naive* plan — full-width table scans, the join tree
in FROM/connectivity order, and every non-join predicate in one FilterRel
above the joins — so that the rule-based optimizer (repro.optimizer) is the
component that earns predicate pushdown, projection pruning, join ordering
and build-side selection, exactly as DuckDB's optimizer does in front of
Sirius.

Subquery handling mirrors the rewrites DuckDB applies before emitting
Substrait:
  * ``x IN (SELECT ...)``     → semi join   (NOT IN → anti join)
  * ``EXISTS (SELECT ...)``   → semi join on the correlated equality keys
    (NOT EXISTS → anti join); only equality correlation is supported,
  * uncorrelated scalar subqueries → ``ScalarSubquery`` nodes, executed
    first by the engine and bound as literals.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Set, Tuple

from ..core.plan import (
    AggregateRel, FetchRel, FilterRel, JoinRel, ProjectRel, ReadRel, Rel,
    ScalarSubquery, SortRel,
)
from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    BinOp, Col, Expr, and_all, expr_equal, split_conjuncts, transform_expr,
    walk_expr,
)
from ..relational.sort import SortKey
from .binder import Catalog, DEFAULT_CATALOG, Scope, bind_expr
from .lexer import SqlError
from .nodes import (
    OrderItem, OuterCol, SelectItem, SelectStmt, SqlCol, SqlExists, SqlFunc,
    SqlInSubquery, SqlSubquery, Star,
)

_AGG_FN_MAP = {"sum": "sum", "avg": "avg", "min": "min", "max": "max",
               "count": "count"}


def _contains(e: Expr, types) -> bool:
    return any(isinstance(n, types) for n in walk_expr(e))


def _cols_of(e: Expr) -> List[str]:
    return [n.name for n in walk_expr(e) if isinstance(n, Col)]


def _outer_cols_of(e: Expr) -> List[str]:
    return [n.name for n in walk_expr(e) if isinstance(n, OuterCol)]


class _Lowering:
    def __init__(self, catalog: Catalog):
        self.catalog = catalog
        self._names = itertools.count()

    def fresh(self, prefix: str) -> str:
        return f"__{prefix}{next(self._names)}"

    # ------------------------------------------------------------------
    def lower(self, stmt: SelectStmt, outer: Optional[Scope] = None,
              for_exists: bool = False):
        """→ (plan, output column names, correlations).

        ``correlations`` is a list of (outer_col, inner_col) equality pairs
        extracted from the WHERE clause; non-empty only when ``outer`` is
        given and the subquery is correlated.
        """
        scope = Scope(self.catalog, stmt.from_tables, parent=outer)

        where = bind_expr(stmt.where, scope) if stmt.where is not None \
            else None
        conjuncts = split_conjuncts(where)

        correlations: List[Tuple[str, str]] = []
        plain: List[Expr] = []
        sub_joins: List[Expr] = []       # IN/EXISTS subquery conjuncts
        for c in conjuncts:
            if isinstance(c, (SqlExists, SqlInSubquery)):
                sub_joins.append(c)
                continue
            outer_refs = _outer_cols_of(c)
            if outer_refs:
                pair = self._correlation_pair(c)
                if pair is None:
                    raise SqlError(
                        "only equality correlation (inner_col = outer_col) "
                        "is supported in subqueries")
                correlations.append(pair)
                continue
            plain.append(self._lower_scalar_subqueries(c, scope))

        # -- join tree over the FROM tables -----------------------------
        plan, available = self._join_tree(stmt.from_tables, plain, scope)

        # -- IN / EXISTS subqueries → semi/anti joins --------------------
        for c in sub_joins:
            plan = self._lower_sub_join(plan, c, scope)

        # -- residual predicates (single FilterRel; optimizer pushes) ----
        residual = and_all(plain)
        if residual is not None:
            plan = FilterRel(plan, residual)

        if for_exists:
            return plan, list(available), correlations

        # -- select items / aggregation ----------------------------------
        items = self._expand_items(stmt.items, available)
        bound_items = [SelectItem(bind_expr(it.expr, scope), it.alias)
                       for it in items]
        alias_map = {it.alias: it.expr for it in bound_items if it.alias}

        group_exprs = self._bind_group_by(stmt.group_by, scope, alias_map)
        has_agg = bool(group_exprs) or any(
            _contains(it.expr, SqlFunc) for it in bound_items)
        having = None
        if stmt.having is not None:
            having = bind_expr(stmt.having, scope)
            having = self._subst_aliases(having, alias_map)
            has_agg = True

        out_names: List[str] = []
        out_exprs: List[Tuple[str, Expr]] = []

        if has_agg:
            plan, key_names, rewrite = self._build_aggregate(
                plan, group_exprs, bound_items, having, scope)
            for i, it in enumerate(bound_items):
                name = it.alias or self._default_name(it.expr, i)
                out_exprs.append((name, rewrite(it.expr)))
                out_names.append(name)
        else:
            for i, it in enumerate(bound_items):
                e = self._lower_scalar_subqueries(it.expr, scope)
                name = it.alias or self._default_name(e, i)
                out_exprs.append((name, e))
                out_names.append(name)

        if len(set(out_names)) != len(out_names):
            raise SqlError(f"duplicate output column names: {out_names}")
        plan = ProjectRel(plan, out_exprs)

        if stmt.distinct:
            plan = AggregateRel(plan, list(out_names), [])

        # -- order by / limit --------------------------------------------
        if stmt.order_by:
            keys = [self._sort_key(o, out_exprs, scope) for o in stmt.order_by]
            plan = SortRel(plan, keys, limit=stmt.limit)
        elif stmt.limit is not None:
            plan = FetchRel(plan, stmt.limit)

        return plan, out_names, correlations

    # ------------------------------------------------------------------
    def _correlation_pair(self, c: Expr) -> Optional[Tuple[str, str]]:
        if isinstance(c, BinOp) and c.op == "==":
            l, r = c.left, c.right
            if isinstance(l, Col) and isinstance(r, OuterCol):
                return (r.name, l.name)
            if isinstance(l, OuterCol) and isinstance(r, Col):
                return (l.name, r.name)
        return None

    def _lower_scalar_subqueries(self, e: Expr, scope: Scope) -> Expr:
        def visit(node: Expr) -> Expr:
            if isinstance(node, SqlSubquery):
                plan, cols, corr = self.lower(node.select, outer=scope)
                if corr:
                    raise SqlError(
                        "correlated scalar subqueries are not supported")
                if len(cols) != 1:
                    raise SqlError(
                        "scalar subquery must produce exactly one column")
                return ScalarSubquery(plan, cols[0])
            return node
        return transform_expr(e, visit)

    def _join_tree(self, tables, plain: List[Expr], scope: Scope):
        """Greedy connectivity join over the FROM list.  Consumes the
        cross-table equality conjuncts from ``plain``."""
        def table_cols(name: str) -> Set[str]:
            return set(self.catalog.columns(name))

        def is_equi(c: Expr) -> Optional[Tuple[str, str]]:
            if isinstance(c, BinOp) and c.op == "==" \
                    and isinstance(c.left, Col) and isinstance(c.right, Col):
                lt = scope.col_table.get(c.left.name)
                rt = scope.col_table.get(c.right.name)
                if lt and rt and lt != rt:
                    return (c.left.name, c.right.name)
            return None

        # NB: never use list.remove / `in` on Expr lists — Expr.__eq__ builds
        # a BinOp (truthy), so equality-based removal hits the wrong element
        equi: List[Tuple[Expr, str, str]] = []
        rest: List[Expr] = []
        for c in plain:
            pair = is_equi(c)
            if pair is not None:
                equi.append((c, *pair))
            else:
                rest.append(c)
        plain[:] = rest

        plan: Rel = ReadRel(tables[0].name)
        available = table_cols(tables[0].name)
        remaining = list(tables[1:])
        while remaining:
            picked = None
            for t in remaining:
                tcols = table_cols(t.name)
                keys = [(a, b) if a in available else (b, a)
                        for _, a, b in equi
                        if (a in available and b in tcols)
                        or (b in available and a in tcols)]
                if keys:
                    picked = (t, keys)
                    break
            if picked is None:
                raise SqlError(
                    f"disconnected join graph: no equality predicate links "
                    f"{[t.name for t in remaining]} to the joined tables "
                    "(cross joins are not supported)")
            t, keys = picked
            probe_keys = [k[0] for k in keys]
            build_keys = [k[1] for k in keys]
            plan = JoinRel(plan, ReadRel(t.name), probe_keys, build_keys,
                           "inner")
            available |= table_cols(t.name)
            used = {(a, b) for a, b in zip(probe_keys, build_keys)}
            equi = [e for e in equi
                    if (e[1], e[2]) not in used and (e[2], e[1]) not in used]
            remaining.remove(t)
        # equality conjuncts that never linked a new table (both sides were
        # already available) stay as residual filters
        plain.extend(c for c, _a, _b in equi)
        return plan, available

    def _lower_sub_join(self, plan: Rel, c: Expr, scope: Scope) -> Rel:
        if isinstance(c, SqlInSubquery):
            operand = bind_expr(c.operand, scope)
            if not isinstance(operand, Col):
                raise SqlError("IN (SELECT ...) requires a plain column on "
                               "the left-hand side")
            sub_plan, sub_cols, corr = self.lower(c.select, outer=scope)
            if corr:
                raise SqlError("correlated IN subqueries are not supported")
            if len(sub_cols) != 1:
                raise SqlError("IN subquery must produce exactly one column")
            how = "anti" if c.negate else "semi"
            return JoinRel(plan, sub_plan, [operand.name], [sub_cols[0]], how)
        assert isinstance(c, SqlExists)
        sub_plan, _cols, corr = self.lower(c.select, outer=scope,
                                           for_exists=True)
        if not corr:
            raise SqlError("EXISTS subquery must be correlated with the "
                           "outer query through an equality predicate")
        probe_keys = [outer for outer, _ in corr]
        build_keys = [inner for _, inner in corr]
        how = "anti" if c.negate else "semi"
        return JoinRel(plan, sub_plan, probe_keys, build_keys, how)

    # ------------------------------------------------------------------
    def _expand_items(self, items: List[SelectItem], available: Set[str]):
        out = []
        for it in items:
            if isinstance(it.expr, Star):
                out.extend(SelectItem(SqlCol(None, c)) for c in
                           sorted(available))
            else:
                out.append(it)
        return out

    def _bind_group_by(self, group_by, scope: Scope, alias_map):
        """→ list of (key_name, bound_expr)."""
        out: List[Tuple[str, Expr]] = []
        for i, g in enumerate(group_by):
            alias_name = None
            if isinstance(g, SqlCol) and g.qualifier is None \
                    and g.name in alias_map:
                alias_name = g.name
                bound = alias_map[g.name]
            else:
                bound = bind_expr(g, scope)
            if isinstance(bound, Col):
                out.append((bound.name, bound))
                continue
            # expression key: name it after the select alias when one matches
            name = alias_name
            if name is None:
                for a, e in alias_map.items():
                    if expr_equal(e, bound):
                        name = a
                        break
            out.append((name or self.fresh("key"), bound))
        return out

    def _subst_aliases(self, e: Expr, alias_map) -> Expr:
        def visit(node: Expr) -> Expr:
            if isinstance(node, SqlCol) and node.qualifier is None \
                    and node.name in alias_map:
                return alias_map[node.name]
            return node
        return transform_expr(e, visit)

    def _default_name(self, e: Expr, i: int) -> str:
        if isinstance(e, Col):
            return e.name
        return f"col{i}"

    def _build_aggregate(self, plan: Rel, group_exprs, bound_items,
                         having, scope: Scope):
        """Insert (pre-projection?) + AggregateRel; returns a rewriter that
        maps post-aggregation expressions onto the aggregate's output."""
        # pre-projection for expression-valued group keys
        pre: List[Tuple[str, Expr]] = []
        key_names: List[str] = []
        for name, e in group_exprs:
            key_names.append(name)
            if not isinstance(e, Col):
                pre.append((name, e))
        if pre:
            plan = ProjectRel(plan, pre, keep_input=True)

        aggs: List[AggSpec] = []

        def agg_name_for(fn_node: SqlFunc, preferred: Optional[str]) -> str:
            fn = _AGG_FN_MAP[fn_node.name]
            if fn_node.name == "count" and fn_node.arg is None:
                fn = "count_star"
            elif fn_node.name == "count" and fn_node.distinct:
                fn = "count_distinct"
            arg = None
            if fn_node.arg is not None:
                arg = self._lower_scalar_subqueries(fn_node.arg, scope)
            for spec in aggs:
                if spec.fn == fn and expr_equal(spec.expr, arg):
                    return spec.name
            name = preferred or self.fresh("agg")
            if any(a.name == name for a in aggs):
                name = self.fresh("agg")
            aggs.append(AggSpec(fn, arg, name))
            return name

        # seed the agg list from the select items so single-agg items keep
        # their SQL alias as the aggregate's output name
        for it in bound_items:
            if isinstance(it.expr, SqlFunc) and it.alias:
                agg_name_for(it.expr, it.alias)

        rewritten_having = None
        if having is not None:
            rewritten_having = self._rewrite_post_agg(
                having, group_exprs, agg_name_for, None)
            # alias refs to agg outputs: SqlCol(alias) already substituted by
            # _subst_aliases; plain Col refs to agg names pass through
            bad = [c for c in _cols_of(rewritten_having)
                   if c not in key_names
                   and not any(a.name == c for a in aggs)]
            if bad:
                raise SqlError(f"HAVING references non-aggregated columns "
                               f"{bad}")
            rewritten_having = self._lower_scalar_subqueries(
                rewritten_having, scope)

        agg_rel = AggregateRel(plan, key_names, aggs, having=rewritten_having)

        def rewrite(e: Expr) -> Expr:
            out = self._rewrite_post_agg(e, group_exprs, agg_name_for, None)
            out = self._lower_scalar_subqueries(out, scope)
            bad = [c for c in _cols_of(out)
                   if c not in key_names
                   and not any(a.name == c for a in agg_rel.aggs)]
            if bad:
                raise SqlError(
                    f"column(s) {bad} must appear in GROUP BY or inside an "
                    "aggregate function")
            return out

        return agg_rel, key_names, rewrite

    def _rewrite_post_agg(self, e: Expr, group_exprs, agg_name_for,
                          preferred):
        """Top-down: SqlFunc subtrees → Col(agg name); group-key-matching
        subtrees → Col(key name)."""
        if isinstance(e, SqlFunc):
            return Col(agg_name_for(e, preferred))
        for name, ge in group_exprs:
            if expr_equal(e, ge):
                return Col(name)
        if not dataclasses.is_dataclass(e):
            return e
        changes = {}
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, Expr):
                nv = self._rewrite_post_agg(v, group_exprs, agg_name_for,
                                            None)
                if nv is not v:
                    changes[f.name] = nv
            elif isinstance(v, (list, tuple)) and not isinstance(v, str):
                new_items, dirty = [], False
                for item in v:
                    if isinstance(item, Expr):
                        ni = self._rewrite_post_agg(item, group_exprs,
                                                    agg_name_for, None)
                        dirty |= ni is not item
                        new_items.append(ni)
                    elif isinstance(item, tuple):
                        ni = tuple(
                            self._rewrite_post_agg(x, group_exprs,
                                                   agg_name_for, None)
                            if isinstance(x, Expr) else x for x in item)
                        dirty |= any(a is not b for a, b in zip(ni, item))
                        new_items.append(ni)
                    else:
                        new_items.append(item)
                if dirty:
                    changes[f.name] = new_items
        return dataclasses.replace(e, **changes) if changes else e

    def _sort_key(self, o: OrderItem, out_exprs, scope: Scope) -> SortKey:
        e = o.expr
        # a bare identifier naming an output column (alias or plain column)
        if isinstance(e, SqlCol) and e.qualifier is None:
            for name, _ in out_exprs:
                if name == e.name:
                    return SortKey(name, o.ascending)
        bound = bind_expr(e, scope)
        if isinstance(bound, Col):
            for name, oe in out_exprs:
                if isinstance(oe, Col) and oe.name == bound.name \
                        or name == bound.name:
                    return SortKey(name, o.ascending)
        for name, oe in out_exprs:
            if expr_equal(oe, bound):
                return SortKey(name, o.ascending)
        raise SqlError(
            "ORDER BY must reference an output column of the SELECT list")


def lower_select(stmt: SelectStmt, catalog: Optional[Catalog] = None) -> Rel:
    """Lower a bound SELECT statement to a (naive, unoptimized) plan."""
    catalog = catalog or DEFAULT_CATALOG
    plan, _cols, corr = _Lowering(catalog).lower(stmt)
    assert not corr
    return plan
