"""AST → plan-IR lowering (the "standard plan" half of the drop-in pipeline).

Produces a deliberately *naive* plan — full-width table scans, the join tree
in FROM/connectivity order, and every non-join predicate in one FilterRel
above the joins — so that the rule-based optimizer (repro.optimizer) is the
component that earns predicate pushdown, projection pruning, join ordering
and build-side selection, exactly as DuckDB's optimizer does in front of
Sirius.

Subquery handling mirrors the rewrites DuckDB applies before emitting
Substrait:
  * ``x IN (SELECT ...)``     → semi join   (NOT IN → anti join)
  * ``EXISTS (SELECT ...)``   → semi join on the correlated equality keys
    (NOT EXISTS → anti join); only equality correlation is supported,
  * uncorrelated scalar subqueries → ``ScalarSubquery`` nodes, executed
    first by the engine and bound as literals,
  * correlated scalar comparisons (``x < (SELECT agg ... WHERE inner =
    outer)``) → the subquery aggregate grouped by its correlation keys,
    inner-joined on those keys, comparison kept as a residual predicate.

FROM-clause shapes beyond base tables: derived tables are lowered first
and bound like base tables; LEFT OUTER JOIN entries keep their ON
condition at the join (equality keys + build-side predicates), and
``count(col)`` over a left join's build side lowers to
``sum(case when __matched ...)``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Set, Tuple

from ..core.plan import (
    AggregateRel, FetchRel, FilterRel, JoinRel, ProjectRel, ReadRel, Rel,
    ScalarSubquery, SortRel,
)
from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, and_all, expr_children, expr_equal, split_conjuncts,
    transform_expr, walk_expr,
)
from ..relational.sort import SortKey
from ..relational.table import BOOL, DATE, NUMERIC, STRING
from .binder import Binding, Catalog, DEFAULT_CATALOG, Scope, bind_expr
from .lexer import SqlError
from .nodes import (
    OrderItem, OuterCol, SelectItem, SelectStmt, SqlCol, SqlExists, SqlFunc,
    SqlInSubquery, SqlSubquery, Star,
)

_AGG_FN_MAP = {"sum": "sum", "avg": "avg", "min": "min", "max": "max",
               "count": "count"}


def _contains(e: Expr, types) -> bool:
    return any(isinstance(n, types) for n in walk_expr(e))


def _cols_of(e: Expr) -> List[str]:
    return [n.name for n in walk_expr(e) if isinstance(n, Col)]


def _outer_cols_of(e: Expr) -> List[str]:
    return [n.name for n in walk_expr(e) if isinstance(n, OuterCol)]


class _Lowering:
    def __init__(self, catalog: Catalog):
        self.catalog = catalog
        self._names = itertools.count()

    def fresh(self, prefix: str) -> str:
        return f"__{prefix}{next(self._names)}"

    # ------------------------------------------------------------------
    def _make_bindings(self, refs) -> List[Binding]:
        """Resolve FROM entries: catalog tables → column/kind bindings,
        derived tables → lowered sub-plans with inferred output kinds."""
        out: List[Binding] = []
        for t in refs:
            if t.subquery is not None:
                plan, cols, corr, kinds = self.lower(t.subquery)
                assert not corr
                out.append(Binding(t.binding_name, cols, kinds, plan=plan))
                continue
            if not self.catalog.has_table(t.name):
                raise SqlError(f"unknown table {t.name!r}")
            cols = self.catalog.columns(t.name)
            kinds = {c: self.catalog.kind(t.name, c) for c in cols}
            out.append(Binding(t.binding_name, cols, kinds, table=t.name))
        return out

    def _scan_for(self, b: Binding) -> Rel:
        """A binding's scan: ReadRel / derived sub-plan, plus a renaming
        projection when the scope assigned non-source effective names."""
        base: Rel = b.plan if b.plan is not None else ReadRel(b.table)
        if b.renamed:
            base = ProjectRel(base, [(b.eff[c], Col(c)) for c in b.columns])
        return base

    def lower(self, stmt: SelectStmt, outer: Optional[Scope] = None,
              for_exists: bool = False, corr_group: bool = False):
        """→ (plan, output column names, correlations, output kinds).

        ``correlations`` is a list of (outer_col, inner_col) equality pairs
        extracted from the WHERE clause; non-empty only when ``outer`` is
        given and the subquery is correlated.  With ``corr_group`` (the
        correlated-scalar-subquery path) the inner correlation columns are
        injected as leading group keys and output columns, which is the
        standard aggregate decorrelation DuckDB performs.
        """
        bindings = self._make_bindings(stmt.from_tables)
        left_bindings = self._make_bindings([t for t, _ in stmt.left_joins])
        scope = Scope(self.catalog, bindings + left_bindings, parent=outer)

        where = bind_expr(stmt.where, scope) if stmt.where is not None \
            else None
        conjuncts = split_conjuncts(where)

        correlations: List[Tuple[str, str]] = []
        plain: List[Expr] = []
        sub_joins: List[Expr] = []       # IN/EXISTS subquery conjuncts
        scalar_cmps: List[Expr] = []     # conjuncts embedding (SELECT ...)
        for c in conjuncts:
            if isinstance(c, (SqlExists, SqlInSubquery)):
                sub_joins.append(c)
                continue
            outer_refs = _outer_cols_of(c)
            if outer_refs:
                pair = self._correlation_pair(c)
                if pair is None:
                    raise SqlError(
                        "only equality correlation (inner_col = outer_col) "
                        "is supported in subqueries")
                correlations.append(pair)
                continue
            if _contains(c, SqlSubquery):
                scalar_cmps.append(c)
                continue
            plain.append(c)

        # -- join tree over the FROM tables -----------------------------
        plan, available = self._join_tree(bindings, plain, scope)

        # -- LEFT JOIN entries (ON conditions stay at the join) ----------
        if len(stmt.left_joins) > 1:
            raise SqlError(
                "at most one LEFT JOIN per SELECT is supported (the "
                "engine's __matched marker is per-query)")
        left_info: List[Tuple[str, set]] = []
        for (tref, on_expr), b in zip(stmt.left_joins, left_bindings):
            plan, available = self._lower_left_join(
                plan, available, b, on_expr, scope, left_info)

        # -- IN / EXISTS subqueries → semi/anti joins --------------------
        for c in sub_joins:
            plan = self._lower_sub_join(plan, c, scope)

        # -- scalar subquery comparisons: uncorrelated → ScalarSubquery,
        #    correlated → decorrelating aggregate join ------------------
        for c in scalar_cmps:
            plan, rewritten = self._lower_scalar_cmp(plan, available, c,
                                                     scope)
            plain.append(rewritten)

        # WHERE predicates over the left join's build side would compare
        # garbage values on unmatched rows — reject instead of mis-answer
        self._check_left_guard(plain, left_info)

        # -- residual predicates (single FilterRel; optimizer pushes) ----
        residual = and_all(plain)
        if residual is not None:
            plan = FilterRel(plan, residual)

        if for_exists:
            return plan, list(available), correlations, {}

        # -- select items / aggregation ----------------------------------
        items = self._expand_items(stmt.items, available)
        bound_items = [SelectItem(bind_expr(it.expr, scope), it.alias)
                       for it in items]
        alias_map = {it.alias: it.expr for it in bound_items if it.alias}

        group_exprs = self._bind_group_by(stmt.group_by, scope, alias_map)
        has_agg = bool(group_exprs) or any(
            _contains(it.expr, SqlFunc) for it in bound_items)
        having = None
        if stmt.having is not None:
            having = bind_expr(stmt.having, scope)
            having = self._subst_aliases(having, alias_map)
            has_agg = True

        if corr_group and correlations:
            # decorrelation: group by the correlation keys, output them first
            if not has_agg:
                raise SqlError(
                    "correlated scalar subquery must be an aggregate")
            inner_keys: List[str] = []
            for _o, i in correlations:
                if i not in inner_keys:
                    inner_keys.append(i)
            group_exprs = [(k, Col(k)) for k in inner_keys] + group_exprs
            bound_items = [SelectItem(Col(k), k) for k in inner_keys] \
                + bound_items

        # unmatched left-join rows have no build-side values: only the
        # count(col)→sum(case __matched) rewrite can consume those columns
        self._check_left_guard(
            [it.expr for it in bound_items] + [e for _n, e in group_exprs]
            + ([having] if having is not None else []), left_info)

        # output kinds (for derived-table bindings in the enclosing scope)
        out_kinds: Dict[str, Optional[str]] = {}

        out_names: List[str] = []
        out_exprs: List[Tuple[str, Expr]] = []

        if has_agg:
            plan, key_names, rewrite = self._build_aggregate(
                plan, group_exprs, bound_items, having, scope, left_info)
            for i, it in enumerate(bound_items):
                name = it.alias or self._default_name(it.expr, i)
                out_kinds[name] = self._expr_kind(it.expr, scope)
                out_exprs.append((name, rewrite(it.expr)))
                out_names.append(name)
        else:
            for i, it in enumerate(bound_items):
                e = self._lower_scalar_subqueries(it.expr, scope)
                name = it.alias or self._default_name(e, i)
                out_kinds[name] = self._expr_kind(it.expr, scope)
                out_exprs.append((name, e))
                out_names.append(name)

        if len(set(out_names)) != len(out_names):
            raise SqlError(f"duplicate output column names: {out_names}")
        plan = ProjectRel(plan, out_exprs)

        if stmt.distinct:
            plan = AggregateRel(plan, list(out_names), [])

        # -- order by / limit --------------------------------------------
        if stmt.order_by:
            keys = [self._sort_key(o, out_exprs, scope) for o in stmt.order_by]
            plan = SortRel(plan, keys, limit=stmt.limit)
        elif stmt.limit is not None:
            plan = FetchRel(plan, stmt.limit)

        return plan, out_names, correlations, out_kinds

    # ------------------------------------------------------------------
    def _expr_kind(self, e: Expr, scope: Scope) -> Optional[str]:
        """Best-effort output kind of a bound expression (for derived-table
        column bindings; None = unknown, which only disables the binder's
        date-literal coercion for that column)."""
        if isinstance(e, Col):
            return scope.kind_of(e.name)
        if isinstance(e, SqlFunc):
            if e.name in ("min", "max") and e.arg is not None:
                return self._expr_kind(e.arg, scope)
            return NUMERIC
        if isinstance(e, Substr):
            return STRING
        if isinstance(e, (ExtractYear, Cast)):
            return NUMERIC
        if isinstance(e, Lit):
            return e.resolved_kind()
        if isinstance(e, (Between, InList, Like, StartsWith)):
            return BOOL
        if isinstance(e, BinOp):
            if e.op in ("and", "or") or e.op in ("==", "!=", "<", "<=", ">",
                                                 ">="):
                return BOOL
            return NUMERIC
        if isinstance(e, Case) and e.whens:
            return self._expr_kind(e.whens[0][1], scope)
        return None

    # ------------------------------------------------------------------
    def _correlation_pair(self, c: Expr) -> Optional[Tuple[str, str]]:
        if isinstance(c, BinOp) and c.op == "==":
            l, r = c.left, c.right
            if isinstance(l, Col) and isinstance(r, OuterCol):
                return (r.name, l.name)
            if isinstance(l, OuterCol) and isinstance(r, Col):
                return (l.name, r.name)
        return None

    def _lower_scalar_subqueries(self, e: Expr, scope: Scope) -> Expr:
        def visit(node: Expr) -> Expr:
            if isinstance(node, SqlSubquery):
                plan, cols, corr, _kinds = self.lower(node.select, outer=scope)
                if corr:
                    raise SqlError(
                        "correlated scalar subqueries are only supported as "
                        "the comparison operand of a WHERE conjunct")
                if len(cols) != 1:
                    raise SqlError(
                        "scalar subquery must produce exactly one column")
                return ScalarSubquery(plan, cols[0])
            return node
        return transform_expr(e, visit)

    def _join_tree(self, bindings: List[Binding], plain: List[Expr],
                   scope: Scope):
        """Greedy connectivity join over the FROM bindings.  Consumes the
        cross-binding equality conjuncts from ``plain``."""
        inner_ids = {id(b) for b in bindings}

        def is_equi(c: Expr) -> Optional[Tuple[str, str]]:
            if isinstance(c, BinOp) and c.op == "==" \
                    and isinstance(c.left, Col) and isinstance(c.right, Col):
                lb = scope.col_binding.get(c.left.name)
                rb = scope.col_binding.get(c.right.name)
                if lb and rb and lb[0] is not rb[0] \
                        and id(lb[0]) in inner_ids and id(rb[0]) in inner_ids:
                    return (c.left.name, c.right.name)
            return None

        # NB: never use list.remove / `in` on Expr lists — Expr.__eq__ builds
        # a BinOp (truthy), so equality-based removal hits the wrong element
        equi: List[Tuple[Expr, str, str]] = []
        rest: List[Expr] = []
        for c in plain:
            pair = is_equi(c)
            if pair is not None:
                equi.append((c, *pair))
            else:
                rest.append(c)
        plain[:] = rest

        plan: Rel = self._scan_for(bindings[0])
        available = set(bindings[0].eff_columns())
        remaining = list(bindings[1:])
        while remaining:
            picked = None
            for b in remaining:
                tcols = set(b.eff_columns())
                keys = [(a, bb) if a in available else (bb, a)
                        for _, a, bb in equi
                        if (a in available and bb in tcols)
                        or (bb in available and a in tcols)]
                if keys:
                    picked = (b, keys)
                    break
            if picked is None:
                raise SqlError(
                    f"disconnected join graph: no equality predicate links "
                    f"{[b.name for b in remaining]} to the joined tables "
                    "(cross joins are not supported)")
            b, keys = picked
            probe_keys = [k[0] for k in keys]
            build_keys = [k[1] for k in keys]
            plan = JoinRel(plan, self._scan_for(b), probe_keys, build_keys,
                           "inner")
            available |= set(b.eff_columns())
            used = {(a, bb) for a, bb in zip(probe_keys, build_keys)}
            equi = [e for e in equi
                    if (e[1], e[2]) not in used and (e[2], e[1]) not in used]
            remaining = [r for r in remaining if r is not b]
        # equality conjuncts that never linked a new table (both sides were
        # already available) stay as residual filters
        plain.extend(c for c, _a, _b in equi)
        return plan, available

    def _lower_left_join(self, plan: Rel, available: Set[str], b: Binding,
                         on_expr: Expr, scope: Scope,
                         left_info: List[Tuple[str, set]]):
        """LEFT OUTER JOIN lowering.  The ON condition must decompose into
        cross-side equality keys plus build-side-only predicates (pushed
        beneath the join, where they are outer-join-safe); the engine's left
        join marks matched rows with ``__matched``."""
        bound = bind_expr(on_expr, scope)
        bcols = set(b.eff_columns())
        probe_keys: List[str] = []
        build_keys: List[str] = []
        build_preds: List[Expr] = []
        for c in split_conjuncts(bound):
            if isinstance(c, BinOp) and c.op == "==" \
                    and isinstance(c.left, Col) and isinstance(c.right, Col):
                l, r = c.left.name, c.right.name
                if l in available and r in bcols:
                    probe_keys.append(l); build_keys.append(r)
                    continue
                if r in available and l in bcols:
                    probe_keys.append(r); build_keys.append(l)
                    continue
            cols = set(c.columns())
            if cols and cols <= bcols and not _contains(c, SqlSubquery):
                build_preds.append(c)
                continue
            raise SqlError(
                "LEFT JOIN ON supports equality keys plus right-side-only "
                "predicates")
        if not probe_keys:
            raise SqlError("LEFT JOIN requires at least one equality key")
        scan = self._scan_for(b)
        if build_preds:
            scan = FilterRel(scan, and_all(build_preds))
        plan = JoinRel(plan, scan, probe_keys, build_keys, "left")
        left_info.append(("__matched", bcols))
        return plan, available | bcols | {"__matched"}

    def _check_left_guard(self, exprs, left_info) -> None:
        """Reject references to a LEFT JOIN's build-side columns outside
        ``count(col)``.  The engine fills unmatched rows' build columns with
        arbitrary gathered values guarded by ``__matched``; only the
        count-rewrite consults the guard, so any other use would silently
        compute over garbage — a SqlError is the honest answer."""
        if not left_info:
            return
        bcols = set()
        for _mark, bc in left_info:
            bcols |= bc

        def visit(e: Expr) -> None:
            if isinstance(e, SqlFunc) and e.name == "count" \
                    and not e.distinct and isinstance(e.arg, Col) \
                    and e.arg.name in bcols:
                return                 # guarded: lowered to sum(case when)
            if isinstance(e, Col) and e.name in bcols:
                raise SqlError(
                    f"column {e.name!r} from a LEFT JOIN's right side can "
                    "only be used inside count(...) — unmatched rows have "
                    "no value for it")
            for child in expr_children(e):
                visit(child)

        for e in exprs:
            if e is not None:
                visit(e)

    def _lower_scalar_cmp(self, plan: Rel, available: Set[str], c: Expr,
                          scope: Scope):
        """Lower a WHERE conjunct embedding a scalar subquery.

        Uncorrelated subqueries become ``ScalarSubquery`` literals (executed
        first by the engine).  A correlated subquery must appear as one side
        of a comparison; it is decorrelated into an aggregate grouped by its
        correlation keys, inner-joined on those keys, with the comparison
        kept as a residual predicate — DuckDB's standard rewrite, and
        NULL-faithful here because a key with no group simply finds no join
        partner (sum/avg over the empty set compare as unknown in SQL).
        """
        is_cmp = (isinstance(c, BinOp)
                  and c.op in ("==", "!=", "<", "<=", ">", ">=")
                  and (isinstance(c.left, SqlSubquery)
                       ^ isinstance(c.right, SqlSubquery)))
        if not is_cmp:
            # any embedded subquery must be uncorrelated here
            return plan, self._lower_scalar_subqueries(c, scope)
        sub = c.right if isinstance(c.right, SqlSubquery) else c.left
        sub_plan, cols, corr, _kinds = self.lower(sub.select, outer=scope,
                                                  corr_group=True)
        if not corr:
            if len(cols) != 1:
                raise SqlError(
                    "scalar subquery must produce exactly one column")
            repl = ScalarSubquery(sub_plan, cols[0])
        else:
            inner_keys: List[str] = []
            key_outer: dict = {}
            for o, i in corr:
                if i in key_outer:
                    if key_outer[i] != o:
                        raise SqlError(
                            "conflicting correlation predicates on "
                            f"column {i!r}")
                    continue
                key_outer[i] = o
                inner_keys.append(i)
            missing = [key_outer[i] for i in inner_keys
                       if key_outer[i] not in available]
            if missing:
                raise SqlError(
                    f"correlated columns {missing} are not available in the "
                    "outer FROM clause")
            if len(cols) != len(inner_keys) + 1:
                raise SqlError("correlated scalar subquery must produce "
                               "exactly one column")
            tag = self.fresh("sq")
            renames = [(f"{tag}_k{j}", Col(k))
                       for j, k in enumerate(inner_keys)]
            renames.append((f"{tag}_v", Col(cols[len(inner_keys)])))
            sub_plan = ProjectRel(sub_plan, renames)
            plan = JoinRel(plan, sub_plan,
                           [key_outer[i] for i in inner_keys],
                           [f"{tag}_k{j}" for j in range(len(inner_keys))],
                           "inner")
            repl = Col(f"{tag}_v")
        if isinstance(c.right, SqlSubquery):
            other = self._lower_scalar_subqueries(c.left, scope)
            return plan, BinOp(c.op, other, repl)
        other = self._lower_scalar_subqueries(c.right, scope)
        return plan, BinOp(c.op, repl, other)

    def _lower_sub_join(self, plan: Rel, c: Expr, scope: Scope) -> Rel:
        if isinstance(c, SqlInSubquery):
            operand = bind_expr(c.operand, scope)
            if not isinstance(operand, Col):
                raise SqlError("IN (SELECT ...) requires a plain column on "
                               "the left-hand side")
            sub_plan, sub_cols, corr, _k = self.lower(c.select, outer=scope)
            if corr:
                raise SqlError("correlated IN subqueries are not supported")
            if len(sub_cols) != 1:
                raise SqlError("IN subquery must produce exactly one column")
            how = "anti" if c.negate else "semi"
            return JoinRel(plan, sub_plan, [operand.name], [sub_cols[0]], how)
        assert isinstance(c, SqlExists)
        sub_plan, _cols, corr, _k = self.lower(c.select, outer=scope,
                                               for_exists=True)
        if not corr:
            raise SqlError("EXISTS subquery must be correlated with the "
                           "outer query through an equality predicate")
        probe_keys = [outer for outer, _ in corr]
        build_keys = [inner for _, inner in corr]
        how = "anti" if c.negate else "semi"
        return JoinRel(plan, sub_plan, probe_keys, build_keys, how)

    # ------------------------------------------------------------------
    def _expand_items(self, items: List[SelectItem], available: Set[str]):
        out = []
        for it in items:
            if isinstance(it.expr, Star):
                # ``available`` holds *effective* (already-resolved) names —
                # emit bound Cols directly: re-resolving them as unqualified
                # SqlCols would fail for renamed self-join columns, and the
                # internal left-join marker is not a user-visible column
                out.extend(SelectItem(Col(c)) for c in sorted(available)
                           if not c.startswith("__"))
            else:
                out.append(it)
        return out

    def _bind_group_by(self, group_by, scope: Scope, alias_map):
        """→ list of (key_name, bound_expr)."""
        out: List[Tuple[str, Expr]] = []
        for i, g in enumerate(group_by):
            alias_name = None
            if isinstance(g, SqlCol) and g.qualifier is None \
                    and g.name in alias_map:
                alias_name = g.name
                bound = alias_map[g.name]
            else:
                bound = bind_expr(g, scope)
            if isinstance(bound, Col):
                out.append((bound.name, bound))
                continue
            # expression key: name it after the select alias when one matches
            name = alias_name
            if name is None:
                for a, e in alias_map.items():
                    if expr_equal(e, bound):
                        name = a
                        break
            out.append((name or self.fresh("key"), bound))
        return out

    def _subst_aliases(self, e: Expr, alias_map) -> Expr:
        def visit(node: Expr) -> Expr:
            if isinstance(node, SqlCol) and node.qualifier is None \
                    and node.name in alias_map:
                return alias_map[node.name]
            return node
        return transform_expr(e, visit)

    def _default_name(self, e: Expr, i: int) -> str:
        if isinstance(e, Col):
            return e.name
        return f"col{i}"

    def _build_aggregate(self, plan: Rel, group_exprs, bound_items,
                         having, scope: Scope, left_info=()):
        """Insert (pre-projection?) + AggregateRel; returns a rewriter that
        maps post-aggregation expressions onto the aggregate's output."""
        # pre-projection for expression-valued group keys
        pre: List[Tuple[str, Expr]] = []
        key_names: List[str] = []
        for name, e in group_exprs:
            key_names.append(name)
            if not isinstance(e, Col):
                pre.append((name, e))
        if pre:
            plan = ProjectRel(plan, pre, keep_input=True)

        aggs: List[AggSpec] = []

        def agg_name_for(fn_node: SqlFunc, preferred: Optional[str]) -> str:
            fn = _AGG_FN_MAP[fn_node.name]
            if fn_node.name == "count" and fn_node.arg is None:
                fn = "count_star"
            elif fn_node.name == "count" and fn_node.distinct:
                fn = "count_distinct"
            arg = None
            if fn_node.arg is not None:
                arg = self._lower_scalar_subqueries(fn_node.arg, scope)
            if fn == "count" and isinstance(arg, Col):
                # count(col) over the build side of a LEFT JOIN counts
                # matches, not rows: rewrite to sum(case when matched)
                for mark, bcols in left_info:
                    if arg.name in bcols:
                        fn = "sum"
                        arg = Case([(Col(mark), Lit(1))], Lit(0))
                        break
            for spec in aggs:
                if spec.fn == fn and expr_equal(spec.expr, arg):
                    return spec.name
            name = preferred or self.fresh("agg")
            if any(a.name == name for a in aggs):
                name = self.fresh("agg")
            aggs.append(AggSpec(fn, arg, name))
            return name

        # seed the agg list from the select items so single-agg items keep
        # their SQL alias as the aggregate's output name
        for it in bound_items:
            if isinstance(it.expr, SqlFunc) and it.alias:
                agg_name_for(it.expr, it.alias)

        rewritten_having = None
        if having is not None:
            rewritten_having = self._rewrite_post_agg(
                having, group_exprs, agg_name_for, None)
            # alias refs to agg outputs: SqlCol(alias) already substituted by
            # _subst_aliases; plain Col refs to agg names pass through
            bad = [c for c in _cols_of(rewritten_having)
                   if c not in key_names
                   and not any(a.name == c for a in aggs)]
            if bad:
                raise SqlError(f"HAVING references non-aggregated columns "
                               f"{bad}")
            rewritten_having = self._lower_scalar_subqueries(
                rewritten_having, scope)

        agg_rel = AggregateRel(plan, key_names, aggs, having=rewritten_having)

        def rewrite(e: Expr) -> Expr:
            out = self._rewrite_post_agg(e, group_exprs, agg_name_for, None)
            out = self._lower_scalar_subqueries(out, scope)
            bad = [c for c in _cols_of(out)
                   if c not in key_names
                   and not any(a.name == c for a in agg_rel.aggs)]
            if bad:
                raise SqlError(
                    f"column(s) {bad} must appear in GROUP BY or inside an "
                    "aggregate function")
            return out

        return agg_rel, key_names, rewrite

    def _rewrite_post_agg(self, e: Expr, group_exprs, agg_name_for,
                          preferred):
        """Top-down: SqlFunc subtrees → Col(agg name); group-key-matching
        subtrees → Col(key name)."""
        if isinstance(e, SqlFunc):
            return Col(agg_name_for(e, preferred))
        for name, ge in group_exprs:
            if expr_equal(e, ge):
                return Col(name)
        if not dataclasses.is_dataclass(e):
            return e
        changes = {}
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, Expr):
                nv = self._rewrite_post_agg(v, group_exprs, agg_name_for,
                                            None)
                if nv is not v:
                    changes[f.name] = nv
            elif isinstance(v, (list, tuple)) and not isinstance(v, str):
                new_items, dirty = [], False
                for item in v:
                    if isinstance(item, Expr):
                        ni = self._rewrite_post_agg(item, group_exprs,
                                                    agg_name_for, None)
                        dirty |= ni is not item
                        new_items.append(ni)
                    elif isinstance(item, tuple):
                        ni = tuple(
                            self._rewrite_post_agg(x, group_exprs,
                                                   agg_name_for, None)
                            if isinstance(x, Expr) else x for x in item)
                        dirty |= any(a is not b for a, b in zip(ni, item))
                        new_items.append(ni)
                    else:
                        new_items.append(item)
                if dirty:
                    changes[f.name] = new_items
        return dataclasses.replace(e, **changes) if changes else e

    def _sort_key(self, o: OrderItem, out_exprs, scope: Scope) -> SortKey:
        e = o.expr
        # a bare identifier naming an output column (alias or plain column)
        if isinstance(e, SqlCol) and e.qualifier is None:
            for name, _ in out_exprs:
                if name == e.name:
                    return SortKey(name, o.ascending)
        bound = bind_expr(e, scope)
        if isinstance(bound, Col):
            for name, oe in out_exprs:
                if isinstance(oe, Col) and oe.name == bound.name \
                        or name == bound.name:
                    return SortKey(name, o.ascending)
        for name, oe in out_exprs:
            if expr_equal(oe, bound):
                return SortKey(name, o.ascending)
        raise SqlError(
            "ORDER BY must reference an output column of the SELECT list")


def lower_select(stmt: SelectStmt, catalog: Optional[Catalog] = None) -> Rel:
    """Lower a bound SELECT statement to a (naive, unoptimized) plan."""
    catalog = catalog or DEFAULT_CATALOG
    plan, _cols, corr, _kinds = _Lowering(catalog).lower(stmt)
    assert not corr
    return plan
