"""SQL tokenizer.

Hand-rolled scanner producing a flat token stream for the recursive-descent
parser.  Keywords are case-insensitive; identifiers are lowercased (TPC-H
catalogs are all lower-case); string literals use single quotes with ''
escaping; numbers distinguish int/float.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


class SqlError(ValueError):
    """Parse/bind error with source position context."""

    def __init__(self, message: str, sql: Optional[str] = None,
                 pos: Optional[int] = None):
        if sql is not None and pos is not None:
            line_start = sql.rfind("\n", 0, pos) + 1
            line_end = sql.find("\n", pos)
            line_end = len(sql) if line_end < 0 else line_end
            caret = " " * (pos - line_start) + "^"
            message = f"{message}\n  {sql[line_start:line_end]}\n  {caret}"
        super().__init__(message)


KEYWORDS = {
    "select", "distinct", "from", "where", "group", "by", "having", "order",
    "limit", "as", "and", "or", "not", "in", "exists", "between", "like",
    "case", "when", "then", "else", "end", "join", "inner", "left", "outer",
    "on", "asc", "desc", "date", "interval", "year", "month", "day",
    "extract", "substring", "for", "cast", "is", "null", "true", "false",
}

# token kinds
KW, IDENT, NUM, STR, OP, EOF = "kw", "ident", "num", "str", "op", "eof"

_TWO_CHAR_OPS = ("<=", ">=", "<>", "!=")
_ONE_CHAR_OPS = "=<>+-*/(),.;"


@dataclasses.dataclass
class Token:
    kind: str
    value: object          # str for kw/ident/op/str, int|float for num
    pos: int

    def is_kw(self, *names: str) -> bool:
        return self.kind == KW and self.value in names

    def is_op(self, *ops: str) -> bool:
        return self.kind == OP and self.value in ops


def tokenize(sql: str) -> List[Token]:
    toks: List[Token] = []
    i, n = 0, len(sql)
    while i < n:
        c = sql[i]
        if c.isspace():
            i += 1
            continue
        if sql.startswith("--", i):                      # line comment
            j = sql.find("\n", i)
            i = n if j < 0 else j + 1
            continue
        if c == "'":                                     # string literal
            j, parts = i + 1, []
            while True:
                if j >= n:
                    raise SqlError("unterminated string literal", sql, i)
                if sql[j] == "'":
                    if j + 1 < n and sql[j + 1] == "'":  # '' escape
                        parts.append("'")
                        j += 2
                        continue
                    break
                parts.append(sql[j])
                j += 1
            toks.append(Token(STR, "".join(parts), i))
            i = j + 1
            continue
        if c.isdigit() or (c == "." and i + 1 < n and sql[i + 1].isdigit()):
            j = i
            is_float = False
            while j < n and (sql[j].isdigit() or sql[j] == "."):
                is_float |= sql[j] == "."
                j += 1
            text = sql[i:j]
            if text.count(".") > 1:
                raise SqlError(f"bad number {text!r}", sql, i)
            toks.append(Token(NUM, float(text) if is_float else int(text), i))
            i = j
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (sql[j].isalnum() or sql[j] == "_"):
                j += 1
            word = sql[i:j].lower()
            toks.append(Token(KW if word in KEYWORDS else IDENT, word, i))
            i = j
            continue
        two = sql[i:i + 2]
        if two in _TWO_CHAR_OPS:
            toks.append(Token(OP, two, i))
            i += 2
            continue
        if c in _ONE_CHAR_OPS:
            toks.append(Token(OP, c, i))
            i += 1
            continue
        raise SqlError(f"unexpected character {c!r}", sql, i)
    toks.append(Token(EOF, None, n))
    return toks
