"""SQL AST.

Scalar expressions reuse the engine's own Expr algebra
(``repro.relational.expressions``) so parse output composes directly with the
plan IR; SQL-only constructs (unresolved column refs, aggregate calls,
subqueries, intervals) are Expr subclasses that the binder and the lowering
pass eliminate.  Statement-level nodes (SELECT and its clauses) are plain
dataclasses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..relational.expressions import Expr

AGG_FUNCS = ("sum", "avg", "min", "max", "count")


# ---------------------------------------------------------------------------
# SQL-only expression leaves (eliminated by binding/lowering)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class SqlCol(Expr):
    """Unresolved column reference, optionally qualified by a table alias."""
    qualifier: Optional[str]
    name: str


@dataclasses.dataclass(eq=False)
class OuterCol(Expr):
    """Binder-resolved reference to a column of an *enclosing* query scope
    (a correlated reference, decorrelated into join keys during lowering)."""
    name: str


@dataclasses.dataclass(eq=False)
class SqlFunc(Expr):
    """Aggregate call; ``arg`` None means count(*)."""
    name: str
    arg: Optional[Expr]
    distinct: bool = False


@dataclasses.dataclass(eq=False)
class IntervalLit(Expr):
    """INTERVAL 'n' unit — only valid added to / subtracted from a date
    literal; folded to a DateLit by the binder."""
    amount: int
    unit: str                       # year | month | day


@dataclasses.dataclass(eq=False)
class Star(Expr):
    """The ``*`` select item (only meaningful under EXISTS or bare SELECT)."""


@dataclasses.dataclass(eq=False)
class SqlSubquery(Expr):
    """Scalar subquery: (SELECT single-expr FROM ...)."""
    select: "SelectStmt"


@dataclasses.dataclass(eq=False)
class SqlExists(Expr):
    select: "SelectStmt"
    negate: bool = False


@dataclasses.dataclass(eq=False)
class SqlInSubquery(Expr):
    operand: Expr
    select: "SelectStmt"
    negate: bool = False


# ---------------------------------------------------------------------------
# statement nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TableRef:
    """A FROM-list entry: a base table or a derived table (FROM subquery).

    For a derived table ``name`` equals the (mandatory) alias and
    ``subquery`` holds the parsed SELECT; the lowering pass lowers it first
    and binds its output columns like a base table's."""
    name: str
    alias: Optional[str] = None
    subquery: Optional["SelectStmt"] = None

    @property
    def binding_name(self) -> str:
        return self.alias or self.name


@dataclasses.dataclass
class SelectItem:
    expr: Expr
    alias: Optional[str] = None


@dataclasses.dataclass
class OrderItem:
    expr: Expr
    ascending: bool = True


@dataclasses.dataclass
class SelectStmt:
    items: List[SelectItem]
    from_tables: List[TableRef]
    where: Optional[Expr] = None
    group_by: List[Expr] = dataclasses.field(default_factory=list)
    having: Optional[Expr] = None
    order_by: List[OrderItem] = dataclasses.field(default_factory=list)
    limit: Optional[int] = None
    distinct: bool = False
    # LEFT OUTER JOIN entries: (table, ON condition).  Kept separate from
    # from_tables because their ON predicates must NOT fold into WHERE.
    left_joins: List[tuple] = dataclasses.field(default_factory=list)
