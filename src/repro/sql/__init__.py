"""SQL frontend: the host-database half of the paper's drop-in pipeline.

    sql text ── tokenize ─▶ parse ─▶ bind ─▶ lower ─▶ naive plan IR
                                   (repro.optimizer.optimize) ─▶ optimized IR
                                   (engine.execute / FallbackEngine) ─▶ rows

Entry points:
  * ``sql_to_plan(sql)``            — SQL text → (optimized) plan IR
  * ``run_sql(sql, db)``            — end-to-end: parse, optimize, execute;
    ``db`` may be a SiriusEngine, a FallbackEngine, or a host-format
    ``dict[table] -> dict[col] -> np.ndarray``
  * ``explain_sql(sql)``            — EXPLAIN output before/after rules
"""
from __future__ import annotations

from typing import Optional, Union

from ..core.plan import Rel, explain
from .binder import Catalog, DEFAULT_CATALOG
from .lexer import SqlError, tokenize
from .lower import lower_select
from .parser import parse_sql

__all__ = [
    "Catalog", "SqlError", "explain_sql", "parse_sql", "run_sql",
    "sql_to_plan", "tokenize",
]


def sql_to_plan(sql: str, catalog: Optional[Catalog] = None,
                optimize: bool = True) -> Rel:
    """Parse + bind + lower SQL text; optionally run the optimizer rules."""
    plan = lower_select(parse_sql(sql), catalog or DEFAULT_CATALOG)
    if optimize:
        from ..optimizer import optimize as _optimize
        plan = _optimize(plan, catalog or DEFAULT_CATALOG)
    return plan


def run_sql(sql: str, db, catalog: Optional[Catalog] = None,
            optimize: bool = True):
    """Execute SQL text against ``db``.

    ``db`` is a ``SiriusEngine`` (returns a device ``Table``), a
    ``FallbackEngine``, or a host-format dict-of-dicts (both return the
    host-table dict format).
    """
    from ..core.fallback import FallbackEngine

    plan = sql_to_plan(sql, catalog, optimize)
    if isinstance(db, dict):
        return FallbackEngine(db).execute(plan)
    return db.execute(plan)


def explain_sql(sql: str, catalog: Optional[Catalog] = None) -> str:
    """EXPLAIN: the naive lowered plan and the optimized plan side by side."""
    naive = sql_to_plan(sql, catalog, optimize=False)
    optimized = sql_to_plan(sql, catalog, optimize=True)
    return ("-- naive plan --\n" + explain(naive)
            + "\n-- optimized plan --\n" + explain(optimized))
