"""SQL frontend: the host-database half of the paper's drop-in pipeline.

    sql text ── tokenize ─▶ parse ─▶ bind ─▶ lower ─▶ naive plan IR
                                   (repro.optimizer.optimize) ─▶ optimized IR
                                   (engine.execute / FallbackEngine) ─▶ rows

Supported SQL (the TPC-H + ClickBench surface): SELECT [DISTINCT] with
joins (comma / INNER JOIN ON / LEFT OUTER JOIN ON), aliased self-joins,
derived tables in FROM, WHERE / GROUP BY (incl. expression keys) / HAVING /
ORDER BY / LIMIT, aggregates (sum, avg, min, max, count, count(distinct)),
subqueries (IN / NOT IN, EXISTS / NOT EXISTS, scalar — correlated scalar
comparisons are decorrelated DuckDB-style), CASE, CAST, EXTRACT(YEAR),
date/interval arithmetic, and the string functions LIKE (with backslash
escapes), substring(col, start, len) and starts_with(col, 'prefix').

Entry points (this module):
  * ``sql_to_plan(sql, catalog=None, optimize=True)`` — SQL text →
    (optimized) plan IR; the unit to inspect, serialize, or hand to any
    engine.
  * ``run_sql(sql, db, catalog=None, optimize=True)`` — end-to-end
    execution; ``db`` may be a ``SiriusEngine`` (device ``Table`` result),
    a ``FallbackEngine``, or a host-format dict-of-dicts.
  * ``explain_sql(sql, catalog=None)`` — naive and optimized plans side by
    side with cardinality annotations (the EXPLAIN observability loop).
  * ``EXPLAIN ANALYZE <query>`` — recognized as a prefix by ``run_sql`` and
    ``SiriusEngine.sql``; runs the query with per-operator telemetry and
    returns the ``QueryProfile`` (see ``repro.observability``) instead of
    rows.

``Catalog`` supplies table schemas, row estimates and (optionally, via
``Catalog.with_dictionaries``) string dictionaries for the optimizer's
dictionary-informed selectivity.  ``DEFAULT_CATALOG`` is TPC-H at SF 1;
the ClickBench catalog comes from ``repro.data.clickbench``.
"""
from __future__ import annotations

import re
from typing import Optional, Union

from ..core.plan import Rel, explain
from .binder import Catalog, DEFAULT_CATALOG
from .lexer import SqlError, tokenize
from .lower import lower_select
from .parser import parse_sql

__all__ = [
    "Catalog", "EXPLAIN_ANALYZE_RE", "SqlError", "explain_sql", "parse_sql",
    "run_sql", "sql_to_plan", "sql_to_wire", "tokenize",
]

# ``EXPLAIN ANALYZE`` is an entry-point prefix, not grammar: the statement
# after it parses unchanged, so the lexer/parser never see the keywords.
EXPLAIN_ANALYZE_RE = re.compile(r"^\s*explain\s+analyze\b", re.IGNORECASE)


def sql_to_plan(sql: str, catalog: Optional[Catalog] = None,
                optimize: bool = True) -> Rel:
    """Parse + bind + lower SQL text to plan IR.

    Args:
        sql: a single SELECT statement (trailing ``;`` allowed).
        catalog: table schemas / stats to bind against (default: TPC-H).
        optimize: run the rule-based optimizer passes; with False the
            naive lowering is returned (full-width scans, FROM-order join
            tree, one residual FilterRel) — the optimizer A/B baseline.

    Returns:
        The root ``Rel`` node; serialize with ``plan_to_json``, inspect
        with ``explain``, execute with any engine.

    Raises:
        SqlError: on lexical, syntactic or binding errors, with a caret
            pointing into the source text where possible.
    """
    plan = lower_select(parse_sql(sql), catalog or DEFAULT_CATALOG)
    if optimize:
        from ..optimizer import optimize as _optimize
        plan = _optimize(plan, catalog or DEFAULT_CATALOG)
    return plan


def sql_to_wire(sql: str, catalog: Optional[Catalog] = None,
                optimize: bool = True) -> dict:
    """SQL text → Substrait-style wire plan (the host-database producer).

    This is the full drop-in pipeline of the paper's host side: parse,
    bind, lower, optimize, then serialize through ``repro.substrait.emit``
    so the plan can cross a process/system boundary and be handed to
    ``SiriusEngine.accelerate`` (or any other consumer).  Serialize the
    returned dict canonically with ``repro.substrait.wire_bytes``.
    """
    from ..substrait import emit
    from .binder import DEFAULT_CATALOG

    cat = catalog or DEFAULT_CATALOG
    return emit(sql_to_plan(sql, cat, optimize), cat)


def run_sql(sql: str, db, catalog: Optional[Catalog] = None,
            optimize: bool = True):
    """Execute SQL text against ``db`` (parse → optimize → execute).

    Args:
        sql: a single SELECT statement.
        db: where to run —
            * ``SiriusEngine``: the accelerated pipeline engine; returns a
              device ``Table`` (call ``.to_host()`` for numpy columns);
            * ``FallbackEngine``: the numpy oracle; returns the host-table
              dict format;
            * ``dict[table] -> dict[col] -> np.ndarray``: host data,
              wrapped in a fresh ``FallbackEngine``.
        catalog: binder/optimizer catalog (default: TPC-H).  Prefer
            ``SiriusEngine.sql``, which also attaches the loaded tables'
            dictionaries for dictionary-informed stats.
        optimize: run the optimizer passes before executing.

    ``EXPLAIN ANALYZE <query>`` prefixes delegate to ``db.sql`` (engines
    that support profiling) and return the ``QueryProfile``.
    """
    from ..core.fallback import FallbackEngine

    if EXPLAIN_ANALYZE_RE.match(sql):
        if hasattr(db, "sql"):
            return db.sql(sql, catalog=catalog, optimize=optimize)
        raise SqlError("EXPLAIN ANALYZE requires a profiling engine "
                       "(SiriusEngine); got " + type(db).__name__)
    plan = sql_to_plan(sql, catalog, optimize)
    if isinstance(db, dict):
        return FallbackEngine(db).execute(plan)
    return db.execute(plan)


def explain_sql(sql: str, catalog: Optional[Catalog] = None) -> str:
    """EXPLAIN: the naive lowered plan and the optimized plan side by side.

    Each line is one plan operator with its salient detail (scan filters,
    join keys and sides, aggregate keys) and, on the optimized plan, the
    optimizer's ``[~N rows]`` cardinality annotation — the artifact to read
    when deciding whether pushdown/reordering did what you expected.
    """
    naive = sql_to_plan(sql, catalog, optimize=False)
    optimized = sql_to_plan(sql, catalog, optimize=True)
    return ("-- naive plan --\n" + explain(naive)
            + "\n-- optimized plan --\n" + explain(optimized))
