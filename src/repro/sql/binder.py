"""Name resolution + light typing against the catalog.

The binder rewrites parser output in three ways:

  * ``SqlCol`` → engine ``Col`` (local scope) or ``OuterCol`` (correlated
    reference to an enclosing scope, later decorrelated into join keys);
  * date coercion: a string literal compared against (or bounding a BETWEEN
    over) a DATE column becomes a DateLit, and ``date '...' ± interval``
    arithmetic is constant-folded to a DateLit — the rewrites DuckDB's
    binder performs before its optimizer runs;
  * scope bookkeeping: which FROM binding provides each column (the
    lowering pass builds the join graph from this).

The plan IR addresses columns purely by name, so every reference resolves
to a scope-unique **effective name**: the first binding to provide a source
column name keeps it; later bindings (aliased self-joins like ``nation n1,
nation n2``, colliding derived-table outputs) have theirs renamed to
``<binding>__<column>``, and the lowering inserts a renaming projection
over those scans.  Unqualified references are only valid while unambiguous;
qualified ones resolve through the binding's alias.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..data.tpch import TPCH_BASE_ROWS, TPCH_SCHEMA
from ..relational.expressions import (
    Between, BinOp, Col, Expr, Lit, transform_expr,
)
from ..relational.table import DATE, date_to_days
from .lexer import SqlError
from .nodes import IntervalLit, SqlCol


class Catalog:
    """Table schemas (column → kind), base-cardinality estimates, and —
    when attached — the string columns' dictionaries.

    Dictionaries turn the optimizer's constant string-predicate guesses
    (``SEL_LIKE`` et al.) into measured hit rates over the actual value
    domain; see ``repro.optimizer.stats.selectivity``.
    """

    def __init__(self, schema: Dict[str, Dict[str, str]],
                 rows: Optional[Dict[str, float]] = None,
                 dictionaries: Optional[Dict[str, Dict[str, object]]] = None):
        self.schema = schema
        self.rows = dict(rows or {})
        # table -> column -> sorted np.ndarray of distinct values
        self.dictionaries = dict(dictionaries or {})

    @staticmethod
    def tpch(scale_factor: float = 1.0) -> "Catalog":
        rows = {t: max(r * scale_factor, 1.0) if t not in ("region", "nation")
                else float(r) for t, r in TPCH_BASE_ROWS.items()}
        return Catalog(TPCH_SCHEMA, rows)

    def has_table(self, name: str) -> bool:
        return name in self.schema

    def columns(self, table: str) -> List[str]:
        return list(self.schema[table])

    def kind(self, table: str, col: str) -> str:
        return self.schema[table][col]

    def row_estimate(self, table: str) -> float:
        return float(self.rows.get(table, 1000.0))

    # -- dictionary-informed statistics ------------------------------------
    def with_dictionaries(self, tables) -> "Catalog":
        """Copy of this catalog with string dictionaries attached.

        ``tables`` maps table name to either a loaded ``relational.Table``
        or a plain ``{column: dictionary}`` mapping (what the engine keeps —
        dictionaries are host-side, so no device table needs pinning)."""
        dicts: Dict[str, Dict[str, object]] = dict(self.dictionaries)
        for name, table in tables.items():
            if not self.has_table(name):
                continue
            if hasattr(table, "columns") and not isinstance(table, dict):
                cols = {c: col.dictionary for c, col in table.columns.items()
                        if col.dictionary is not None}
            else:
                cols = {c: d for c, d in table.items() if d is not None}
            if cols:
                dicts[name] = cols
        return Catalog(self.schema, self.rows, dicts)

    def dictionary_for(self, column: str):
        """Dictionary of a (globally unique) column name, or None.

        TPC-H and the ClickBench hits table both have globally unique
        column names, so a flat lookup is unambiguous; renamed self-join
        columns simply miss and fall back to the constant heuristics.
        """
        for cols in self.dictionaries.values():
            if column in cols:
                return cols[column]
        return None


DEFAULT_CATALOG = Catalog.tpch()


class Binding:
    """One FROM-list entry resolved against the catalog (or a pre-lowered
    derived table): its source columns, their kinds, and the scope-unique
    *effective* output names the lowering uses downstream.

    Effective names are what make self-joins work on a plan IR that
    addresses columns purely by name: the first occurrence of a source
    column name in the scope keeps it, later occurrences (``nation n2``)
    are renamed to ``<binding>__<column>`` and the lowering inserts a
    renaming projection over that scan.
    """

    def __init__(self, name: str, columns: List[str],
                 kinds: Dict[str, Optional[str]], table: Optional[str] = None,
                 plan=None):
        self.name = name              # binding (alias) name
        self.table = table            # catalog table; None for derived
        self.columns = list(columns)  # source column names
        self.kinds = dict(kinds)      # source column -> kind (or None)
        self.plan = plan              # derived table's lowered sub-plan
        self.eff: Dict[str, str] = {}  # source column -> effective name

    def eff_columns(self) -> List[str]:
        return [self.eff[c] for c in self.columns]

    @property
    def renamed(self) -> bool:
        return any(self.eff[c] != c for c in self.columns)


class Scope:
    """Binding scope: the FROM entries of one SELECT (base tables, derived
    tables and left-join tables), chained to the parent query's scope for
    correlated references.  Resolution returns *effective* column names."""

    def __init__(self, catalog: Catalog, bindings: List[Binding],
                 parent: Optional["Scope"] = None):
        self.catalog = catalog
        self.bindings = bindings
        self.parent = parent
        self.by_alias: Dict[str, Binding] = {}
        self.by_source: Dict[str, List[Binding]] = {}
        self.col_binding: Dict[str, tuple] = {}  # eff -> (binding, src col)
        for b in bindings:
            if b.name in self.by_alias:
                raise SqlError(f"duplicate table alias {b.name!r}")
            self.by_alias[b.name] = b
            for col in b.columns:
                self.by_source.setdefault(col, []).append(b)
        taken = set()
        for b in bindings:
            for col in b.columns:
                eff = col if col not in taken else f"{b.name}__{col}"
                if eff in taken:
                    raise SqlError(
                        f"cannot disambiguate column {col!r} of {b.name!r}")
                taken.add(eff)
                b.eff[col] = eff
                self.col_binding[eff] = (b, col)

    def resolve(self, qualifier: Optional[str], name: str):
        """→ ("local"|"outer", effective column name)."""
        if qualifier is not None:
            b = self.by_alias.get(qualifier)
            if b is not None:
                if name not in b.eff:
                    raise SqlError(
                        f"column {name!r} not in table {qualifier!r}")
                return "local", b.eff[name]
            if self.parent is not None:
                _, eff = self.parent.resolve(qualifier, name)
                return "outer", eff
            raise SqlError(f"unknown table alias {qualifier!r}")
        cands = self.by_source.get(name, [])
        if len(cands) == 1:
            return "local", cands[0].eff[name]
        if len(cands) > 1:
            raise SqlError(
                f"ambiguous column {name!r} (qualify it with a table alias)")
        if self.parent is not None:
            _, eff = self.parent.resolve(None, name)
            return "outer", eff
        raise SqlError(f"unknown column {name!r}")

    def kind_of(self, name: str) -> Optional[str]:
        """Kind of an *effective* column name (None when unknown)."""
        hit = self.col_binding.get(name)
        return hit[0].kinds.get(hit[1]) if hit else None


# ---------------------------------------------------------------------------
# binding rewrites
# ---------------------------------------------------------------------------

_DATE_INTERVAL_OPS = ("+", "-")


def _shift_date(days: int, amount: int, unit: str) -> int:
    import calendar
    import datetime

    d = datetime.date(1970, 1, 1) + datetime.timedelta(days=int(days))
    if unit == "day":
        return int(days) + amount
    months = amount * (12 if unit == "year" else 1)
    total = d.year * 12 + (d.month - 1) + months
    y, m = divmod(total, 12)
    m += 1
    # SQL semantics: clamp to the target month's last day (Jan 31 + 1 month
    # is Feb 28/29, not an error)
    day = min(d.day, calendar.monthrange(y, m)[1])
    return date_to_days(f"{y:04d}-{m:02d}-{day:02d}")


def _parse_date(s: str) -> Optional[int]:
    """'1995-03-15' (or unpadded '1995-3-15') → days since epoch, else None."""
    import datetime

    parts = s.split("-")
    if len(parts) != 3:
        return None
    try:
        d = datetime.date(int(parts[0]), int(parts[1]), int(parts[2]))
    except ValueError:
        return None
    return date_to_days(d.isoformat())


def _date_lit(s: str) -> Lit:
    days = _parse_date(s)
    if days is None:
        raise SqlError(f"cannot compare a DATE column with non-date string "
                       f"{s!r}")
    return Lit(days, DATE)


def bind_expr(expr: Expr, scope: Scope) -> Expr:
    """Resolve columns and fold date arithmetic.  Subquery nodes are left in
    place (the lowering pass recurses into them with a child scope)."""
    from .nodes import OuterCol, SqlExists, SqlInSubquery, SqlSubquery

    def visit(e: Expr) -> Expr:
        if isinstance(e, SqlCol):
            where, col = scope.resolve(e.qualifier, e.name)
            return Col(col) if where == "local" else OuterCol(col)
        if isinstance(e, SqlInSubquery):
            # operand is bound; the subquery select binds during lowering
            return e
        if isinstance(e, (SqlSubquery, SqlExists)):
            return e
        if isinstance(e, BinOp):
            # fold: date_lit ± interval
            if e.op in _DATE_INTERVAL_OPS:
                l, r = e.left, e.right
                if isinstance(l, Lit) and l.kind == DATE \
                        and isinstance(r, IntervalLit):
                    sign = 1 if e.op == "+" else -1
                    return Lit(_shift_date(l.value, sign * r.amount, r.unit),
                               DATE)
            # coerce: DATE column compared against a string literal — a
            # non-date string here is always a type error, never a silent
            # raw-string comparison
            if e.op in ("==", "!=", "<", "<=", ">", ">="):
                l, r = e.left, e.right
                if isinstance(l, Col) and scope.kind_of(l.name) == DATE \
                        and isinstance(r, Lit) and isinstance(r.value, str):
                    return BinOp(e.op, l, _date_lit(r.value))
                if isinstance(r, Col) and scope.kind_of(r.name) == DATE \
                        and isinstance(l, Lit) and isinstance(l.value, str):
                    return BinOp(e.op, _date_lit(l.value), r)
            return e
        if isinstance(e, Between):
            v = e.operand
            if isinstance(v, Col) and scope.kind_of(v.name) == DATE:
                lo, hi = e.lo, e.hi
                changed = False
                if isinstance(lo, Lit) and isinstance(lo.value, str):
                    lo, changed = _date_lit(lo.value), True
                if isinstance(hi, Lit) and isinstance(hi.value, str):
                    hi, changed = _date_lit(hi.value), True
                if changed:
                    return Between(v, lo, hi)
            return e
        if isinstance(e, IntervalLit):
            return e                 # consumed by the BinOp fold above
        return e

    bound = transform_expr(expr, visit)
    for node in _walk_shallow(bound):
        if isinstance(node, IntervalLit):
            raise SqlError("INTERVAL is only supported added to/subtracted "
                           "from a DATE literal")
    return bound


def _walk_shallow(e: Expr):
    from ..relational.expressions import walk_expr
    yield from walk_expr(e)
