"""Name resolution + light typing against the catalog.

The binder rewrites parser output in three ways:

  * ``SqlCol`` → engine ``Col`` (local scope) or ``OuterCol`` (correlated
    reference to an enclosing scope, later decorrelated into join keys);
  * date coercion: a string literal compared against (or bounding a BETWEEN
    over) a DATE column becomes a DateLit, and ``date '...' ± interval``
    arithmetic is constant-folded to a DateLit — the rewrites DuckDB's
    binder performs before its optimizer runs;
  * scope bookkeeping: which FROM table provides each column (the lowering
    pass builds the join graph from this).

TPC-H column names are globally unique, so resolution maps every reference
to its bare column name; qualifiers are validated, then dropped.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..data.tpch import TPCH_BASE_ROWS, TPCH_SCHEMA
from ..relational.expressions import (
    Between, BinOp, Col, Expr, Lit, transform_expr,
)
from ..relational.table import DATE, date_to_days
from .lexer import SqlError
from .nodes import IntervalLit, SqlCol, TableRef


class Catalog:
    """Table schemas (column → kind) + base-cardinality estimates."""

    def __init__(self, schema: Dict[str, Dict[str, str]],
                 rows: Optional[Dict[str, float]] = None):
        self.schema = schema
        self.rows = dict(rows or {})

    @staticmethod
    def tpch(scale_factor: float = 1.0) -> "Catalog":
        rows = {t: max(r * scale_factor, 1.0) if t not in ("region", "nation")
                else float(r) for t, r in TPCH_BASE_ROWS.items()}
        return Catalog(TPCH_SCHEMA, rows)

    def has_table(self, name: str) -> bool:
        return name in self.schema

    def columns(self, table: str) -> List[str]:
        return list(self.schema[table])

    def kind(self, table: str, col: str) -> str:
        return self.schema[table][col]

    def row_estimate(self, table: str) -> float:
        return float(self.rows.get(table, 1000.0))


DEFAULT_CATALOG = Catalog.tpch()


class Scope:
    """Binding scope: the FROM tables of one SELECT, chained to the parent
    query's scope for correlated references."""

    def __init__(self, catalog: Catalog, tables: List[TableRef],
                 parent: Optional["Scope"] = None):
        self.catalog = catalog
        self.tables = tables
        self.parent = parent
        self.by_alias: Dict[str, str] = {}
        self.col_table: Dict[str, str] = {}   # column name -> providing table
        seen_tables = set()
        for t in tables:
            if not catalog.has_table(t.name):
                raise SqlError(f"unknown table {t.name!r}")
            if t.name in seen_tables:
                raise SqlError(
                    f"table {t.name!r} appears twice in FROM; self-joins are "
                    "not supported by the SQL frontend")
            seen_tables.add(t.name)
            if t.binding_name in self.by_alias:
                raise SqlError(f"duplicate table alias {t.binding_name!r}")
            self.by_alias[t.binding_name] = t.name
            for col in catalog.columns(t.name):
                if col in self.col_table:
                    raise SqlError(f"ambiguous column {col!r}")
                self.col_table[col] = t.name

    def resolve(self, qualifier: Optional[str], name: str):
        """→ ("local"|"outer", table, column)."""
        if qualifier is not None:
            if qualifier in self.by_alias:
                table = self.by_alias[qualifier]
                if name not in self.catalog.schema[table]:
                    raise SqlError(f"column {name!r} not in table {table!r}")
                return "local", table, name
            if self.parent is not None:
                kind, table, col = self.parent.resolve(qualifier, name)
                return "outer", table, col
            raise SqlError(f"unknown table alias {qualifier!r}")
        if name in self.col_table:
            return "local", self.col_table[name], name
        if self.parent is not None:
            kind, table, col = self.parent.resolve(None, name)
            return "outer", table, col
        raise SqlError(f"unknown column {name!r}")

    def kind_of(self, name: str) -> Optional[str]:
        t = self.col_table.get(name)
        return self.catalog.kind(t, name) if t else None


# ---------------------------------------------------------------------------
# binding rewrites
# ---------------------------------------------------------------------------

_DATE_INTERVAL_OPS = ("+", "-")


def _shift_date(days: int, amount: int, unit: str) -> int:
    import calendar
    import datetime

    d = datetime.date(1970, 1, 1) + datetime.timedelta(days=int(days))
    if unit == "day":
        return int(days) + amount
    months = amount * (12 if unit == "year" else 1)
    total = d.year * 12 + (d.month - 1) + months
    y, m = divmod(total, 12)
    m += 1
    # SQL semantics: clamp to the target month's last day (Jan 31 + 1 month
    # is Feb 28/29, not an error)
    day = min(d.day, calendar.monthrange(y, m)[1])
    return date_to_days(f"{y:04d}-{m:02d}-{day:02d}")


def _parse_date(s: str) -> Optional[int]:
    """'1995-03-15' (or unpadded '1995-3-15') → days since epoch, else None."""
    import datetime

    parts = s.split("-")
    if len(parts) != 3:
        return None
    try:
        d = datetime.date(int(parts[0]), int(parts[1]), int(parts[2]))
    except ValueError:
        return None
    return date_to_days(d.isoformat())


def _date_lit(s: str) -> Lit:
    days = _parse_date(s)
    if days is None:
        raise SqlError(f"cannot compare a DATE column with non-date string "
                       f"{s!r}")
    return Lit(days, DATE)


def bind_expr(expr: Expr, scope: Scope) -> Expr:
    """Resolve columns and fold date arithmetic.  Subquery nodes are left in
    place (the lowering pass recurses into them with a child scope)."""
    from .nodes import OuterCol, SqlExists, SqlInSubquery, SqlSubquery

    def visit(e: Expr) -> Expr:
        if isinstance(e, SqlCol):
            where, _table, col = scope.resolve(e.qualifier, e.name)
            return Col(col) if where == "local" else OuterCol(col)
        if isinstance(e, SqlInSubquery):
            # operand is bound; the subquery select binds during lowering
            return e
        if isinstance(e, (SqlSubquery, SqlExists)):
            return e
        if isinstance(e, BinOp):
            # fold: date_lit ± interval
            if e.op in _DATE_INTERVAL_OPS:
                l, r = e.left, e.right
                if isinstance(l, Lit) and l.kind == DATE \
                        and isinstance(r, IntervalLit):
                    sign = 1 if e.op == "+" else -1
                    return Lit(_shift_date(l.value, sign * r.amount, r.unit),
                               DATE)
            # coerce: DATE column compared against a string literal — a
            # non-date string here is always a type error, never a silent
            # raw-string comparison
            if e.op in ("==", "!=", "<", "<=", ">", ">="):
                l, r = e.left, e.right
                if isinstance(l, Col) and scope.kind_of(l.name) == DATE \
                        and isinstance(r, Lit) and isinstance(r.value, str):
                    return BinOp(e.op, l, _date_lit(r.value))
                if isinstance(r, Col) and scope.kind_of(r.name) == DATE \
                        and isinstance(l, Lit) and isinstance(l.value, str):
                    return BinOp(e.op, _date_lit(l.value), r)
            return e
        if isinstance(e, Between):
            v = e.operand
            if isinstance(v, Col) and scope.kind_of(v.name) == DATE:
                lo, hi = e.lo, e.hi
                changed = False
                if isinstance(lo, Lit) and isinstance(lo.value, str):
                    lo, changed = _date_lit(lo.value), True
                if isinstance(hi, Lit) and isinstance(hi.value, str):
                    hi, changed = _date_lit(hi.value), True
                if changed:
                    return Between(v, lo, hi)
            return e
        if isinstance(e, IntervalLit):
            return e                 # consumed by the BinOp fold above
        return e

    bound = transform_expr(expr, visit)
    for node in _walk_shallow(bound):
        if isinstance(node, IntervalLit):
            raise SqlError("INTERVAL is only supported added to/subtracted "
                           "from a DATE literal")
    return bound


def _walk_shallow(e: Expr):
    from ..relational.expressions import walk_expr
    yield from walk_expr(e)
