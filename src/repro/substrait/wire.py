"""Substrait-style wire format: producer (``emit``) and consumer (``ingest``).

The wire is a plain-JSON analogue of a Substrait plan message:

.. code-block:: text

    {
      "version":       {"majorNumber": 0, "minorNumber": 54, ...},
      "extensionUris": [{"extensionUriAnchor": 1, "uri": ".../*.yaml"}, ...],
      "extensions":    [{"extensionFunction": {"extensionUriReference": 1,
                                               "functionAnchor": 7,
                                               "name": "add"}}, ...],
      "schemas":       {"lineitem": {"columns": [{"name", "kind", "dtype",
                                                  "dictionary"}, ...]}},
      "relations":     [{"root": {"input": <rel>, "names": [...]}}]
    }

Every rel is a single-key object (``{"read": {...}}``, ``{"join": {...}}``,
…) and every non-leaf expression is a ``scalarFunction`` whose
``functionReference`` resolves through the ``extensions`` block into the
function registry — ingesting a plan that references a function or rel this
engine does not know fails with an actionable ``SubstraitError`` instead of
a ``KeyError``, which is the negotiation half of the drop-in contract.

Determinism: ``emit`` assigns extension anchors by sorted (group, name), so
emit → ingest → emit is byte-identical under ``wire_bytes`` (the canonical
serialization the golden files in ``tests/golden/substrait`` are stored in).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Set, Tuple

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SetRel, SortRel, WindowRel, walk_deep,
)
from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp, walk_expr,
)
from ..relational.sort import SortKey
from .registry import (
    BINOP_TO_FUNCTION, EXTENSION_URIS, FUNCTION_TO_BINOP, FUNCTIONS,
    function_uri,
)

WIRE_MAJOR = 0
WIRE_MINOR = 54
PRODUCER = "repro-substrait/0.1"

_KIND_DTYPE = {
    "numeric": "fp64",
    "string": "dictionary<i32,string>",
    "date": "date32[day]",
    "bool": "bool",
}

_REL_KEYS = ("read", "filter", "project", "join", "aggregate", "sort",
             "fetch", "exchange", "set", "window")

_JOIN_TYPES = {
    "inner": "JOIN_TYPE_INNER", "left": "JOIN_TYPE_LEFT",
    "semi": "JOIN_TYPE_LEFT_SEMI", "anti": "JOIN_TYPE_LEFT_ANTI",
    "mark": "JOIN_TYPE_LEFT_MARK",
}
_JOIN_TYPES_BACK = {v: k for k, v in _JOIN_TYPES.items()}

_SORT_ASC = "SORT_DIRECTION_ASC_NULLS_FIRST"
_SORT_DESC = "SORT_DIRECTION_DESC_NULLS_LAST"


class SubstraitError(ValueError):
    """Wire-format violation: unknown rel/function, bad reference, missing
    field.  Always carries enough context to locate the offending node."""


# ---------------------------------------------------------------------------
# canonical serialization
# ---------------------------------------------------------------------------


def wire_bytes(wire: Dict[str, Any]) -> bytes:
    """The canonical byte serialization (what golden files store): compact,
    key-sorted JSON + trailing newline."""
    return (json.dumps(wire, sort_keys=True, separators=(",", ":"),
                       ensure_ascii=True) + "\n").encode("utf-8")


# ---------------------------------------------------------------------------
# producer
# ---------------------------------------------------------------------------


def _used_functions(plan: Rel) -> Set[str]:
    used: Set[str] = set()

    def visit_expr(e: Expr) -> None:
        for node in walk_expr(e):
            if isinstance(node, BinOp):
                used.add(BINOP_TO_FUNCTION[node.op])
            elif isinstance(node, UnOp):
                used.add("not" if node.op == "not" else "negate")
            elif isinstance(node, Between):
                used.add("between")
            elif isinstance(node, InList):
                used.add("index_in")
            elif isinstance(node, Like):
                used.add("like")
            elif isinstance(node, StartsWith):
                used.add("starts_with")
            elif isinstance(node, Case):
                used.add("if_then")
            elif isinstance(node, ExtractYear):
                used.add("extract_year")
            elif isinstance(node, Substr):
                used.add("substring")
            elif isinstance(node, Cast):
                used.add("cast")

    from ..core.plan import rel_exprs
    for rel in walk_deep(plan):
        for e in rel_exprs(rel):
            visit_expr(e)
        if isinstance(rel, AggregateRel):
            for a in rel.aggs:
                used.add(a.fn)
        elif isinstance(rel, WindowRel):
            used.add(rel.func)
    return used


class _Emitter:
    def __init__(self, anchors: Dict[str, int]):
        self.anchors = anchors

    # -- expressions -------------------------------------------------------
    def fn(self, name: str, args: List[Any],
           options: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        node: Dict[str, Any] = {
            "functionReference": self.anchors[name],
            "arguments": args,
        }
        if options:
            node["options"] = options
        return {"scalarFunction": node}

    def expr(self, e: Expr) -> Dict[str, Any]:
        if isinstance(e, Col):
            return {"selection": {"column": e.name}}
        if isinstance(e, Lit):
            return {"literal": {"value": e.value, "kind": e.kind}}
        if isinstance(e, ScalarSubquery):
            return {"subquery": {"input": self.rel(e.plan),
                                 "column": e.column}}
        if isinstance(e, BinOp):
            return self.fn(BINOP_TO_FUNCTION[e.op],
                           [self.expr(e.left), self.expr(e.right)])
        if isinstance(e, UnOp):
            return self.fn("not" if e.op == "not" else "negate",
                           [self.expr(e.operand)])
        if isinstance(e, Between):
            return self.fn("between", [self.expr(e.operand),
                                       self.expr(e.lo), self.expr(e.hi)])
        if isinstance(e, InList):
            return self.fn("index_in", [self.expr(e.operand)],
                           {"values": list(e.values), "negate": e.negate})
        if isinstance(e, Like):
            return self.fn("like", [self.expr(e.operand)],
                           {"pattern": e.pattern, "negate": e.negate})
        if isinstance(e, StartsWith):
            return self.fn("starts_with", [self.expr(e.operand)],
                           {"prefix": e.prefix, "negate": e.negate})
        if isinstance(e, Case):
            args = []
            for c, v in e.whens:
                args.append(self.expr(c))
                args.append(self.expr(v))
            args.append(self.expr(e.default))
            return self.fn("if_then", args)
        if isinstance(e, ExtractYear):
            return self.fn("extract_year", [self.expr(e.operand)])
        if isinstance(e, Substr):
            return self.fn("substring", [self.expr(e.operand)],
                           {"start": e.start, "length": e.length})
        if isinstance(e, Cast):
            return self.fn("cast", [self.expr(e.operand)],
                           {"dtype": e.dtype})
        raise SubstraitError(f"cannot emit expression {type(e).__name__}")

    def _opt_expr(self, e: Optional[Expr]) -> Optional[Dict[str, Any]]:
        return None if e is None else self.expr(e)

    def _sorts(self, keys: List[SortKey]) -> List[Dict[str, Any]]:
        return [{"field": k.name,
                 "direction": _SORT_ASC if k.ascending else _SORT_DESC}
                for k in keys]

    # -- relations ---------------------------------------------------------
    def rel(self, r: Rel) -> Dict[str, Any]:
        if isinstance(r, ReadRel):
            return {"read": {
                "table": r.table,
                "columns": list(r.columns) if r.columns is not None else None,
                "filter": self._opt_expr(r.filter),
            }}
        if isinstance(r, FilterRel):
            return {"filter": {"input": self.rel(r.input),
                               "condition": self.expr(r.condition)}}
        if isinstance(r, ProjectRel):
            return {"project": {
                "input": self.rel(r.input),
                "expressions": [{"name": n, "expr": self.expr(e)}
                                for n, e in r.exprs],
                "keepInput": r.keep_input,
            }}
        if isinstance(r, JoinRel):
            return {"join": {
                "probe": self.rel(r.probe),
                "build": self.rel(r.build),
                "probeKeys": list(r.probe_keys),
                "buildKeys": list(r.build_keys),
                "type": _JOIN_TYPES[r.how],
                "markName": r.mark_name,
                "postFilter": self._opt_expr(r.post_filter),
            }}
        if isinstance(r, AggregateRel):
            return {"aggregate": {
                "input": self.rel(r.input),
                "groupings": list(r.group_keys),
                "measures": [{
                    "functionReference": self.anchors[a.fn],
                    "argument": self._opt_expr(a.expr),
                    "name": a.name,
                } for a in r.aggs],
                "having": self._opt_expr(r.having),
            }}
        if isinstance(r, SortRel):
            return {"sort": {"input": self.rel(r.input),
                             "sorts": self._sorts(r.keys),
                             "limit": r.limit}}
        if isinstance(r, FetchRel):
            return {"fetch": {"input": self.rel(r.input), "count": r.count}}
        if isinstance(r, ExchangeRel):
            return {"exchange": {"input": self.rel(r.input), "kind": r.kind,
                                 "keys": list(r.keys)}}
        if isinstance(r, SetRel):
            return {"set": {"inputs": [self.rel(p) for p in r.operands],
                            "op": r.op}}
        if isinstance(r, WindowRel):
            return {"window": {
                "input": self.rel(r.input),
                "partitionKeys": list(r.partition_keys),
                "orderKeys": self._sorts(r.order_keys),
                "functionReference": self.anchors[r.func],
                "argument": r.arg,
                "name": r.name,
            }}
        raise SubstraitError(f"cannot emit relation {type(r).__name__}")


def emit(plan: Rel, catalog=None) -> Dict[str, Any]:
    """Serialize a plan into the Substrait-style wire dict.

    ``catalog`` (a ``repro.sql.Catalog``) contributes the schema blocks for
    the base tables the plan reads and the root output names; without one
    the wire simply carries empty ``schemas``/``names``.
    """
    used = sorted(_used_functions(plan),
                  key=lambda n: (FUNCTIONS[n], n))
    anchors = {name: i + 1 for i, name in enumerate(used)}

    groups = sorted({FUNCTIONS[n] for n in used})
    uri_anchor = {g: i + 1 for i, g in enumerate(groups)}
    extension_uris = [{"extensionUriAnchor": uri_anchor[g],
                       "uri": EXTENSION_URIS[g]} for g in groups]
    extensions = [{"extensionFunction": {
        "extensionUriReference": uri_anchor[FUNCTIONS[n]],
        "functionAnchor": anchors[n],
        "name": n,
    }} for n in used]

    schemas: Dict[str, Any] = {}
    if catalog is not None:
        tables = sorted({r.table for r in walk_deep(plan)
                         if isinstance(r, ReadRel)
                         and catalog.has_table(r.table)})
        for t in tables:
            schemas[t] = {"columns": [
                {"name": c, "kind": k, "dtype": _KIND_DTYPE[k],
                 "dictionary": k == "string"}
                for c, k in catalog.schema[t].items()]}

    names: List[str] = []
    if catalog is not None:
        try:
            from ..optimizer.stats import rel_columns
            names = list(rel_columns(plan, catalog))
        except Exception:  # noqa: BLE001 — names are advisory
            names = []

    root = _Emitter(anchors).rel(plan)
    return {
        "version": {"majorNumber": WIRE_MAJOR, "minorNumber": WIRE_MINOR,
                    "patchNumber": 0, "producer": PRODUCER},
        "extensionUris": extension_uris,
        "extensions": extensions,
        "schemas": schemas,
        "relations": [{"root": {"input": root, "names": names}}],
    }


# ---------------------------------------------------------------------------
# consumer
# ---------------------------------------------------------------------------


class _Ingester:
    def __init__(self, functions: Dict[int, str]):
        self.functions = functions   # anchor -> registry name

    def _function(self, d: Dict[str, Any], path: str) -> str:
        ref = d.get("functionReference")
        if ref not in self.functions:
            raise SubstraitError(
                f"{path}: functionReference {ref!r} does not resolve to a "
                f"declared extension function (declared anchors: "
                f"{sorted(self.functions)})")
        return self.functions[ref]

    # -- expressions -------------------------------------------------------
    def expr(self, d: Any, path: str) -> Expr:
        if not isinstance(d, dict) or len(d) != 1:
            raise SubstraitError(
                f"{path}: expected a single-key expression object, got "
                f"{type(d).__name__}")
        key, body = next(iter(d.items()))
        if key == "selection":
            return Col(self._field(body, "column", path))
        if key == "literal":
            if "value" not in body:
                raise SubstraitError(f"{path}: literal without 'value'")
            return Lit(body["value"], body.get("kind"))
        if key == "subquery":
            return ScalarSubquery(
                self.rel(self._field(body, "input", path), path + ".subquery"),
                self._field(body, "column", path))
        if key != "scalarFunction":
            raise SubstraitError(
                f"{path}: unknown expression type {key!r} (expected "
                f"selection | literal | subquery | scalarFunction)")
        name = self._function(body, path)
        args = [self.expr(a, f"{path}.{name}[{i}]")
                for i, a in enumerate(body.get("arguments", []))]
        opts = body.get("options", {})

        def arity(n: int) -> None:
            if len(args) != n:
                raise SubstraitError(
                    f"{path}: function {name!r} expects {n} argument(s), "
                    f"got {len(args)}")

        if name in FUNCTION_TO_BINOP:
            arity(2)
            return BinOp(FUNCTION_TO_BINOP[name], args[0], args[1])
        if name == "not":
            arity(1)
            return UnOp("not", args[0])
        if name == "negate":
            arity(1)
            return UnOp("-", args[0])
        if name == "between":
            arity(3)
            return Between(args[0], args[1], args[2])
        if name == "index_in":
            arity(1)
            return InList(args[0], list(self._field(opts, "values", path)),
                          bool(opts.get("negate", False)))
        if name == "like":
            arity(1)
            return Like(args[0], self._field(opts, "pattern", path),
                        bool(opts.get("negate", False)))
        if name == "starts_with":
            arity(1)
            return StartsWith(args[0], self._field(opts, "prefix", path),
                              bool(opts.get("negate", False)))
        if name == "if_then":
            if len(args) < 3 or len(args) % 2 == 0:
                raise SubstraitError(
                    f"{path}: if_then expects pairs + default "
                    f"(odd arity >= 3), got {len(args)}")
            whens = [(args[i], args[i + 1])
                     for i in range(0, len(args) - 1, 2)]
            return Case(whens, args[-1])
        if name == "extract_year":
            arity(1)
            return ExtractYear(args[0])
        if name == "substring":
            arity(1)
            return Substr(args[0], int(self._field(opts, "start", path)),
                          int(self._field(opts, "length", path)))
        if name == "cast":
            arity(1)
            return Cast(args[0], self._field(opts, "dtype", path))
        raise SubstraitError(
            f"{path}: function {name!r} is declared but is not a scalar "
            f"function this consumer can build an expression from")

    def _opt_expr(self, d: Any, path: str) -> Optional[Expr]:
        return None if d is None else self.expr(d, path)

    @staticmethod
    def _field(body: Any, name: str, path: str) -> Any:
        if not isinstance(body, dict) or name not in body:
            raise SubstraitError(f"{path}: missing required field {name!r}")
        return body[name]

    def _sorts(self, items: Any, path: str) -> List[SortKey]:
        out = []
        for i, s in enumerate(items):
            direction = self._field(s, "direction", f"{path}[{i}]")
            if direction not in (_SORT_ASC, _SORT_DESC):
                raise SubstraitError(
                    f"{path}[{i}]: unknown sort direction {direction!r}")
            out.append(SortKey(self._field(s, "field", f"{path}[{i}]"),
                               direction == _SORT_ASC))
        return out

    # -- relations ---------------------------------------------------------
    def rel(self, d: Any, path: str) -> Rel:
        if not isinstance(d, dict) or len(d) != 1:
            raise SubstraitError(
                f"{path}: expected a single-key relation object, got "
                f"{d!r}" if not isinstance(d, dict) else
                f"{path}: relation object must have exactly one key, got "
                f"{sorted(d)}")
        key, body = next(iter(d.items()))
        p = f"{path}.{key}"
        if key not in _REL_KEYS:
            raise SubstraitError(
                f"{path}: unknown relation type {key!r}; this consumer "
                f"understands {list(_REL_KEYS)}")
        if key == "read":
            cols = body.get("columns")
            return ReadRel(self._field(body, "table", p),
                           list(cols) if cols is not None else None,
                           self._opt_expr(body.get("filter"), p + ".filter"))
        if key == "filter":
            return FilterRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                self.expr(self._field(body, "condition", p), p + ".condition"))
        if key == "project":
            exprs = [(self._field(x, "name", f"{p}.expressions[{i}]"),
                      self.expr(self._field(x, "expr", f"{p}.expressions[{i}]"),
                                f"{p}.expressions[{i}]"))
                     for i, x in enumerate(self._field(body, "expressions", p))]
            return ProjectRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                exprs, bool(body.get("keepInput", False)))
        if key == "join":
            jt = self._field(body, "type", p)
            if jt not in _JOIN_TYPES_BACK:
                raise SubstraitError(
                    f"{p}: unknown join type {jt!r}; expected one of "
                    f"{sorted(_JOIN_TYPES_BACK)}")
            return JoinRel(
                probe=self.rel(self._field(body, "probe", p), p + ".probe"),
                build=self.rel(self._field(body, "build", p), p + ".build"),
                probe_keys=list(self._field(body, "probeKeys", p)),
                build_keys=list(self._field(body, "buildKeys", p)),
                how=_JOIN_TYPES_BACK[jt],
                mark_name=body.get("markName", "__mark"),
                post_filter=self._opt_expr(body.get("postFilter"),
                                           p + ".postFilter"))
        if key == "aggregate":
            aggs = []
            for i, m in enumerate(self._field(body, "measures", p)):
                mp = f"{p}.measures[{i}]"
                fn = self._function(m, mp)
                if FUNCTIONS.get(fn) != "aggregate":
                    raise SubstraitError(
                        f"{mp}: {fn!r} is not an aggregate function")
                aggs.append(AggSpec(
                    fn, self._opt_expr(m.get("argument"), mp),
                    self._field(m, "name", mp)))
            return AggregateRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                list(self._field(body, "groupings", p)), aggs,
                self._opt_expr(body.get("having"), p + ".having"))
        if key == "sort":
            return SortRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                self._sorts(self._field(body, "sorts", p), p + ".sorts"),
                body.get("limit"))
        if key == "fetch":
            return FetchRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                int(self._field(body, "count", p)))
        if key == "exchange":
            return ExchangeRel(
                self.rel(self._field(body, "input", p), p + ".input"),
                self._field(body, "kind", p),
                list(body.get("keys", [])))
        if key == "set":
            inputs = self._field(body, "inputs", p)
            if not inputs:
                raise SubstraitError(
                    f"{p}: set relation requires at least one input")
            return SetRel(
                [self.rel(x, f"{p}.inputs[{i}]") for i, x in
                 enumerate(inputs)],
                body.get("op", "union_all"))
        if key == "window":
            fn = self._function(body, p)
            if FUNCTIONS.get(fn) not in ("window", "aggregate") \
                    or fn in ("count_star", "count_distinct"):
                raise SubstraitError(
                    f"{p}: {fn!r} is not a window function")
            if fn in ("sum", "avg", "min", "max") \
                    and body.get("argument") is None:
                raise SubstraitError(
                    f"{p}: window aggregate {fn!r} requires an 'argument' "
                    f"column")
            return WindowRel(
                input=self.rel(self._field(body, "input", p), p + ".input"),
                partition_keys=list(self._field(body, "partitionKeys", p)),
                order_keys=self._sorts(body.get("orderKeys", []),
                                       p + ".orderKeys"),
                func=fn,
                arg=body.get("argument"),
                name=body.get("name", "__window"))
        raise AssertionError(key)  # unreachable: key checked above


def _parse_extensions(wire: Dict[str, Any]) -> Dict[int, str]:
    uri_entries = wire.get("extensionUris", [])
    ext_entries = wire.get("extensions", [])
    if not isinstance(uri_entries, list) or not all(
            isinstance(u, dict) for u in uri_entries):
        raise SubstraitError("extensionUris must be a list of objects")
    if not isinstance(ext_entries, list):
        raise SubstraitError("extensions must be a list")
    uris = {u.get("extensionUriAnchor"): u.get("uri") for u in uri_entries}
    known_uris = set(EXTENSION_URIS.values())
    functions: Dict[int, str] = {}
    for i, ext in enumerate(ext_entries):
        body = ext.get("extensionFunction") if isinstance(ext, dict) else None
        if not isinstance(body, dict):
            raise SubstraitError(
                f"extensions[{i}]: expected an extensionFunction entry")
        name = body.get("name")
        uri_ref = body.get("extensionUriReference")
        anchor = body.get("functionAnchor")
        if uri_ref not in uris:
            raise SubstraitError(
                f"extensions[{i}]: extensionUriReference {uri_ref!r} is not "
                f"declared in extensionUris")
        if name not in FUNCTIONS:
            raise SubstraitError(
                f"extensions[{i}]: function {name!r} is not in this "
                f"consumer's registry (uri {uris[uri_ref]!r}); known "
                f"functions: {sorted(FUNCTIONS)}")
        if uris[uri_ref] not in known_uris:
            raise SubstraitError(
                f"extensions[{i}]: unknown extension uri {uris[uri_ref]!r} "
                f"for function {name!r}; this consumer serves "
                f"{sorted(known_uris)}")
        if not isinstance(anchor, int):
            raise SubstraitError(
                f"extensions[{i}]: functionAnchor must be an int, got "
                f"{anchor!r}")
        functions[anchor] = name
    return functions


def ingest(wire) -> Rel:
    """Deserialize a wire plan (dict, or its JSON text/bytes) into plan IR.

    Raises ``SubstraitError`` on any structural violation — version
    mismatch, unknown rel/function, dangling reference, missing field —
    with a path into the document.
    """
    if isinstance(wire, (bytes, bytearray)):
        wire = wire.decode("utf-8")
    if isinstance(wire, str):
        try:
            wire = json.loads(wire)
        except json.JSONDecodeError as e:
            raise SubstraitError(f"wire plan is not valid JSON: {e}") from e
    if not isinstance(wire, dict):
        raise SubstraitError(
            f"wire plan must be a JSON object, got {type(wire).__name__}")

    version = wire.get("version")
    if not isinstance(version, dict) or "majorNumber" not in version:
        raise SubstraitError("wire plan carries no version block")
    if version["majorNumber"] != WIRE_MAJOR:
        raise SubstraitError(
            f"wire major version {version['majorNumber']!r} is incompatible "
            f"with this consumer (expected {WIRE_MAJOR})")

    relations = wire.get("relations")
    if not isinstance(relations, list) or len(relations) != 1 \
            or not isinstance(relations[0], dict):
        raise SubstraitError("wire plan must carry exactly one relation tree")
    root = relations[0].get("root")
    if not isinstance(root, dict) or "input" not in root:
        raise SubstraitError("relations[0] must be {'root': {'input': ...}}")

    functions = _parse_extensions(wire)
    return _Ingester(functions).rel(root["input"], "relations[0].root.input")
