"""Substrait-style plan interchange + hybrid drop-in acceleration layer.

This package is the serialization boundary that makes the engine *drop-in*
(paper §3.1): a host database emits a standard plan representation, the
accelerator consumes it, and anything the accelerator cannot run degrades
to hybrid execution on the host fallback instead of erroring.

Public surface:

* ``emit(plan, catalog=None) -> dict`` — plan IR → Substrait-shaped wire
  dict (versioned, function-registry URIs, schema blocks).
* ``ingest(wire) -> Rel`` — wire dict / JSON text → plan IR; raises
  ``SubstraitError`` with a document path on any violation.
* ``wire_bytes(wire) -> bytes`` — the canonical byte serialization
  (compact, key-sorted; golden files store exactly these bytes).
* ``CapabilityRegistry`` / ``DEFAULT_REGISTRY`` — the per-rel / per-expr
  device-capability table.
* ``HybridRouter`` / ``explain_fragments`` — fragment splitting + two-engine
  execution with boundary-transfer accounting.

The engine front door is ``SiriusEngine.accelerate(wire_plan)``; the
process-boundary proof is ``scripts/substrait_smoke.py``.
"""
from __future__ import annotations

from .registry import (
    DEFAULT_REGISTRY, DEVICE_EXPRS, DEVICE_RELS, EXTENSION_URIS, FUNCTIONS,
    CapabilityRegistry,
)
from .router import Fragment, HybridRouter, explain_fragments
from .wire import SubstraitError, emit, ingest, wire_bytes

__all__ = [
    "CapabilityRegistry", "DEFAULT_REGISTRY", "DEVICE_EXPRS", "DEVICE_RELS",
    "EXTENSION_URIS", "FUNCTIONS", "Fragment", "HybridRouter",
    "SubstraitError", "emit", "explain_fragments", "ingest", "wire_bytes",
]
