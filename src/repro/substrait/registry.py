"""Function + capability registries for the plan-interchange boundary.

Two registries live here:

* the **function registry** — every scalar/aggregate/window operation the
  wire format can express, grouped under Substrait-style extension YAML
  URIs.  ``emit`` declares the functions a plan uses in the wire's
  ``extensions`` block (anchor → name) and ``ingest`` refuses anchors or
  names it does not know with an actionable ``SubstraitError``, exactly how
  Substrait consumers negotiate capability with producers.

* the **capability registry** — the per-rel / per-expr table the hybrid
  router consults to decide which plan fragments the device engine can own
  and which must degrade to the host fallback (``core.fallback``).  This is
  Sirius's drop-in contract: an unsupported rel (WindowRel, SetRel — or
  anything a test marks host-only) costs a fragment boundary, never an
  error.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, Optional, Tuple

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SetRel, SortRel, WindowRel, rel_exprs,
)
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp, walk_expr,
)

# ---------------------------------------------------------------------------
# function registry (wire vocabulary)
# ---------------------------------------------------------------------------

_EXT_BASE = "https://github.com/substrait-io/substrait/blob/main/extensions/"

EXTENSION_URIS: Dict[str, str] = {
    "arithmetic": _EXT_BASE + "functions_arithmetic.yaml",
    "comparison": _EXT_BASE + "functions_comparison.yaml",
    "boolean": _EXT_BASE + "functions_boolean.yaml",
    "string": _EXT_BASE + "functions_string.yaml",
    "datetime": _EXT_BASE + "functions_datetime.yaml",
    "type": _EXT_BASE + "functions_type.yaml",
    "aggregate": _EXT_BASE + "functions_aggregate_generic.yaml",
    "window": _EXT_BASE + "functions_window.yaml",
}

# function name -> extension group.  Scalar functions carry the whole Expr
# vocabulary; aggregate/window names serve AggregateRel measures + WindowRel.
FUNCTIONS: Dict[str, str] = {
    # BinOp arithmetic
    "add": "arithmetic", "subtract": "arithmetic", "multiply": "arithmetic",
    "divide": "arithmetic", "negate": "arithmetic",
    # BinOp comparisons + Between/InList
    "equal": "comparison", "not_equal": "comparison", "lt": "comparison",
    "lte": "comparison", "gt": "comparison", "gte": "comparison",
    "between": "comparison", "index_in": "comparison",
    # boolean connectives, UnOp not, Case
    "and": "boolean", "or": "boolean", "not": "boolean",
    "if_then": "boolean",
    # string predicates/transforms
    "like": "string", "starts_with": "string", "substring": "string",
    # datetime
    "extract_year": "datetime",
    # casts
    "cast": "type",
    # aggregate measures (AggSpec.fn names)
    "sum": "aggregate", "avg": "aggregate", "min": "aggregate",
    "max": "aggregate", "count": "aggregate", "count_star": "aggregate",
    "count_distinct": "aggregate",
    # window functions
    "row_number": "window", "rank": "window",
}

# BinOp.op <-> registry name
BINOP_TO_FUNCTION: Dict[str, str] = {
    "+": "add", "-": "subtract", "*": "multiply", "/": "divide",
    "==": "equal", "!=": "not_equal", "<": "lt", "<=": "lte",
    ">": "gt", ">=": "gte", "and": "and", "or": "or",
}
FUNCTION_TO_BINOP = {v: k for k, v in BINOP_TO_FUNCTION.items()}


def function_uri(name: str) -> str:
    return EXTENSION_URIS[FUNCTIONS[name]]


# ---------------------------------------------------------------------------
# capability registry (hybrid routing)
# ---------------------------------------------------------------------------

# Everything the push-based device executor can lower (core.executor
# PlanLowering + relational ops).  WindowRel / SetRel are deliberately
# absent: known to the wire, host-only at execution time.
DEVICE_RELS: FrozenSet[str] = frozenset(c.__name__ for c in (
    ReadRel, FilterRel, ProjectRel, JoinRel, AggregateRel, SortRel,
    FetchRel, ExchangeRel))

# Everything relational.expressions.evaluate handles on device.
DEVICE_EXPRS: FrozenSet[str] = frozenset(c.__name__ for c in (
    Col, Lit, BinOp, UnOp, Between, InList, Like, StartsWith, Case,
    ExtractYear, Substr, Cast, ScalarSubquery))

# The host fallback executes the full vocabulary.
HOST_RELS: FrozenSet[str] = DEVICE_RELS | frozenset(
    c.__name__ for c in (SetRel, WindowRel))


class CapabilityRegistry:
    """Per-rel / per-expr device-capability table.

    ``host_only_rels`` / ``host_only_exprs`` subtract capability (type
    names), which is how tests simulate an engine that lacks, say, LIKE —
    the router must respond by moving the containing rel to the host
    fragment, not by failing the query.
    """

    def __init__(self,
                 device_rels: Optional[Iterable[str]] = None,
                 device_exprs: Optional[Iterable[str]] = None,
                 host_only_rels: Iterable[str] = (),
                 host_only_exprs: Iterable[str] = ()):
        self.device_rels = frozenset(device_rels or DEVICE_RELS) \
            - frozenset(host_only_rels)
        self.device_exprs = frozenset(device_exprs or DEVICE_EXPRS) \
            - frozenset(host_only_exprs)

    # -- per-expr ----------------------------------------------------------
    def expr_on_device(self, e: Expr) -> bool:
        for node in walk_expr(e):
            if type(node).__name__ not in self.device_exprs:
                return False
            if isinstance(node, ScalarSubquery):
                # the executor resolves the sub-plan on device before the
                # pipeline runs, so its rels count against this expr
                if not self.plan_on_device(node.plan):
                    return False
        return True

    # -- per-rel -----------------------------------------------------------
    def rel_on_device(self, rel: Rel) -> bool:
        """Can the device engine own this node (exprs included, children
        excluded — fragment assembly is the router's job)?"""
        if type(rel).__name__ not in self.device_rels:
            return False
        return all(self.expr_on_device(e) for e in rel_exprs(rel))

    def plan_on_device(self, plan: Rel) -> bool:
        """Whole-subtree capability (used for scalar-subquery plans)."""
        return self.rel_on_device(plan) and all(
            self.plan_on_device(c) for c in plan.inputs())

    def placement(self, rel: Rel) -> str:
        return "device" if self.rel_on_device(rel) else "host"


DEFAULT_REGISTRY = CapabilityRegistry()
