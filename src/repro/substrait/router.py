"""Hybrid execution router: maximal device fragments + host fallback.

The drop-in contract (paper §3.2.2): when a plan contains a rel or
expression the accelerator engine cannot execute, Sirius does not error —
the host engine keeps those operators and only the supported fragments run
on the device.  This module reproduces that split for ingested plans:

1. every node gets a placement from the ``CapabilityRegistry``
   (device-capable or host-only);
2. maximal same-placement subtrees become **fragments**; each cut edge is a
   boundary scan (``ReadRel`` on a ``__substrait_frag<N>`` temp table);
3. fragments execute in dependency order — device fragments on the
   ``SiriusEngine`` pipeline executor, host fragments on the numpy oracle
   (``core.fallback.FallbackEngine``);
4. every table that crosses the boundary is accounted: device→host via
   ``BufferManager.account_boundary_to_host``, host→device via the buffer
   manager's cold-copy path plus ``account_boundary_to_device`` — so tests
   can assert that a pure-device plan moves zero boundary bytes and a
   hybrid plan moves exactly its cut-edge tables.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core.plan import (
    HYBRID_BOUNDARY_PREFIX, ReadRel, Rel, explain, walk_deep,
)
from ..observability.metrics import METRICS
from ..relational.table import Table
from .registry import DEFAULT_REGISTRY, CapabilityRegistry


@dataclasses.dataclass
class Fragment:
    """One routed plan piece: a subtree of uniform placement whose leaf
    boundary scans read other fragments' materialized results."""
    fid: int
    plan: Rel
    placement: str                      # "device" | "host"
    deps: List[int]
    rel_count: int                      # own rels (boundary scans excluded)


def _boundary_name(fid: int) -> str:
    return f"{HYBRID_BOUNDARY_PREFIX}{fid}"


def _is_boundary(rel: Rel) -> bool:
    return isinstance(rel, ReadRel) and \
        rel.table.startswith(HYBRID_BOUNDARY_PREFIX)


class HybridRouter:
    """Splits a plan by capability and drives the two engines."""

    def __init__(self, engine, registry: Optional[CapabilityRegistry] = None):
        self.engine = engine
        self.registry = registry or DEFAULT_REGISTRY

    # -- planning ----------------------------------------------------------
    def plan_fragments(self, plan: Rel) -> List[Fragment]:
        """Cut the plan into maximal same-placement fragments (pure —
        no execution, no engine state).  The root fragment is last."""
        registry = self.registry
        fragments: List[Fragment] = []

        def make(root: Rel) -> int:
            placement = registry.placement(root)
            deps: List[int] = []

            def rewrite(node: Rel) -> Rel:
                if registry.placement(node) != placement:
                    fid = make(node)
                    deps.append(fid)
                    return ReadRel(_boundary_name(fid))
                changes = {}
                for f in dataclasses.fields(node):
                    v = getattr(node, f.name)
                    if isinstance(v, Rel):
                        nv = rewrite(v)
                        if nv is not v:
                            changes[f.name] = nv
                    elif isinstance(v, list) and \
                            any(isinstance(x, Rel) for x in v):
                        changes[f.name] = [
                            rewrite(x) if isinstance(x, Rel) else x
                            for x in v]
                return dataclasses.replace(node, **changes) if changes \
                    else node

            new_root = rewrite(root)
            n_rels = sum(1 for r in walk_deep(new_root)
                         if not _is_boundary(r))
            frag = Fragment(len(fragments), new_root, placement, deps, n_rels)
            fragments.append(frag)
            return frag.fid

        make(plan)
        return fragments

    def device_fragment_fraction(self, plan: Rel) -> float:
        """Fraction of plan rels the device engine owns after routing
        (1.0 = fully device-resident, the paper's happy path)."""
        frags = self.plan_fragments(plan)
        total = sum(f.rel_count for f in frags)
        dev = sum(f.rel_count for f in frags if f.placement == "device")
        return dev / total if total else 1.0

    # -- execution ---------------------------------------------------------
    def execute(self, plan: Rel,
                analyze: bool = False) -> Tuple[Any, Dict[str, Any]]:
        """Run ``plan`` hybrid.  Returns (result, report): the result is a
        device ``Table`` when the root fragment ran on device, a host dict
        otherwise; the report carries fragment placements and boundary
        traffic.  With ``analyze=True`` each fragment entry also gets its
        wall-clock ``seconds`` and ``rows_out``, and device fragments carry
        their per-operator ``QueryProfile`` under ``"_profile"`` (popped by
        ``SiriusEngine.accelerate`` when it merges the combined profile)."""
        from ..core.fallback import FallbackEngine

        fragments = self.plan_fragments(plan)
        buffers = self.engine.buffers
        results: Dict[int, Any] = {}
        frag_info: Dict[int, Dict[str, Any]] = {}
        temp_names: List[str] = []
        to_host_bytes = to_device_bytes = 0
        try:
            for frag in fragments:
                METRICS.counter(f"router.{frag.placement}_fragments").inc()
                t_frag = time.perf_counter()
                if frag.placement == "device":
                    for d in frag.deps:
                        dep = results[d]
                        if not isinstance(dep, Table):
                            dep = Table.from_pydict(dep)
                            to_device_bytes += dep.nbytes
                            buffers.account_boundary_to_device(dep.nbytes)
                        name = _boundary_name(d)
                        buffers.cache_table(name, dep)
                        temp_names.append(name)
                    executor = self.engine.executor
                    # fragments that scan boundary temp tables must bypass
                    # the executable-plan cache: the temp contents change
                    # across accelerate() calls while the fragment's plan
                    # signature stays identical
                    prev_cache = executor.cache_enabled
                    executor.cache_enabled = prev_cache and not frag.deps
                    try:
                        out: Any = executor.execute(frag.plan,
                                                    analyze=analyze)
                    finally:
                        executor.cache_enabled = prev_cache
                    if analyze:
                        frag_info[frag.fid] = {
                            "_profile": self.engine.executor.last_profile}
                else:
                    host_tables = dict(self.engine.host_tables)
                    for d in frag.deps:
                        dep = results[d]
                        if isinstance(dep, Table):
                            buffers.account_boundary_to_host(dep.nbytes)
                            to_host_bytes += dep.nbytes
                            dep = dep.to_host()
                        host_tables[_boundary_name(d)] = dep
                    for rel in walk_deep(frag.plan):
                        # base tables this host fragment scans but the host
                        # side never saw: decode from the device cache
                        if isinstance(rel, ReadRel) and \
                                rel.table not in host_tables:
                            dev = buffers.get(rel.table)
                            buffers.account_boundary_to_host(dev.nbytes)
                            to_host_bytes += dev.nbytes
                            host_tables[rel.table] = dev.to_host()
                    out = FallbackEngine(host_tables).execute(frag.plan)
                results[frag.fid] = out
                if analyze:
                    info = frag_info.setdefault(frag.fid, {})
                    info["seconds"] = time.perf_counter() - t_frag
                    info["rows_out"] = (
                        out.num_rows if isinstance(out, Table)
                        else len(next(iter(out.values()), [])))
        finally:
            for name in temp_names:
                buffers.drop(name)
        total_rels = sum(f.rel_count for f in fragments)
        device_rels = sum(f.rel_count for f in fragments
                          if f.placement == "device")
        report = {
            "fragments": [dict({"fid": f.fid, "placement": f.placement,
                                "rels": f.rel_count, "deps": list(f.deps)},
                               **frag_info.get(f.fid, {}))
                          for f in fragments],
            "device_fragments": sum(1 for f in fragments
                                    if f.placement == "device"),
            "host_fragments": sum(1 for f in fragments
                                  if f.placement == "host"),
            "device_rel_fraction": device_rels / total_rels
            if total_rels else 1.0,
            "boundary_to_host_bytes": to_host_bytes,
            "boundary_to_device_bytes": to_device_bytes,
        }
        return results[fragments[-1].fid], report


def explain_fragments(fragments: List[Fragment]) -> str:
    """Human-readable routed plan: one block per fragment, hybrid boundary
    scans marked inline by ``explain`` (the EXPLAIN counterpart of the
    paper's fallback observability)."""
    blocks = []
    for f in fragments:
        head = f"Fragment {f.fid} [{f.placement}]"
        if f.deps:
            head += f" deps={f.deps}"
        body = "\n".join("  " + line
                         for line in explain(f.plan).splitlines())
        blocks.append(head + "\n" + body)
    return "\n".join(blocks)
