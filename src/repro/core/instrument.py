"""Host-transfer accounting: prove the data path stays device-resident.

Sirius's core bet is that columns never round-trip through host memory
mid-query.  This module makes that claim *testable*: ``track_transfers``
patches ``np.asarray`` (the one gate every device→host materialization in
this codebase goes through) and counts calls whose argument is a live
``jax.Array``.  The executor marks pipeline execution via ``pipeline_scope``
so the counter can distinguish transfers inside the hot path (must be zero)
from legitimate ones at the result boundary (``Table.to_host``) or during
scalar-subquery planning.

Scalar syncs (``int(x)``/``bool(x)`` on device scalars — dynamic output
sizes, eligibility bits) are deliberately *not* counted: they move O(1)
bytes and are part of the eager-dispatch contract, not a data-path breach.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import numpy as np


class TransferCounter:
    """Counts device→host column materializations (see module docstring)."""

    def __init__(self):
        self.total = 0            # all np.asarray(jax.Array) calls
        self.in_pipeline = 0      # …of which inside pipeline execution

    def reset(self) -> None:
        self.total = 0
        self.in_pipeline = 0


_local = threading.local()


def _depth() -> int:
    return getattr(_local, "pipeline_depth", 0)


@contextlib.contextmanager
def pipeline_scope() -> Iterator[None]:
    """Marks the current thread as executing a pipeline (worker threads)."""
    _local.pipeline_depth = _depth() + 1
    try:
        yield
    finally:
        _local.pipeline_depth = _depth() - 1


@contextlib.contextmanager
def track_transfers() -> Iterator[TransferCounter]:
    """Count device→host materializations until the context exits.

    Patches ``np.asarray`` process-wide (tests and benchmarks only — not a
    production mode); nesting is not supported.
    """
    counter = TransferCounter()
    orig = np.asarray

    def counting_asarray(a, *args, **kwargs):
        if isinstance(a, jax.Array):
            counter.total += 1
            if _depth() > 0:
                counter.in_pipeline += 1
        return orig(a, *args, **kwargs)

    np.asarray = counting_asarray
    try:
        yield counter
    finally:
        np.asarray = orig
