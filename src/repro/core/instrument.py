"""Host-transfer + sync accounting: prove the data path stays device-resident.

Sirius's core bet is that columns never round-trip through host memory
mid-query.  This module makes that claim *testable*: ``track_transfers``
patches ``np.asarray`` (the one gate every device→host materialization in
this codebase goes through) and counts calls whose argument is a live
``jax.Array``.  The executor marks pipeline execution via ``pipeline_scope``
so the counter can distinguish transfers inside the hot path (must be zero)
from legitimate ones at the result boundary (``Table.to_host``) or during
scalar-subquery planning.

Scalar syncs (``int(x)``/``bool(x)`` on device scalars — dynamic output
sizes, eligibility bits) are deliberately *not* counted: they move O(1)
bytes and are part of the eager-dispatch contract, not a data-path breach.

A second always-on counter, ``sync_barriers``, counts the executor's
explicit ``block_until_ready`` barriers.  The default async path issues
exactly **one** per query (the final result materialization); profiling
modes (``profile=True`` / ``analyze=True``) add opt-in per-operator
barriers — the overhead-guard test asserts the delta is zero when
profiling is off.

Both counters are thread-safe (concurrent queries from ROADMAP item 2's
serving layer increment them from many worker threads) and mirror into the
process-wide ``observability.METRICS`` registry.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import numpy as np

from ..observability.metrics import METRICS


class TransferCounter:
    """Counts device→host column materializations (see module docstring).

    Increments are lock-protected: ``track_transfers`` may observe many
    concurrent queries, and a torn ``+= 1`` would silently under-count —
    the exact failure mode an instrumentation module exists to rule out.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0            # all np.asarray(jax.Array) calls
        self.in_pipeline = 0      # …of which inside pipeline execution

    def record(self, in_pipeline: bool) -> None:
        with self._lock:
            self.total += 1
            if in_pipeline:
                self.in_pipeline += 1

    def reset(self) -> None:
        with self._lock:
            self.total = 0
            self.in_pipeline = 0


class _SyncCounter:
    """Thread-safe counter for the executor's explicit host barriers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self) -> None:
        with self._lock:
            self._value += 1

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


sync_barriers = _SyncCounter()


def count_sync() -> None:
    """Record one explicit executor barrier (``jax.block_until_ready``)."""
    sync_barriers.inc()
    METRICS.counter("executor.sync_barriers").inc()


_local = threading.local()


def _depth() -> int:
    return getattr(_local, "pipeline_depth", 0)


@contextlib.contextmanager
def pipeline_scope() -> Iterator[None]:
    """Marks the current thread as executing a pipeline (worker threads)."""
    _local.pipeline_depth = _depth() + 1
    try:
        yield
    finally:
        _local.pipeline_depth = _depth() - 1


_patch_lock = threading.Lock()


@contextlib.contextmanager
def track_transfers() -> Iterator[TransferCounter]:
    """Count device→host materializations until the context exits.

    Patches ``np.asarray`` process-wide (tests and benchmarks only — not a
    production mode); nesting is not supported, and concurrent entry from
    two threads is serialized by a module lock so the unpatch never
    clobbers a live patch.  Counts mirror into ``METRICS`` under
    ``instrument.transfers.total`` / ``instrument.transfers.in_pipeline``.
    """
    counter = TransferCounter()
    with _patch_lock:
        orig = np.asarray

        def counting_asarray(a, *args, **kwargs):
            if isinstance(a, jax.Array):
                in_pipe = _depth() > 0
                counter.record(in_pipe)
                METRICS.counter("instrument.transfers.total").inc()
                if in_pipe:
                    METRICS.counter("instrument.transfers.in_pipeline").inc()
            return orig(a, *args, **kwargs)

        np.asarray = counting_asarray
    try:
        yield counter
    finally:
        with _patch_lock:
            np.asarray = orig
