"""Host-transfer + sync accounting: prove the data path stays device-resident.

Sirius's core bet is that columns never round-trip through host memory
mid-query.  This module makes that claim *testable*: ``track_transfers``
patches ``np.asarray`` (the one gate every device→host materialization in
this codebase goes through) and counts calls whose argument is a live
``jax.Array``.  The executor marks pipeline execution via ``pipeline_scope``
so the counter can distinguish transfers inside the hot path (must be zero)
from legitimate ones at the result boundary (``Table.to_host``) or during
scalar-subquery planning.

Scalar syncs (``int(x)``/``bool(x)`` on device scalars — dynamic output
sizes, eligibility bits) move O(1) bytes but each one still stalls host
dispatch behind the device stream.  Since PR 7 they are *countable* and
*replayable*: every dynamic-cardinality pull in the engine goes through
``pull_scalar``, which (a) counts into ``scalar_syncs`` so the warm-path
contract test can assert zero, and (b) participates in the executable-plan
cache's record/replay protocol — a cold run records each pulled value; a
warm run returns the recorded value *without syncing* and instead emits a
device-side ``value != recorded`` flag that the executor folds into the
query's single final sync (any mismatch invalidates the cache entry and
re-executes cold).  Registered data is immutable between ``register()``
calls — the cache is cleared on re-registration — so recorded cardinalities
are exact for warm runs and the flags are a safety net, not a branch.

A second always-on counter, ``sync_barriers``, counts the executor's
explicit ``block_until_ready`` barriers.  The default async path issues
exactly **one** per query (the final result materialization); profiling
modes (``profile=True`` / ``analyze=True``) add opt-in per-operator
barriers — the overhead-guard test asserts the delta is zero when
profiling is off.

Both counters are thread-safe (concurrent queries from ROADMAP item 2's
serving layer increment them from many worker threads) and mirror into the
process-wide ``observability.METRICS`` registry.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..observability.metrics import METRICS


class TransferCounter:
    """Counts device→host column materializations (see module docstring).

    Increments are lock-protected: ``track_transfers`` may observe many
    concurrent queries, and a torn ``+= 1`` would silently under-count —
    the exact failure mode an instrumentation module exists to rule out.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0            # all np.asarray(jax.Array) calls
        self.in_pipeline = 0      # …of which inside pipeline execution

    def record(self, in_pipeline: bool) -> None:
        with self._lock:
            self.total += 1
            if in_pipeline:
                self.in_pipeline += 1

    def reset(self) -> None:
        with self._lock:
            self.total = 0
            self.in_pipeline = 0


class _SyncCounter:
    """Thread-safe counter for the executor's explicit host barriers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self) -> None:
        with self._lock:
            self._value += 1

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


sync_barriers = _SyncCounter()
scalar_syncs = _SyncCounter()


def count_sync() -> None:
    """Record one explicit executor barrier (``jax.block_until_ready``)."""
    sync_barriers.inc()
    METRICS.counter("executor.sync_barriers").inc()


_local = threading.local()


# ---------------------------------------------------------------------------
# scalar pulls: counted, recordable, replayable (executable-plan cache)
# ---------------------------------------------------------------------------


class ReplayMismatch(Exception):
    """A replayed execution diverged structurally from its recording.

    Raised when a warm run performs more pulls than the cold run recorded —
    control flow changed, so the cached dispatch schedule is stale.  (Value
    divergence is detected lazily via device-side flags at the final sync,
    not here.)  The executor invalidates the entry and re-runs cold."""


class _ScalarCtx:
    __slots__ = ("mode", "values", "pos", "flags")

    def __init__(self, mode: str, values: list, flags: list = None):
        self.mode = mode          # "record" | "replay"
        self.values = values
        self.pos = 0
        self.flags = flags


def _materialize(x):
    return x.item() if hasattr(x, "item") else x


def pull_scalar(x):
    """Materialize a device scalar (dynamic row count, eligibility bit).

    The one blessed gate for host↔device scalar pulls on the data path:

    * **normal** — sync now (``.item()``), count into ``scalar_syncs`` /
      ``executor.scalar_syncs`` when the value was actually on device;
    * **record** (cold run under the plan cache) — sync, count, and append
      the value to the active recording;
    * **replay** (warm run) — return the recorded value *without syncing*;
      the ``x != recorded`` comparison stays on device and is checked at
      the query's final barrier.

    Host-side inputs (python/numpy scalars) pass through uncounted.
    """
    ctx = getattr(_local, "scalar_ctx", None)
    if ctx is not None and ctx.mode == "replay":
        if ctx.pos >= len(ctx.values):
            raise ReplayMismatch(
                f"replay exhausted after {len(ctx.values)} recorded pulls")
        v = ctx.values[ctx.pos]
        ctx.pos += 1
        if isinstance(x, jax.Array):
            ctx.flags.append(jnp.reshape(x != v, ()))
        elif _materialize(x) != v:
            raise ReplayMismatch("host-side scalar diverged from recording")
        return v
    on_device = isinstance(x, jax.Array)
    v = _materialize(x)
    if on_device:
        scalar_syncs.inc()
        METRICS.counter("executor.scalar_syncs").inc()
    if ctx is not None and ctx.mode == "record":
        ctx.values.append(v)
    return v


@contextlib.contextmanager
def scalar_recording(values: list) -> Iterator[None]:
    """Append every ``pull_scalar`` value on this thread to ``values``."""
    prev = getattr(_local, "scalar_ctx", None)
    _local.scalar_ctx = _ScalarCtx("record", values)
    try:
        yield
    finally:
        _local.scalar_ctx = prev


@contextlib.contextmanager
def scalar_replay(values: list, flags: list) -> Iterator[None]:
    """Serve ``pull_scalar`` calls from ``values`` without syncing.

    Device-side ``!=`` verification flags accumulate into ``flags``; the
    caller must fold them into its final barrier and treat any set flag as
    a cache invalidation.  Raises ``ReplayMismatch`` (from ``pull_scalar``)
    if the pull sequence outruns the recording."""
    prev = getattr(_local, "scalar_ctx", None)
    ctx = _ScalarCtx("replay", values, flags)
    _local.scalar_ctx = ctx
    try:
        yield
        if ctx.pos != len(values):
            raise ReplayMismatch(
                f"replay consumed {ctx.pos} of {len(values)} recorded pulls")
    finally:
        _local.scalar_ctx = prev


@contextlib.contextmanager
def pulls_suspended() -> Iterator[None]:
    """Temporarily drop out of record/replay (insert-time-only code paths:
    probe lowering, nested planning) so their pulls never join a schedule."""
    prev = getattr(_local, "scalar_ctx", None)
    _local.scalar_ctx = None
    try:
        yield
    finally:
        _local.scalar_ctx = prev


def _depth() -> int:
    return getattr(_local, "pipeline_depth", 0)


@contextlib.contextmanager
def pipeline_scope() -> Iterator[None]:
    """Marks the current thread as executing a pipeline (worker threads)."""
    _local.pipeline_depth = _depth() + 1
    try:
        yield
    finally:
        _local.pipeline_depth = _depth() - 1


_patch_lock = threading.Lock()


@contextlib.contextmanager
def track_transfers() -> Iterator[TransferCounter]:
    """Count device→host materializations until the context exits.

    Patches ``np.asarray`` process-wide (tests and benchmarks only — not a
    production mode); nesting is not supported, and concurrent entry from
    two threads is serialized by a module lock so the unpatch never
    clobbers a live patch.  Counts mirror into ``METRICS`` under
    ``instrument.transfers.total`` / ``instrument.transfers.in_pipeline``.
    """
    counter = TransferCounter()
    with _patch_lock:
        orig = np.asarray

        def counting_asarray(a, *args, **kwargs):
            if isinstance(a, jax.Array):
                in_pipe = _depth() > 0
                counter.record(in_pipe)
                METRICS.counter("instrument.transfers.total").inc()
                if in_pipe:
                    METRICS.counter("instrument.transfers.in_pipeline").inc()
            return orig(a, *args, **kwargs)

        np.asarray = counting_asarray
    try:
        yield counter
    finally:
        with _patch_lock:
            np.asarray = orig
