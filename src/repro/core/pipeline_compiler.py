"""Pipeline compiler: fuse contiguous op chains into one jitted device region.

The eager executor dispatches each operator separately and (pre-refactor)
forced a host sync between them.  Per the data-path-fusion line of work
(PAPERS.md), the single largest win for this architecture is compiling each
pipeline's contiguous Filter/Project/Probe chain into **one** XLA program:
columns enter the region once, every intermediate lives in device registers /
HBM, and the only host interaction is the scalar row count of the final
compaction.

Mechanics:

* **Mask-mode execution.**  Inside the fused region tables keep a static row
  count; filters and probes narrow a validity mask instead of compacting.
  One ``kernels.ops.compact`` + gather at the region boundary materializes
  the survivors (the TPU answer to warp-ballot compaction).
* **Signature-keyed cache.**  Compiled regions are cached across queries,
  keyed by the *plan signature*: the structural expression tree of every op
  plus the input column names/kinds/dtypes (and dictionary identity for
  string columns, whose host-side dictionaries fold into the trace as
  constants).  Shapes are deliberately absent from the signature — jax.jit
  keys them — but inputs are padded to power-of-two **padding buckets** so
  repeated runs and near-miss cardinalities reuse the same compilation.
* **Probe lowering.**  An eligible hash probe (single int key; unique build
  keys for inner; inner/semi/anti/mark) becomes a static-shape lookup inside
  the fused region: the lookup table is built once per pipeline on device
  and passed in as arguments.  Dense key domains get a sort-free
  direct-address build (``kernels.ops.direct_build``), sparse domains a
  sorted binary-search build, and with a kernel backend attached the probe
  runs the Pallas ``hash_probe`` kernel on int32-factorized keys.
* **Graceful degradation.**  Any op outside the fusion contract (left joins,
  multi-column keys, duplicate build keys…) splits the chain; the op runs
  eagerly between fused segments.  A chain whose trace fails is marked and
  executed eagerly forever after — never an error.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..observability.metrics import METRICS
from ..relational.expressions import Expr, evaluate
from ..relational.table import BOOL, DATE, NUMERIC, Column, Table
from .instrument import pull_scalar

_bucket = kops.bucket_size
_pad = kops.pad_rows


def expr_signature(e) -> str:
    """Deterministic structural rendering of an expression tree.

    Part of the plan signature that keys the compiled-region cache (the safe
    idiom here is structural — Expr.__eq__ builds BinOp nodes, see
    ``Expr.equals``)."""
    if e is None:
        return "_"
    if isinstance(e, Expr) and dataclasses.is_dataclass(e):
        parts = []
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, Expr):
                parts.append(expr_signature(v))
            elif isinstance(v, (list, tuple)):
                parts.append("[" + ",".join(
                    expr_signature(x) if isinstance(x, Expr) else
                    ("(" + ",".join(expr_signature(y) if isinstance(y, Expr)
                                    else repr(y) for y in x) + ")")
                    if isinstance(x, tuple) else repr(x) for x in v) + "]")
            else:
                parts.append(repr(v))
        return f"{type(e).__name__}({','.join(parts)})"
    return repr(e)


def _table_signature(t: Table) -> Tuple:
    return tuple((n, c.kind, str(c.data.dtype),
                  id(c.dictionary) if c.dictionary is not None else None)
                 for n, c in t.columns.items())


# ---------------------------------------------------------------------------
# fused items (static descriptions of ops inside a region)
# ---------------------------------------------------------------------------


class _FusedFilter:
    def __init__(self, cond: Expr):
        self.cond = cond

    def signature(self):
        return ("F", expr_signature(self.cond))

    def apply(self, t: Table, valid, aux):
        return t, valid & evaluate(self.cond, t).data


class _FusedProject:
    def __init__(self, exprs, keep_input: bool):
        self.exprs = exprs
        self.keep_input = keep_input

    def signature(self):
        return ("P", tuple((n, expr_signature(e)) for n, e in self.exprs),
                self.keep_input)

    def apply(self, t: Table, valid, aux):
        cols = dict(t.columns) if self.keep_input else {}
        for name, e in self.exprs:
            cols[name] = evaluate(e, t)
        return Table(cols), valid


class _FusedSelect:
    def __init__(self, columns):
        self.columns = list(columns)

    def signature(self):
        return ("S", tuple(self.columns))

    def apply(self, t: Table, valid, aux):
        return t.select([c for c in self.columns if c in t]), valid


class _FusedProbe:
    """Static-shape hash probe; the build table arrives as region arguments.

    ``aux`` = (sorted keys, lookup table, build_arrays) — all padded to
    power-of-two buckets at prepare time.  ``lookup table`` is the
    sorted-order row map (pure-XLA binary-search probe) or the Pallas
    kernel's (slots_key, slots_row) when a kernel backend is attached.
    """

    def __init__(self, probe_key: str, how: str, mark_name: str,
                 post_filter: Optional[Expr], build_meta, mode: str,
                 interpret: bool = True):
        self.probe_key = probe_key
        self.how = how
        self.mark_name = mark_name
        self.post_filter = post_filter
        self.build_meta = build_meta      # tuple of (name, kind, dtype, dict)
        self.mode = mode                  # direct | sorted | kernel
        self.interpret = interpret        # kernel mode only; traced in

    def signature(self):
        return ("J", self.probe_key, self.how, self.mark_name,
                expr_signature(self.post_filter),
                tuple((n, k, str(dt), id(d) if d is not None else None)
                      for n, k, dt, d in self.build_meta),
                self.mode, self.interpret)

    def apply(self, t: Table, valid, aux):
        table, build_arrays = aux
        probe_col = t[self.probe_key]
        if probe_col.data.dtype.kind not in "iu":
            # int64 cast of a float/string key would change semantics: abort
            # the trace; the segment degrades to the eager ops (correct path)
            raise TypeError(f"unfusable probe key dtype {probe_col.data.dtype}")
        pk = probe_col.data.astype(jnp.int64)
        if self.mode == "kernel":
            s_keys, slots_key, slots_row = table
            p32 = kops.map_probe_keys(s_keys, pk)
            row, found = kops.hash_probe(p32, slots_key, slots_row,
                                         interpret=self.interpret)
        elif self.mode == "direct":
            slot, lo = table
            row, found = kops.direct_lookup(slot, lo, pk)
        else:
            s_keys, order = table
            row, found = kops.sorted_lookup(s_keys, order, pk)
        if self.how == "mark":
            out = t.with_column(self.mark_name, Column(found, BOOL))
        elif self.how == "semi":
            out, valid = t, valid & found
        elif self.how == "anti":
            out, valid = t, valid & ~found
        else:  # inner
            cols = dict(t.columns)
            # clip bound comes from the traced build-array shape, never a
            # python constant: a cached region replayed with a fresh (same
            # bucket) build table must not clamp to the old row count
            safe = jnp.clip(row, 0, build_arrays[0].shape[0] - 1)
            for (name, kind, dt, dct), arr in zip(self.build_meta,
                                                  build_arrays):
                if name not in cols:
                    cols[name] = Column(
                        jnp.take(arr, safe),  # padded tail never referenced
                        kind, dct)
            out, valid = Table(cols), valid & found
        if self.post_filter is not None:
            valid = valid & evaluate(self.post_filter, out).data
        return out, valid

    def _dicts(self):
        return [(n, d) for n, k, dt, d in self.build_meta]


# ---------------------------------------------------------------------------
# compiled region (cached across queries by signature)
# ---------------------------------------------------------------------------


class _CompiledRegion:
    def __init__(self, compiler: "PipelineCompiler", items, in_meta):
        self.compiler = compiler
        self.items = items
        self.in_meta = in_meta            # tuple of (name, kind, dictionary)
        self.out_meta = None              # recorded at trace time
        self.failed = False
        self.dict_refs: List = []         # pins dictionary ids for the cache key
        self.cost = None                  # lazy HLO cost summary (analyze mode)
        self._costing = False
        self.jitted = jax.jit(self._run)

    def cost_summary(self, arrays, valid, aux) -> dict:
        """Estimated FLOPs/bytes of this region's compiled XLA program.

        Lowers + compiles the region once more through the AOT path (the
        jit execution cache keeps the hot path untouched), then runs the
        roofline's HLO analyses over the optimized text: loop-corrected
        matmul FLOPs (``launch.hlo_analysis.dot_flops``) maxed with XLA's
        own ``cost_analysis`` flops, plus the HBM bytes-accessed estimate.
        Computed lazily — only ``analyze=True`` asks — and cached per
        region, so each signature pays the extra compile once.
        """
        if self.cost is None:
            from ..launch.hlo_analysis import (
                hbm_traffic_estimate, loop_corrected_flops,
            )
            try:
                self._costing = True
                compiled = self.jitted.lower(arrays, valid, aux).compile()
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0] if ca else {}
                ca = dict(ca or {})
                flops = loop_corrected_flops(
                    compiled.as_text(), float(ca.get("flops", 0.0)))["flops"]
                self.cost = {"est_flops": float(flops),
                             "est_bytes": float(hbm_traffic_estimate(ca))}
            except Exception:  # noqa: BLE001 — cost estimation must never fail a query
                self.cost = {}
            finally:
                self._costing = False
        return self.cost

    def _run(self, arrays, valid, aux):
        # runs at trace time only; execution replays the compiled XLA program
        if not self._costing:          # cost-analysis relower is not a trace
            self.compiler.stats["traces"] += 1
            METRICS.counter("pipeline_compiler.traces").inc()
        t = Table({name: Column(arr, kind, dct)
                   for (name, kind, dct), arr in zip(self.in_meta, arrays)})
        ai = 0
        for item in self.items:
            a = None
            if isinstance(item, _FusedProbe):
                a = aux[ai]
                ai += 1
            t, valid = item.apply(t, valid, a)
        self.out_meta = tuple((n, c.kind, c.dictionary)
                              for n, c in t.columns.items())
        # compaction happens inside the compiled region (cumsum-scatter +
        # gather); only the surviving-row count crosses to host
        idx = jnp.nonzero(valid, size=valid.shape[0], fill_value=0)[0]
        return (tuple(jnp.take(c.data, idx, axis=0)
                      for c in t.columns.values()), valid.sum())


class FusedSegment:
    """A per-execution runnable: pads → compiled region → one compaction."""

    def __init__(self, compiler: "PipelineCompiler", items, eager_ops, aux):
        self.compiler = compiler
        self.items = items
        self.eager_ops = eager_ops        # fallback path (same semantics)
        self.aux = tuple(aux)
        # the items half of the cache key never changes for this segment;
        # rendering expression signatures per call was pure warm-path tax
        self._items_sig = tuple(i.signature() for i in items)
        # per-call telemetry for the analyze path: FusedSegments are built
        # fresh for every pipeline execution (see ``prepare``), so stashing
        # the last call's region/args here is race-free
        self.last_call_info: Optional[dict] = None

    def describe(self) -> str:
        kinds = {"_FusedFilter": "filter", "_FusedSelect": "select",
                 "_FusedProject": "project", "_FusedProbe": "probe"}
        return "FusedRegion[" + "+".join(
            kinds.get(type(i).__name__, "?") for i in self.items) + "]"

    def _eager(self, t: Table) -> Table:
        for op in self.eager_ops:
            t = op(t)
        return t

    def __call__(self, t: Table) -> Table:
        sig = (self._items_sig, _table_signature(t))
        region = self.compiler.cache.get(sig)
        cache_hit = region is not None
        if region is None:
            in_meta = tuple((n, c.kind, c.dictionary)
                            for n, c in t.columns.items())
            region = _CompiledRegion(self.compiler, self.items, in_meta)
            # pin every dictionary object participating in the signature so
            # its id() can never be recycled onto a different dictionary
            region.dict_refs = [c.dictionary for c in t.columns.values()] + [
                d for item in self.items if isinstance(item, _FusedProbe)
                for _, d in item._dicts()]
            self.compiler.cache[sig] = region
            METRICS.counter("pipeline_compiler.cache_misses").inc()
        else:
            self.compiler.stats["cache_hits"] += 1
            METRICS.counter("pipeline_compiler.cache_hits").inc()
        if region.failed:
            self.last_call_info = {"cache_hit": cache_hit, "degraded": True}
            return self._eager(t)

        n = t.num_rows
        b = _bucket(n)
        arrays = tuple(_pad(c.data, b) for c in t.columns.values())
        valid = jnp.arange(b) < n
        try:
            if cache_hit:
                out_arrays, count = region.jitted(arrays, valid, self.aux)
            else:
                # first call on a fresh region dispatches the trace+compile
                # synchronously — its wall clock IS the compile cost
                t0 = time.perf_counter()
                out_arrays, count = region.jitted(arrays, valid, self.aux)
                dt = time.perf_counter() - t0
                self.compiler.stats["trace_seconds"] += dt
                METRICS.histogram("pipeline_compiler.trace_seconds").observe(dt)
        except Exception:  # noqa: BLE001 — degrade, never fail the query
            region.failed = True
            self.last_call_info = {"cache_hit": cache_hit, "degraded": True}
            return self._eager(t)
        self.compiler.stats["region_calls"] += 1
        METRICS.counter("pipeline_compiler.region_calls").inc()
        self.last_call_info = {
            "cache_hit": cache_hit, "degraded": False, "region": region,
            "cost_args": (arrays, valid, self.aux),
        }
        k = pull_scalar(count)   # the region's single scalar pull
        return Table({
            name: Column(arr[:k], kind, dct)
            for (name, kind, dct), arr in zip(region.out_meta, out_arrays)})


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------


class PipelineCompiler:
    """Owns the signature-keyed cache of compiled pipeline regions."""

    def __init__(self):
        self.cache: Dict[Tuple, _CompiledRegion] = {}
        self.stats = {"traces": 0, "cache_hits": 0, "region_calls": 0,
                      "fused_probes": 0, "eager_ops": 0, "trace_seconds": 0.0}

    # -- probe eligibility + device-side build ------------------------------
    def _lower_probe(self, op, backend) -> Optional[_FusedProbe]:
        rel = op.rel
        if rel.how not in ("inner", "semi", "anti", "mark"):
            return None
        if len(rel.probe_keys) != 1 or len(rel.build_keys) != 1:
            return None
        build = op.build_ref.table
        if build is None or build.num_rows == 0:
            return None
        bc = build[rel.build_keys[0]]
        if bc.kind not in (NUMERIC, DATE) or bc.data.dtype.kind not in "iu":
            return None
        bk = bc.data.astype(jnp.int64)
        n = build.num_rows
        nb = _bucket(n)
        valid = jnp.arange(nb) < n
        bk_p = _pad(bk, nb)

        if backend is not None:
            # Pallas kernel path: the sorted ranks double as the int32
            # factorization the probe kernel wants
            s, order, ranks, dup, sentinel_hit = kops.sorted_build(bk_p, valid)
            if pull_scalar(sentinel_hit) or (rel.how == "inner"
                                             and pull_scalar(dup)):
                return None
            b32 = jnp.where(valid, ranks, -1).astype(jnp.int32)
            sk, sr, placed = kops.build_table32(b32, valid)
            if not pull_scalar(placed):
                return None
            mode, table = "kernel", (s, sk, sr)
            backend.probe_hits += 1
        else:
            lo, hi, _ = kops.key_bounds(bk_p, valid)
            # one pull pair for build metadata (prepare-time only; the plan
            # cache replays prepared segments, never this lowering)
            lo_i, hi_i = pull_scalar(lo), pull_scalar(hi)
            domain = _bucket(hi_i - lo_i + 1)
            if domain <= max(1 << 16, 8 * nb):
                # dense key domain: sort-free direct-address build
                slot, dup = kops.direct_build(bk_p, valid, lo, domain)
                if rel.how == "inner" and pull_scalar(dup):
                    return None           # multi-match: eager join handles it
                mode, table = "direct", (slot, lo)
            else:
                # sparse keys: sorted binary-search build
                s, order, ranks, dup, sentinel_hit = kops.sorted_build(
                    bk_p, valid)
                if pull_scalar(sentinel_hit) or (rel.how == "inner"
                                                 and pull_scalar(dup)):
                    return None
                mode, table = "sorted", (s, order)
        build_meta = tuple((nm, c.kind, str(c.data.dtype), c.dictionary)
                           for nm, c in build.columns.items())
        build_arrays = tuple(_pad(c.data, nb)
                             for c in build.columns.values())
        fused = _FusedProbe(rel.probe_keys[0], rel.how, rel.mark_name,
                            rel.post_filter, build_meta, mode,
                            backend.interpret if backend is not None else True)
        fused._aux = (table, build_arrays)
        self.stats["fused_probes"] += 1
        METRICS.counter("pipeline_compiler.fused_probes").inc()
        return fused

    def prepare(self, ops: Sequence, backend=None) -> List:
        """Segment a pipeline's op chain into fused regions + eager ops.

        Called once per pipeline execution, after dependencies (build
        tables) have materialized; returns a list of callables Table→Table.
        """
        from .executor import FilterOp, ProbeOp, ProjectOp, SelectOp

        segments: List = []
        run_items: List = []
        run_ops: List = []
        run_aux: List = []

        def flush():
            if run_items:
                segments.append(FusedSegment(self, list(run_items),
                                             list(run_ops), list(run_aux)))
                run_items.clear(), run_ops.clear(), run_aux.clear()

        for op in ops:
            lowered = None
            if isinstance(op, FilterOp):
                lowered = _FusedFilter(op.cond)
            elif isinstance(op, SelectOp):
                lowered = _FusedSelect(op.columns)
            elif isinstance(op, ProjectOp):
                lowered = _FusedProject(op.exprs, op.keep_input)
            elif isinstance(op, ProbeOp):
                lowered = self._lower_probe(op, backend)
                if lowered is not None:
                    run_aux.append(lowered._aux)
            if lowered is None:
                flush()
                segments.append(op)
                self.stats["eager_ops"] += 1
                METRICS.counter("pipeline_compiler.eager_ops").inc()
            else:
                run_items.append(lowered)
                run_ops.append(op)
        flush()
        return segments
