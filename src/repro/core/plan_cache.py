"""Executable-plan cache: the warm path (DESIGN.md §13).

Cold execution of a plan pays for work that has nothing to do with the data:
plan lowering, probe lowering (device-side build + eligibility pulls), fused
region trace/compile, and a host scalar sync for every dynamic cardinality
(filter counts, join output sizes, group counts).  For the steady-state
workload the paper targets — the same dashboard queries over registered,
immutable data — all of that is pure warm-path tax.

This module caches, per structural plan signature, an **executable plan**:
the lowered pipelines in topological order, each with its already-prepared
stage list (fused regions with build tables baked in as arguments) and the
sequence of scalar values the cold run pulled.  A warm run is then a loop
over closures: fetch source, dispatch the compiled stages, finalize the
sink — with every ``pull_scalar`` served from the recording instead of a
host sync (see ``core.instrument``).  The single host interaction left is
the query's final result materialization, into which the executor folds the
device-side ``value != recorded`` verification flags; any set flag (or a
structural ``ReplayMismatch``) invalidates the entry and re-runs cold.

Safety contract: registered data is immutable between ``register()`` calls,
and ``SiriusEngine.register`` clears this cache — so replayed cardinalities
are exact and the flags are a safety net, not a branch.  Pipelines whose
results are consumed *only* as fused-probe build arguments (captured into
region closures at prepare time) are skipped entirely on replay — re-running
them would produce arrays nothing reads.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional

from ..observability.metrics import METRICS


# ---------------------------------------------------------------------------
# structural plan signatures
# ---------------------------------------------------------------------------


def _render(v, emit) -> None:
    # Generic structural rendering: covers Rel, Expr, AggSpec, SortKey and
    # ScalarSubquery uniformly (anything dataclass-shaped).  Never compares
    # with ``==`` — Expr.__eq__ builds BinOp nodes.
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        emit(type(v).__name__)
        emit("(")
        for f in dataclasses.fields(v):
            emit(f.name)
            emit("=")
            _render(getattr(v, f.name), emit)
            emit(",")
        emit(")")
    elif isinstance(v, (list, tuple)):
        emit("[" if isinstance(v, list) else "(")
        for x in v:
            _render(x, emit)
            emit(",")
        emit("]" if isinstance(v, list) else ")")
    elif isinstance(v, dict):
        emit("{")
        for k, x in sorted(v.items(), key=lambda kv: repr(kv[0])):
            emit(repr(k))
            emit(":")
            _render(x, emit)
            emit(",")
        emit("}")
    else:
        emit(repr(v))


def plan_signature(plan) -> str:
    """Deterministic structural key for a Rel tree (pre-``_prepare``).

    Computed over the *unprepared* plan: ``_prepare`` resolves scalar
    subqueries in place, and callers (benchmarks, ``engine.sql``) hand the
    executor fresh plan objects per run — the signature must match across
    them, so it is purely structural, never identity- or text-based.
    """
    parts: List[str] = []
    _render(plan, parts.append)
    return "".join(parts)


# ---------------------------------------------------------------------------
# cache entries
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecordedPipeline:
    """One pipeline's precomputed dispatch slot in an executable plan.

    ``stages`` is the prepared callable list (fused regions + eager ops)
    from the cold run; ``values`` the scalar-pull recording; ``must_run``
    False marks dead replay work (results only live inside region
    closures)."""

    pipeline: object              # core.executor.Pipeline
    stages: List
    values: List
    fuse_scan_filter: bool
    must_run: bool = True


class ExecutablePlan:
    """A cached, replayable lowering of one plan (topological order)."""

    def __init__(self, pipelines: List[RecordedPipeline], final):
        self.pipelines = pipelines
        self.final = final            # the Pipeline owning the result sink
        self.hits = 0
        # whole-query AOT replay program (PipelineExecutor._compile_replay):
        # (compiled_fn, input layout, per-table column meta, output meta),
        # or None when the replay isn't traceable (host escapes) — the
        # closure loop below then serves warm runs
        self.compiled = None
        # table-name → BufferManager epoch at record time; a replay is only
        # valid while every scanned table is still the recorded generation
        # (direct ``buffers.cache_table`` re-caches bump the epoch without
        # going through ``register``'s cache clear)
        self.epochs: Dict[str, int] = {}
        self._mark_must_run()

    def _mark_must_run(self) -> None:
        """Dead-work elimination for replay: a pipeline must run iff a
        *live* consumer reads its sink result at call time — as a pipeline
        source, or through an eager (unfused) ProbeOp's build_ref.  Fused
        probes captured the padded build arrays at prepare time, so their
        build pipelines are pure dead work warm.  Processed in reverse
        topological order so skipping propagates upstream."""
        from .executor import ProbeOp

        producer: Dict[int, int] = {
            id(rp.pipeline.sink.result): i
            for i, rp in enumerate(self.pipelines)}
        for rp in self.pipelines:
            rp.must_run = rp.pipeline is self.final
        for i in range(len(self.pipelines) - 1, -1, -1):
            rp = self.pipelines[i]
            if not rp.must_run:
                continue
            j = producer.get(id(rp.pipeline.source))
            if j is not None:
                self.pipelines[j].must_run = True
            for stage in rp.stages:
                if isinstance(stage, ProbeOp):
                    j = producer.get(id(stage.build_ref))
                    if j is not None:
                        self.pipelines[j].must_run = True


class PlanCache:
    """LRU map: plan signature → ExecutablePlan (cleared on register())."""

    def __init__(self, max_entries: int = 256, metrics=None):
        self.max_entries = max_entries
        # instance-scoped registry (per-shard engines): defaults to the
        # process-global METRICS
        self.metrics = metrics if metrics is not None else METRICS
        self._entries: "OrderedDict[str, ExecutablePlan]" = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "inserts": 0, "evictions": 0,
                      "invalidations": 0, "replay_mismatches": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, sig: str) -> Optional[ExecutablePlan]:
        entry = self._entries.get(sig)
        if entry is None:
            self.stats["misses"] += 1
            self.metrics.counter("plan_cache.misses").inc()
            return None
        self._entries.move_to_end(sig)
        self.stats["hits"] += 1
        entry.hits += 1
        self.metrics.counter("plan_cache.hits").inc()
        return entry

    def store(self, sig: str, entry: ExecutablePlan) -> None:
        self._entries[sig] = entry
        self._entries.move_to_end(sig)
        self.stats["inserts"] += 1
        self.metrics.counter("plan_cache.inserts").inc()
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats["evictions"] += 1
            self.metrics.counter("plan_cache.evictions").inc()

    def invalidate(self, sig: str, mismatch: bool = False) -> None:
        if self._entries.pop(sig, None) is not None:
            self.stats["invalidations"] += 1
            self.metrics.counter("plan_cache.invalidations").inc()
        if mismatch:
            self.stats["replay_mismatches"] += 1
            self.metrics.counter("plan_cache.replay_mismatches").inc()

    def clear(self) -> None:
        if self._entries:
            self.stats["invalidations"] += len(self._entries)
            self.metrics.counter("plan_cache.invalidations").inc(
                len(self._entries))
        self._entries.clear()
