"""Distributed query execution (paper §3.2.4, §3.3 'Distributed').

Mirrors the Doris+Sirius lifecycle: a host-side **coordinator** dispatches
plan *fragments*; each fragment executes SPMD on the shard mesh as one or
more compiled shard_map steps (kind = compute | exchange, timed separately
for the Table-2 breakdown); intermediate results cross fragments through the
**exchange registry** of temp tables, which is also the checkpoint boundary.

Like the paper's prototype, distributed mode covers a subset of TPC-H —
Q1/Q3/Q6 (the paper's own evaluation set) plus Q12 (ours, going beyond) —
while single-node mode covers all 22.  Unlike the paper ("does not support
avg"), distributed avg works here (sum/count decomposition).

Fault tolerance (paper future work §3.4, implemented here): fragment-level
retry, registry checkpointing + restart, elastic downsizing to a smaller
mesh on (injected) node failure, speculative re-execution of stragglers, and
shuffle-overflow retry with doubled bucket capacity.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..exchange.service import Frame, broadcast, partition_hash, shuffle
from ..relational.table import date_to_days
from ..runtime.checkpoint import RegistryCheckpointer
from ..runtime.control import (
    FaultInjector, HeartbeatMonitor, SimulatedNodeFailure, SpeculativeRunner,
)
from .static_ops import local_sort_agg, static_inner_join, static_semi_join, static_topk

MIX64 = -7046029254386353131


class ExchangeOverflow(RuntimeError):
    pass


def np_partition_hash(keys: np.ndarray, n: int) -> np.ndarray:
    """Host twin of exchange.service.partition_hash (must agree bit-for-bit)."""
    with np.errstate(over="ignore"):
        h = keys.astype(np.int64) * np.int64(MIX64)
        h = (h >> 33) ^ h
    return ((h % n) + n) % n


def encode_host_table(cols: Dict[str, np.ndarray]):
    """Host format → engine encoding (codes / days / numerics) + dictionaries."""
    enc, dicts = {}, {}
    for name, v in cols.items():
        if v.dtype.kind in "UO":
            d, codes = np.unique(np.asarray(v, "U"), return_inverse=True)
            enc[name] = codes.astype(np.int32)
            dicts[name] = d
        elif v.dtype.kind == "M":
            enc[name] = (v.astype("datetime64[D]")
                         - np.datetime64("1970-01-01", "D")).astype(np.int32)
        else:
            enc[name] = v
    return enc, dicts


def _round_up(x: int, m: int = 128) -> int:
    return max(((x + m - 1) // m) * m, m)


class DistributedEngine:
    """SPMD TPC-H over a ('data',) mesh with the exchange service layer."""

    PARTITION_KEYS = {
        "lineitem": "l_partkey",   # co-located with part, NOT with orders —
        "orders": "o_custkey",     # forces Q3 to shuffle both sides (paper §4.3)
        "customer": "c_custkey",
        "part": "p_partkey",
        "supplier": "s_suppkey",
        "partsupp": "ps_partkey",
    }
    SUPPORTED = (1, 3, 6, 12)

    def __init__(self, db: Dict[str, Dict[str, np.ndarray]],
                 n_shards: Optional[int] = None,
                 checkpoint_dir: Optional[str] = None,
                 injector: Optional[FaultInjector] = None,
                 shuffle_slack: float = 2.0,
                 predicate_transfer: bool = False):
        self.db = db
        self.predicate_transfer = predicate_transfer
        devices = jax.devices()
        self.n_shards = n_shards or len(devices)
        if self.n_shards > len(devices):
            raise ValueError("n_shards exceeds device count")
        self.shuffle_slack = shuffle_slack
        self.injector = injector or FaultInjector()
        self.speculative = SpeculativeRunner()
        self.checkpointer = (RegistryCheckpointer(checkpoint_dir)
                             if checkpoint_dir else None)
        self.timers: Dict[str, float] = defaultdict(float)
        self.recoveries = 0
        self._build_mesh()
        self._load()

    # -- data plane ----------------------------------------------------------
    def _build_mesh(self):
        devices = jax.devices()[: self.n_shards]
        self.mesh = Mesh(np.array(devices), ("data",))
        self.heartbeat = HeartbeatMonitor(self.n_shards)

    def _load(self):
        """Partition + encode + device-put base tables (cold run)."""
        self.tables: Dict[str, dict] = {}
        self.dicts: Dict[Tuple[str, str], np.ndarray] = {}
        for tname, key in self.PARTITION_KEYS.items():
            enc, dicts = encode_host_table(self.db[tname])
            for cname, d in dicts.items():
                self.dicts[(tname, cname)] = d
            self.tables[tname] = self._shard_rows(enc, key)

    def _shard_rows(self, enc: Dict[str, np.ndarray], key: str) -> dict:
        n = self.n_shards
        pid = np_partition_hash(enc[key].astype(np.int64), n)
        counts = np.bincount(pid, minlength=n)
        cap = _round_up(int(counts.max()))
        order = np.argsort(pid, kind="stable")
        offs = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=offs[1:])
        cols = {}
        for cname, v in enc.items():
            buf = np.zeros((n * cap,), v.dtype)
            for s in range(n):
                rows = order[offs[s]: offs[s + 1]]
                buf[s * cap: s * cap + len(rows)] = v[rows]
            cols[cname] = jnp.asarray(buf)
        valid = np.zeros((n * cap,), bool)
        for s in range(n):
            valid[s * cap: s * cap + counts[s]] = True
        return {"cols": cols, "valid": jnp.asarray(valid), "cap": cap,
                "partition_key": key}

    def _frame_from_registry(self, entry: dict) -> dict:
        return self._shard_rows(entry["rows"], entry["partition_key"])

    def _commit(self, registry: dict, name: str, frame_arrays: Dict[str, np.ndarray],
                valid: np.ndarray, partition_key: str):
        """Compact valid rows host-side into the temp-table registry (§3.2.4)."""
        sel = np.nonzero(np.asarray(valid))[0]
        rows = {k: np.asarray(v)[sel] for k, v in frame_arrays.items()}
        registry[name] = {"rows": rows, "partition_key": partition_key}

    # -- timing ---------------------------------------------------------------
    def _timed(self, kind: str, fn: Callable, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        self.timers[kind] += time.perf_counter() - t0
        return out

    def _smap(self, fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    # -- coordinator ------------------------------------------------------------
    def run_query(self, qid: int, resume: bool = False):
        if qid not in self.SUPPORTED:
            raise NotImplementedError(
                f"distributed mode supports {self.SUPPORTED} (paper-style "
                f"subset); use the single-node engine for Q{qid}")
        t_start = time.perf_counter()
        self.timers = defaultdict(float)
        program = getattr(self, f"_program_q{qid}")()
        names = [n for n, _ in program]
        registry: dict = {}
        idx = 0
        if resume and self.checkpointer:
            loaded = self.checkpointer.load_latest(names)
            if loaded:
                done_frag, registry = loaded
                idx = names.index(done_frag) + 1
                self.timers["resumed_from"] = idx
        final = None
        attempts = 0
        while idx < len(program):
            name, fn = program[idx]
            attempts += 1
            if attempts > 3 * len(program) + 10:
                raise RuntimeError("fragment retry budget exhausted")
            try:
                self.injector.before_fragment(name)
                delay = self.injector.straggle(name)
                out, _who = self.speculative.run(
                    name, lambda: fn(registry), injected_delay_s=delay)
            except SimulatedNodeFailure as e:
                self.heartbeat.kill(e.node)
                self._elastic_recover()
                program = getattr(self, f"_program_q{qid}")()
                continue
            except ExchangeOverflow:
                self.shuffle_slack *= 2.0
                program = getattr(self, f"_program_q{qid}")()
                continue
            if out is not None:
                final = out
            if self.checkpointer and idx < len(program) - 1:
                self.checkpointer.save(name, registry)
            idx += 1
        total = time.perf_counter() - t_start
        self.timers["other"] = max(
            total - self.timers["compute"] - self.timers["exchange"], 0.0)
        self.timers["total"] = total
        # publish phase timers into the process-wide registry so distributed
        # runs show up next to single-device telemetry
        from ..observability.metrics import METRICS
        for kind, secs in self.timers.items():
            if isinstance(secs, (int, float)) and kind != "resumed_from":
                METRICS.counter(f"distributed.{kind}_seconds").inc(secs)
        METRICS.histogram("distributed.query_seconds").observe(total)
        return final

    def _elastic_recover(self):
        """Node loss → rebuild a smaller mesh and re-shard the base tables.

        Registry snapshots are host-side compacted rows, so they re-shard
        transparently via _frame_from_registry on the new mesh.
        """
        live = max(self.n_shards - 1, 1)
        self.recoveries += 1
        self.n_shards = live
        self._build_mesh()
        self._load()

    # -- shared step builders ----------------------------------------------------
    def _shuffle_step(self, n_cols: int, out_cap: int):
        def step(cols: dict, valid, key):
            fr = Frame(cols, valid)
            out, overflow = shuffle(fr, key, "data", out_cap)
            return out.columns, out.valid, overflow
        return self._smap(
            step,
            in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P()))

    def _out_cap(self, shard_cap: int) -> int:
        per_dest = int(shard_cap * self.shuffle_slack / self.n_shards) + 8
        return _round_up(per_dest, 8)

    # =========================================================================
    # Q1 — scan+filter+group(9)+psum (merge exchange)
    # =========================================================================
    def _program_q1(self):
        li = self.tables["lineitem"]
        rf_dict = self.dicts[("lineitem", "l_returnflag")]
        ls_dict = self.dicts[("lineitem", "l_linestatus")]
        G = len(rf_dict) * len(ls_dict)
        cutoff = date_to_days("1998-09-02")
        ls_card = len(ls_dict)

        def compute(cols, valid):
            mask = valid & (cols["l_shipdate"] <= cutoff)
            gid = (cols["l_returnflag"].astype(jnp.int32) * ls_card
                   + cols["l_linestatus"].astype(jnp.int32))
            gid = jnp.where(mask, gid, G)
            ext = cols["l_extendedprice"]
            disc = cols["l_discount"]
            disc_price = ext * (1.0 - disc)
            charge = disc_price * (1.0 + cols["l_tax"])
            vals = jnp.stack([cols["l_quantity"], ext, disc_price, charge,
                              disc, jnp.ones_like(ext)], axis=1)
            vals = jnp.where(mask[:, None], vals, 0.0)
            return jax.ops.segment_sum(vals, gid, G + 1)[:G]

        def reduce_(partials):   # merge exchange: psum across shards
            return jax.lax.psum(partials.reshape(G, 6), "data")

        fcompute = self._smap(compute, in_specs=(P("data"), P("data")),
                              out_specs=P("data"))
        freduce = self._smap(reduce_, in_specs=P("data"), out_specs=P())

        def frag(registry):
            partials = self._timed("compute", fcompute, li["cols"], li["valid"])
            sums = np.asarray(self._timed("exchange", freduce, partials))
            # coordinator finalize ('other'): decode groups, avgs, order
            rows = []
            for rf in range(len(rf_dict)):
                for ls in range(ls_card):
                    g = rf * ls_card + ls
                    cnt = sums[g, 5]
                    if cnt == 0:
                        continue
                    rows.append((rf_dict[rf], ls_dict[ls], sums[g, 0],
                                 sums[g, 1], sums[g, 2], sums[g, 3],
                                 sums[g, 0] / cnt, sums[g, 1] / cnt,
                                 sums[g, 4] / cnt, int(cnt)))
            rows.sort(key=lambda r: (r[0], r[1]))
            names = ["l_returnflag", "l_linestatus", "sum_qty",
                     "sum_base_price", "sum_disc_price", "sum_charge",
                     "avg_qty", "avg_price", "avg_disc", "count_order"]
            return {n: np.asarray([r[i] for r in rows])
                    for i, n in enumerate(names)}

        return [("q1_agg", frag)]

    # =========================================================================
    # Q6 — scan+filter+scalar sum
    # =========================================================================
    def _program_q6(self):
        li = self.tables["lineitem"]
        lo = date_to_days("1994-01-01")
        hi = date_to_days("1995-01-01")

        def compute(cols, valid):
            m = (valid & (cols["l_shipdate"] >= lo) & (cols["l_shipdate"] < hi)
                 & (cols["l_discount"] >= 0.05) & (cols["l_discount"] <= 0.07)
                 & (cols["l_quantity"] < 24.0))
            rev = jnp.where(m, cols["l_extendedprice"] * cols["l_discount"], 0.0)
            return rev.sum()[None]

        def reduce_(x):
            return jax.lax.psum(x.reshape(()), "data")[None]

        fcompute = self._smap(compute, in_specs=(P("data"), P("data")),
                              out_specs=P("data"))
        freduce = self._smap(reduce_, in_specs=P("data"), out_specs=P())

        def frag(registry):
            part = self._timed("compute", fcompute, li["cols"], li["valid"])
            rev = self._timed("exchange", freduce, part)
            return {"revenue": np.asarray(rev)}

        return [("q6_sum", frag)]

    # =========================================================================
    # Q3 — semi(co-located) + shuffle both sides + join + agg + top-k
    # =========================================================================
    def _program_q3(self):
        cutoff = date_to_days("1995-03-15")
        seg_dict = self.dicts[("customer", "c_mktsegment")]
        seg_code = int(np.searchsorted(seg_dict, "BUILDING"))
        pt = self.predicate_transfer
        bloom_bits = 1 << 20

        def frag_orders(registry):
            from ..exchange.bloom import bloom_build, bloom_or_across
            cust = self.tables["customer"]
            orders = self.tables["orders"]
            o_cap = orders["cap"]
            out_cap = self._out_cap(o_cap)

            def compute(ccols, cvalid, ocols, ovalid):
                cmask = cvalid & (ccols["c_mktsegment"] == seg_code)
                fr = Frame({k: ocols[k] for k in
                            ("o_orderkey", "o_orderdate", "o_shippriority")},
                           ovalid & (ocols["o_orderdate"] < cutoff))
                # co-partitioned on custkey → local semi join
                fr = static_semi_join(fr, ocols["o_custkey"],
                                      ccols["c_custkey"], cmask)
                bloom = jnp.zeros((1,), jnp.uint8)
                if pt:   # predicate transfer: OR-combined key filter
                    bloom = bloom_or_across(
                        bloom_build(fr.columns["o_orderkey"], fr.valid,
                                    bloom_bits), ("data",))
                return fr.columns, fr.valid, bloom

            fcompute = self._smap(
                compute, in_specs=(P("data"),) * 4,
                out_specs=(P("data"), P("data"), P()))
            fshuffle = self._shuffle_step(3, out_cap)

            cols, valid, bloom = self._timed(
                "compute", fcompute, cust["cols"], cust["valid"],
                orders["cols"], orders["valid"])
            scols, svalid, overflow = self._timed(
                "exchange", fshuffle, cols, valid,
                cols["o_orderkey"])
            if int(np.asarray(overflow)) > 0:
                raise ExchangeOverflow
            self._commit(registry, "q3_orders_sh", scols, svalid, "o_orderkey")
            if pt:
                registry["q3_bloom"] = {"rows": {"bits": np.asarray(bloom)},
                                        "partition_key": None}
            return None

        def frag_join(registry):
            from ..exchange.bloom import bloom_maybe_contains
            li = self.tables["lineitem"]
            orders_sh = self._frame_from_registry(registry["q3_orders_sh"])
            # predicate transfer tightens the shuffle cardinality estimate
            # (overflow-retry protects if the estimate is ever wrong)
            out_cap = self._out_cap(li["cap"] // 4 if pt else li["cap"])
            TOPK = 10
            bloom = (jnp.asarray(registry["q3_bloom"]["rows"]["bits"])
                     if pt else None)

            def compute_filter(cols, valid):
                m = valid & (cols["l_shipdate"] > cutoff)
                if pt:   # prune non-joining rows BEFORE the shuffle
                    m = m & bloom_maybe_contains(bloom, cols["l_orderkey"])
                keep = {k: cols[k] for k in
                        ("l_orderkey", "l_extendedprice", "l_discount")}
                return keep, m

            def compute_join(lcols, lvalid, ocols, ovalid):
                lfr = Frame(lcols, lvalid)
                ofr = Frame(ocols, ovalid)
                j = static_inner_join(lfr, lcols["l_orderkey"], ofr,
                                      ocols["o_orderkey"])
                rev = (j.columns["l_extendedprice"]
                       * (1.0 - j.columns["l_discount"]))
                agg, _ = local_sort_agg(
                    j, j.columns["l_orderkey"], sums={"revenue": rev},
                    firsts={"o_orderdate": j.columns["o_orderdate"],
                            "o_shippriority": j.columns["o_shippriority"]})
                top = static_topk(agg, agg.columns["revenue"], TOPK)
                return (top.columns["key"], top.columns["revenue"],
                        top.columns["o_orderdate"],
                        top.columns["o_shippriority"], top.valid)

            ffilter = self._smap(compute_filter,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=(P("data"), P("data")))
            fshuffle = self._shuffle_step(3, out_cap)
            fjoin = self._smap(compute_join, in_specs=(P("data"),) * 4,
                               out_specs=(P("data"),) * 5)

            lcols, lvalid = self._timed(
                "compute", ffilter, li["cols"], li["valid"])
            scols, svalid, overflow = self._timed(
                "exchange", fshuffle, lcols, lvalid, lcols["l_orderkey"])
            if int(np.asarray(overflow)) > 0:
                raise ExchangeOverflow
            okey, rev, odate, oship, valid = self._timed(
                "compute", fjoin, scols, svalid,
                orders_sh["cols"], orders_sh["valid"])
            self._commit(registry, "q3_cands",
                         {"l_orderkey": okey, "revenue": rev,
                          "o_orderdate": odate, "o_shippriority": oship},
                         valid, "l_orderkey")
            return None

        def frag_final(registry):
            rows = registry["q3_cands"]["rows"]
            order = np.lexsort((rows["l_orderkey"], rows["o_orderdate"],
                                -rows["revenue"]))[:10]
            epoch = np.datetime64("1970-01-01", "D")
            return {
                "l_orderkey": rows["l_orderkey"][order],
                "revenue": rows["revenue"][order],
                "o_orderdate": epoch + rows["o_orderdate"][order].astype(
                    "timedelta64[D]"),
                "o_shippriority": rows["o_shippriority"][order],
            }

        return [("q3_orders", frag_orders), ("q3_join", frag_join),
                ("q3_final", frag_final)]

    # =========================================================================
    # Q12 — shuffle join + small-group agg (beyond the paper's subset)
    # =========================================================================
    def _program_q12(self):
        mode_dict = self.dicts[("lineitem", "l_shipmode")]
        prio_dict = self.dicts[("orders", "o_orderpriority")]
        mail = int(np.searchsorted(mode_dict, "MAIL"))
        ship = int(np.searchsorted(mode_dict, "SHIP"))
        urgent = int(np.searchsorted(prio_dict, "1-URGENT"))
        high = int(np.searchsorted(prio_dict, "2-HIGH"))
        lo = date_to_days("1994-01-01")
        hi = date_to_days("1995-01-01")
        M = len(mode_dict)

        def frag_orders(registry):
            orders = self.tables["orders"]
            out_cap = self._out_cap(orders["cap"])

            def compute(cols, valid):
                keep = {k: cols[k] for k in ("o_orderkey", "o_orderpriority")}
                return keep, valid

            f = self._smap(compute, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")))
            fshuffle = self._shuffle_step(2, out_cap)
            cols, valid = self._timed("compute", f, orders["cols"],
                                      orders["valid"])
            scols, svalid, overflow = self._timed(
                "exchange", fshuffle, cols, valid, cols["o_orderkey"])
            if int(np.asarray(overflow)) > 0:
                raise ExchangeOverflow
            self._commit(registry, "q12_orders_sh", scols, svalid,
                         "o_orderkey")
            return None

        def frag_join(registry):
            li = self.tables["lineitem"]
            orders_sh = self._frame_from_registry(registry["q12_orders_sh"])
            out_cap = self._out_cap(li["cap"])

            def compute_filter(cols, valid):
                m = (valid
                     & ((cols["l_shipmode"] == mail) | (cols["l_shipmode"] == ship))
                     & (cols["l_commitdate"] < cols["l_receiptdate"])
                     & (cols["l_shipdate"] < cols["l_commitdate"])
                     & (cols["l_receiptdate"] >= lo)
                     & (cols["l_receiptdate"] < hi))
                keep = {k: cols[k] for k in ("l_orderkey", "l_shipmode")}
                return keep, m

            def compute_join(lcols, lvalid, ocols, ovalid):
                lfr = Frame(lcols, lvalid)
                ofr = Frame(ocols, ovalid)
                j = static_inner_join(lfr, lcols["l_orderkey"], ofr,
                                      ocols["o_orderkey"])
                pr = j.columns["o_orderpriority"]
                ishigh = (pr == urgent) | (pr == high)
                gid = jnp.where(j.valid, j.columns["l_shipmode"].astype(
                    jnp.int32), M)
                hi_ = jax.ops.segment_sum(
                    jnp.where(j.valid & ishigh, 1.0, 0.0), gid, M + 1)[:M]
                lo_ = jax.ops.segment_sum(
                    jnp.where(j.valid & ~ishigh, 1.0, 0.0), gid, M + 1)[:M]
                return jnp.stack([hi_, lo_], axis=1)

            def reduce_(x):
                return jax.lax.psum(x.reshape(M, 2), "data")

            ffilter = self._smap(compute_filter,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=(P("data"), P("data")))
            fshuffle = self._shuffle_step(2, out_cap)
            fjoin = self._smap(compute_join, in_specs=(P("data"),) * 4,
                               out_specs=P("data"))
            freduce = self._smap(reduce_, in_specs=P("data"), out_specs=P())

            lcols, lvalid = self._timed("compute", ffilter, li["cols"],
                                        li["valid"])
            scols, svalid, overflow = self._timed(
                "exchange", fshuffle, lcols, lvalid, lcols["l_orderkey"])
            if int(np.asarray(overflow)) > 0:
                raise ExchangeOverflow
            partials = self._timed("compute", fjoin, scols, svalid,
                                   orders_sh["cols"], orders_sh["valid"])
            sums = np.asarray(self._timed("exchange", freduce, partials))
            out_rows = []
            for code in sorted([mail, ship]):
                out_rows.append((mode_dict[code], sums[code, 0], sums[code, 1]))
            return {
                "l_shipmode": np.asarray([r[0] for r in out_rows]),
                "high_line_count": np.asarray([r[1] for r in out_rows]),
                "low_line_count": np.asarray([r[2] for r in out_rows]),
            }

        return [("q12_orders", frag_orders), ("q12_join", frag_join)]
