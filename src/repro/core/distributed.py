"""Distributed query execution (paper §3.2.4, §3.3 'Distributed').

Mirrors the Doris+Sirius lifecycle: a host-side **coordinator** takes any
optimized plan, runs the exchange-placement pass
(``optimizer.exchange.place_exchanges``) to insert shuffle / broadcast /
merge boundaries, cuts the plan into fragments at those boundaries, and
dispatches the fragments in dependency order.  Each shard fragment compiles
through the regular pipeline executor over its shard's partition (one
shared region compiler, so pow2-bucketed kernel shapes are reused across
shards and queries), and every exchange runs as a real ``shard_map``
collective from ``exchange.service`` over the ``('data',)`` mesh — the
compute/exchange split is timed separately for the Table-2 breakdown.

Intermediate results cross fragments through the **exchange registry** of
temp tables (compacted host rows + partition key), which is also the
checkpoint boundary: snapshots re-shard onto any mesh size, which is what
makes elastic downsizing possible.  Unlike the paper's prototype
("does not support avg"), distributed avg works here (sum/count
decomposition in the placement pass), and the whole 22-query TPC-H +
15-query ClickBench set runs distributed — not a 4-query subset.

Fault tolerance (paper future work §3.4, implemented here): fragment-level
retry, registry checkpointing + restart, elastic downsizing to a smaller
mesh on (injected) node failure, speculative re-execution of stragglers, and
shuffle-overflow retry with doubled bucket capacity.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..exchange.service import (
    Frame, broadcast, compiled_shard_map, shuffle,
)
from ..kernels import ops as kops
from ..observability.dist import skew_ratio
from ..observability.journal import JOURNAL
from ..observability.metrics import METRICS, MetricsRegistry
from ..optimizer.exchange import (
    DIST_BOUNDARY_PREFIX, HASH, REP, ExchangeFragment, Partitioning,
    boundary_name, cut_fragments, place_exchanges,
)
from ..relational.expressions import Expr, Lit
from ..relational.table import Table
from ..runtime.checkpoint import RegistryCheckpointer
from ..runtime.control import (
    FaultInjector, HeartbeatMonitor, SimulatedNodeFailure, SpeculativeRunner,
)
from .fallback import FallbackEngine
from .plan import (
    ReadRel, Rel, ScalarSubquery, plan_from_json, plan_to_json, walk,
    walk_deep,
)

MIX64 = -7046029254386353131


class ExchangeOverflow(RuntimeError):
    pass


def np_partition_hash(keys: np.ndarray, n: int) -> np.ndarray:
    """Host twin of exchange.service.partition_hash (must agree bit-for-bit)."""
    with np.errstate(over="ignore"):
        h = keys.astype(np.int64) * np.int64(MIX64)
        h = (h >> 33) ^ h
    return ((h % n) + n) % n


def _fnv1a(s: str) -> int:
    h = 0xCBF29CE484222325
    for b in s.encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h - (1 << 64) if h >= (1 << 63) else h


def key_to_int64(v: np.ndarray) -> np.ndarray:
    """Deterministic int64 surrogate for any partition-key dtype.

    Used identically for base-table partitioning, registry re-partitioning
    and the device shuffle's key column, so two tables hashed on equal key
    *values* always co-locate — even string keys across different
    dictionaries (per-value FNV-1a, not dictionary codes).
    """
    v = np.asarray(v)
    if v.dtype.kind in "UO":
        uniq, inv = np.unique(np.asarray(v, "U"), return_inverse=True)
        h = np.array([_fnv1a(s) for s in uniq], np.int64)
        return h[inv] if len(uniq) else np.zeros(0, np.int64)
    if v.dtype.kind == "M":
        return (v.astype("datetime64[D]")
                - np.datetime64("1970-01-01", "D")).astype(np.int64)
    if v.dtype.kind == "f":
        # normalize -0.0 so equal float keys share a bit pattern
        return (v.astype(np.float64) + 0.0).view(np.int64)
    return v.astype(np.int64)


def encode_host_table(cols: Dict[str, np.ndarray]):
    """Host format → engine encoding (codes / days / numerics) + dictionaries."""
    enc, dicts = {}, {}
    for name, v in cols.items():
        if v.dtype.kind in "UO":
            d, codes = np.unique(np.asarray(v, "U"), return_inverse=True)
            enc[name] = codes.astype(np.int32)
            dicts[name] = d
        elif v.dtype.kind == "M":
            enc[name] = (v.astype("datetime64[D]")
                         - np.datetime64("1970-01-01", "D")).astype(np.int32)
        else:
            enc[name] = v
    return enc, dicts


class _DbCatalog:
    """Stats-layer adapter over the actual host database (exact row counts
    — the coordinator owns the data, so the placement pass plans against
    real cardinalities, not schema guesses)."""

    def __init__(self, db: Dict[str, Dict[str, np.ndarray]]):
        self.db = db

    def has_table(self, t: str) -> bool:
        return t in self.db

    def columns(self, t: str) -> List[str]:
        return list(self.db[t].keys())

    def row_estimate(self, t: str) -> float:
        cols = self.db.get(t)
        if not cols:
            return 1e3
        return float(len(next(iter(cols.values()))))

    def dictionary_for(self, name: str):
        return None


def _frag_label(frag: ExchangeFragment) -> str:
    return frag.label


class DistributedEngine:
    """SPMD SQL over a ('data',) mesh: generic ``run_plan`` for every
    optimized plan, with the exchange service layer moving rows."""

    PARTITION_KEYS = {
        "lineitem": "l_partkey",   # co-located with part, NOT with orders —
        "orders": "o_custkey",     # forces orderkey joins to exchange (§4.3)
        "customer": "c_custkey",
        "part": "p_partkey",
        "supplier": "s_suppkey",
        "partsupp": "ps_partkey",
        "hits": "userid",          # ClickBench fact table
    }

    def __init__(self, db: Dict[str, Dict[str, np.ndarray]],
                 n_shards: Optional[int] = None,
                 checkpoint_dir: Optional[str] = None,
                 injector: Optional[FaultInjector] = None,
                 shuffle_slack: float = 2.0,
                 predicate_transfer: bool = False,
                 use_kernels: Optional[bool] = None,
                 partition_keys: Optional[Dict[str, str]] = None):
        self.db = db
        self.predicate_transfer = predicate_transfer
        devices = jax.devices()
        self.n_shards = n_shards or len(devices)
        if self.n_shards > len(devices):
            raise ValueError("n_shards exceeds device count")
        self.shuffle_slack = shuffle_slack
        self.injector = injector or FaultInjector()
        self.speculative = SpeculativeRunner()
        self.checkpointer = (RegistryCheckpointer(checkpoint_dir)
                             if checkpoint_dir else None)
        self.use_kernels = (bool(int(os.environ.get("REPRO_USE_KERNELS", "0")))
                            if use_kernels is None else use_kernels)
        self.partition_keys = dict(self.PARTITION_KEYS
                                   if partition_keys is None else partition_keys)
        self.catalog = _DbCatalog(db)
        self.timers: Dict[str, float] = defaultdict(float)
        self.recoveries = 0
        # per-query exchange telemetry: one dict per collective commit
        # {fragment, kind, key, bytes_per_shard, skew_ratio, ...} — what
        # the benchmark driver embeds into BENCH_tpch.json
        self.exchange_stats: List[dict] = []
        # journal query ID of the most recent run_plan/run_query
        self.last_query_id: Optional[str] = None
        # compile seconds the most recent _exec_one_shard incurred (used
        # by _run_fragment_shards to attribute compile vs compute)
        self._last_shard_compile_s = 0.0
        self._shard_engines: List = []
        self._region_compiler = None   # shared across shards/queries
        self._collective_cache: Dict[tuple, Callable] = {}
        self._build_mesh()
        self._load()

    # -- data plane ----------------------------------------------------------
    def _build_mesh(self):
        devices = jax.devices()[: self.n_shards]
        self.mesh = Mesh(np.array(devices), ("data",))
        self.heartbeat = HeartbeatMonitor(self.n_shards)
        self._collective_cache.clear()
        self._shard_engines = []

    def _load(self):
        """Encode each base table once into a master device Table (shared
        dictionaries → cross-shard pipeline-region reuse) plus per-shard
        row indices for hash-partitioned tables; tables without a
        partition key are replicated (every shard reads the master)."""
        self.tables: Dict[str, dict] = {}
        for name, cols in self.db.items():
            key = self.partition_keys.get(name)
            entry = {"master": Table.from_pydict(cols), "key": key,
                     "shard_idx": None, "slices": {}}
            if key is not None and key in cols:
                pid = np_partition_hash(key_to_int64(np.asarray(cols[key])),
                                        self.n_shards)
                entry["shard_idx"] = [np.nonzero(pid == s)[0]
                                      for s in range(self.n_shards)]
            self.tables[name] = entry

    def table_partitionings(self) -> Dict[str, Partitioning]:
        out = {}
        for name, entry in self.tables.items():
            out[name] = (Partitioning(HASH, entry["key"])
                         if entry["shard_idx"] is not None
                         else Partitioning(REP))
        return out

    def _base_table(self, name: str, shard: int, full: bool) -> Table:
        entry = self.tables[name]
        if full or entry["shard_idx"] is None:
            return entry["master"]
        t = entry["slices"].get(shard)
        if t is None:
            t = entry["master"].take(jnp.asarray(entry["shard_idx"][shard]))
            entry["slices"][shard] = t
        return t

    def _boundary_table(self, name: str, producer: ExchangeFragment,
                        registry: dict, shard: int, full: bool) -> Table:
        entry = registry[name]
        cache = entry.setdefault("_device", {})
        master = cache.get("master")
        if master is None:
            master = Table.from_pydict(entry["rows"])
            cache["master"] = master
        if full or producer.kind != "shuffle":
            return master
        key = entry["partition_key"]
        idx = cache.get(("idx", self.n_shards))
        if idx is None:
            pid = np_partition_hash(key_to_int64(entry["rows"][key]),
                                    self.n_shards)
            idx = [np.nonzero(pid == s)[0] for s in range(self.n_shards)]
            cache[("idx", self.n_shards)] = idx
        slot = ("slice", self.n_shards, shard)
        t = cache.get(slot)
        if t is None:
            t = master.take(jnp.asarray(idx[shard]))
            cache[slot] = t
        return t

    # -- timing ---------------------------------------------------------------
    def _timed(self, kind: str, fn: Callable, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        self.timers[kind] += time.perf_counter() - t0
        return out

    # -- planning -------------------------------------------------------------
    def plan_fragments(self, plan: Rel) -> List[ExchangeFragment]:
        """Exchange placement + fragment cutting for ``plan`` (pure)."""
        plan = plan_from_json(plan_to_json(plan))
        placed = place_exchanges(plan, self.catalog, self.n_shards,
                                 self.table_partitionings())
        return cut_fragments(placed)

    def program_names(self, plan_or_qid) -> List[str]:
        """Fragment names ``run_plan`` will execute for a plan (or TPC-H
        query id) — the handles fault-injection plans target."""
        plan = plan_or_qid
        if isinstance(plan_or_qid, int):
            from ..data.tpch_queries import QUERIES
            plan = QUERIES[plan_or_qid]()
        return [_frag_label(f) for f in self.plan_fragments(plan)]

    # -- coordinator ----------------------------------------------------------
    def run_query(self, qid: int, resume: bool = False):
        """Distributed TPC-H by query id.  A ``_program_q{qid}`` attribute,
        if present, overrides the generic path with a hand-built program
        (kept as a hook for tests); everything else goes through
        ``run_plan`` on the standard plan."""
        override = getattr(self, f"_program_q{qid}", None)
        if override is not None:
            t_start = time.perf_counter()
            self.timers = defaultdict(float)
            self.exchange_stats = []
            with JOURNAL.query_span("distributed.query",
                                    shards=self.n_shards,
                                    program=f"q{qid}") as jq:
                final = self._run_program(override, resume=resume)
                self.last_query_id = jq.query_id
            self._publish(t_start)
            return final
        from ..data.tpch_queries import QUERIES
        if qid not in QUERIES:
            raise NotImplementedError(f"unknown TPC-H query {qid}")
        return self.run_plan(QUERIES[qid](), resume=resume)

    def run_plan(self, plan: Rel, resume: bool = False):
        """Execute any optimized plan distributed; returns host columns.

        The whole run roots one journal query tree: fragment attempts,
        per-shard engine runs, collectives, retries, recoveries and
        checkpoints all land under ``self.last_query_id``."""
        t_start = time.perf_counter()
        self.timers = defaultdict(float)
        self.exchange_stats = []
        with JOURNAL.query_span("distributed.query",
                                shards=self.n_shards) as jq:
            out = self._run_plan_inner(plan, resume=resume, top=True)
            self.last_query_id = jq.query_id
            jq.set(exchanges=len(self.exchange_stats),
                   recoveries=self.recoveries)
        self._publish(t_start)
        return out

    def _run_plan_inner(self, plan: Rel, resume: bool = False,
                        top: bool = False):
        plan = plan_from_json(plan_to_json(plan))   # private mutable copy
        self._resolve_subqueries(plan)
        # fragments are fixed for the life of the query: elastic downsizing
        # and overflow retries rebuild closures, not the plan cut, so
        # fragment names stay stable for checkpoints and fault plans
        fragments = self.plan_fragments(plan)

        def build():
            return [(_frag_label(f), self._make_fragment_fn(f, fragments))
                    for f in fragments]

        return self._run_program(build, resume=resume,
                                 checkpoint=top)

    def _resolve_subqueries(self, plan: Rel) -> None:
        """Run scalar subquery plans (distributed, recursively) and splice
        their values in as literals — the executor's contract."""
        def resolve(e):
            if isinstance(e, ScalarSubquery):
                rows = self._run_plan_inner(e.plan)
                val = np.asarray(rows[e.column]).reshape(-1)
                return Lit(float(val[0]) if val.dtype.kind == "f"
                           else int(val[0]))
            if dataclasses.is_dataclass(e) and isinstance(e, Expr):
                for f in dataclasses.fields(e):
                    v = getattr(e, f.name)
                    if isinstance(v, Expr):
                        setattr(e, f.name, resolve(v))
                    elif isinstance(v, (list, tuple)) and v and \
                            isinstance(v[0], tuple):
                        setattr(e, f.name, [
                            tuple(resolve(x) if isinstance(x, Expr) else x
                                  for x in w) for w in v])
            return e

        for rel in walk(plan):
            for f in dataclasses.fields(rel):
                v = getattr(rel, f.name)
                if isinstance(v, Expr):
                    setattr(rel, f.name, resolve(v))
                elif isinstance(v, list) and v and isinstance(v[0], tuple) \
                        and len(v[0]) == 2 and isinstance(v[0][1], Expr):
                    setattr(rel, f.name, [(n, resolve(e)) for n, e in v])
                elif isinstance(v, list):
                    for item in v:
                        if dataclasses.is_dataclass(item) and \
                                isinstance(getattr(item, "expr", None), Expr):
                            item.expr = resolve(item.expr)

    def _run_program(self, build_program, resume: bool = False,
                     checkpoint: bool = True):
        """The fragment dispatch loop: retry budget, elastic recovery on
        node failure, slack doubling on exchange overflow, checkpoint after
        every non-final fragment, speculative straggler re-execution."""
        program = build_program()
        names = [n for n, _ in program]
        registry: dict = {}
        idx = 0
        if resume and self.checkpointer:
            loaded = self.checkpointer.load_latest(names)
            if loaded:
                done_frag, registry = loaded
                idx = names.index(done_frag) + 1
                self.timers["resumed_from"] = idx
        final = None
        attempts = 0
        frag_attempts: Dict[str, int] = defaultdict(int)
        while idx < len(program):
            name, fn = program[idx]
            attempt = frag_attempts[name]
            frag_attempts[name] += 1
            attempts += 1
            if attempts > 3 * len(program) + 10:
                raise RuntimeError("fragment retry budget exhausted")
            fattrs = getattr(fn, "_journal_attrs", {})
            try:
                with JOURNAL.span(name, "fragment", fragment=name,
                                  attempt=attempt, **fattrs):
                    self.injector.before_fragment(name)
                    delay = self.injector.straggle(name)
                    # fragments run on SpeculativeRunner threads: carry
                    # this loop's trace context over so shard/exchange
                    # spans land in the query tree, with each replica
                    # (primary or speculative backup) as its own span
                    ctx = JOURNAL.current_context()
                    self._frag_attempt = attempt

                    def run_replica(who, body, _name=name, _ctx=ctx):
                        with JOURNAL.activate(_ctx):
                            with JOURNAL.span(f"{_name}:{who}", "attempt",
                                              fragment=_name, replica=who):
                                return body()

                    out, who = self.speculative.run(
                        name, lambda: fn(registry), injected_delay_s=delay,
                        wrap=run_replica)
                    if who == "backup":
                        JOURNAL.event("speculative_backup", "recovery",
                                      fragment=name, attempt=attempt)
            except SimulatedNodeFailure as e:
                self.heartbeat.kill(e.node)
                JOURNAL.event("elastic_rebuild", "recovery", fragment=name,
                              node=e.node, shards_next=max(
                                  self.n_shards - 1, 1))
                self._elastic_recover()
                program = build_program()
                continue
            except ExchangeOverflow:
                JOURNAL.event("overflow_retry", "recovery", fragment=name,
                              slack_next=self.shuffle_slack * 2.0)
                self.shuffle_slack *= 2.0
                program = build_program()
                continue
            if out is not None:
                final = out
            if checkpoint and self.checkpointer and idx < len(program) - 1:
                with JOURNAL.span("checkpoint", "checkpoint", fragment=name):
                    self.checkpointer.save(name, registry)
            idx += 1
        return final

    def _publish(self, t_start: float):
        total = time.perf_counter() - t_start
        self.timers["other"] = max(
            total - self.timers["compute"] - self.timers["exchange"]
            - self.timers["compile"], 0.0)
        self.timers["total"] = total
        # phase timers land in the process-wide registry so distributed
        # runs show up next to single-device telemetry
        for kind, secs in self.timers.items():
            if isinstance(secs, (int, float)) and kind != "resumed_from":
                METRICS.counter(f"distributed.{kind}_seconds").inc(secs)
        METRICS.histogram("distributed.query_seconds").observe(total)

    def _elastic_recover(self):
        """Node loss → rebuild a smaller mesh and re-shard the base tables.

        Registry snapshots are host-side compacted rows, so they re-shard
        transparently on the new mesh at the next boundary read.
        """
        live = max(self.n_shards - 1, 1)
        self.recoveries += 1
        self.n_shards = live
        self._build_mesh()
        self._load()

    # -- fragment execution ---------------------------------------------------
    def _make_fragment_fn(self, frag: ExchangeFragment,
                          fragments: List[ExchangeFragment]):
        def fn(registry):
            if frag.placement == "coordinator":
                with JOURNAL.span(f"{frag.label}@coordinator", "coordinator",
                                  fragment=frag.label):
                    return self._run_coordinator(frag, registry)
            outs = self._run_fragment_shards(frag, fragments, registry)
            self._commit_exchange(frag, outs, registry)
            return None
        fn._journal_attrs = {"placement": frag.placement,
                             "kind": frag.kind or "final"}
        return fn

    def _shard_engine(self, shard: int):
        from .executor import SiriusEngine
        while len(self._shard_engines) <= shard:
            idx = len(self._shard_engines)
            # each pooled engine gets its own registry, labeled into the
            # process-global METRICS (``distributed.shard<i>.*``) — shard
            # metrics stay separable instead of colliding in one flat
            # namespace, and ``aggregate_labeled`` restores the global view
            reg = MetricsRegistry(parent=METRICS,
                                  label=f"distributed.shard{idx}")
            eng = SiriusEngine(use_kernels=self.use_kernels, num_workers=1,
                               metrics=reg)
            # boundary temp tables change under a constant plan signature,
            # so warm replays would poison — trace each execution instead
            eng.executor.cache_enabled = False
            if self._region_compiler is None:
                self._region_compiler = eng.executor.compiler
            else:
                eng.executor.compiler = self._region_compiler
            self._shard_engines.append(eng)
        return self._shard_engines[shard]

    def _run_fragment_shards(self, frag: ExchangeFragment,
                             fragments: List[ExchangeFragment],
                             registry: dict) -> List[Dict[str, np.ndarray]]:
        producers = {boundary_name(f.fid): f for f in fragments}
        needed, seen = [], set()
        for rel in walk_deep(frag.plan):
            if isinstance(rel, ReadRel) and rel.table not in seen:
                seen.add(rel.table)
                needed.append(rel.table)
        shards = [0] if frag.run_once else list(range(self.n_shards))
        outs = []
        for s in shards:
            tables = {}
            for tname in needed:
                if tname.startswith(DIST_BOUNDARY_PREFIX):
                    tables[tname] = self._boundary_table(
                        tname, producers[tname], registry, s,
                        full=frag.run_once)
                else:
                    tables[tname] = self._base_table(tname, s,
                                                     full=frag.run_once)
            t0 = time.perf_counter()
            with JOURNAL.span(f"{frag.label}@shard{s}", "shard",
                              fragment=frag.label, shard=s,
                              attempt=getattr(self, "_frag_attempt", 0)):
                rows = self._exec_one_shard(frag.plan, tables, s)
            dt = time.perf_counter() - t0
            # compile (region trace) time the shard engine incurred is not
            # compute — attribute it to its own phase timer so the
            # Table-2-style breakdown stops billing cold traces as compute
            compile_s = min(self._last_shard_compile_s, dt)
            self.timers["compute"] += dt - compile_s
            self.timers["compile"] += compile_s
            METRICS.counter(
                f"distributed.shard{s}.compute_seconds").inc(dt - compile_s)
            if compile_s:
                METRICS.counter(
                    f"distributed.shard{s}.compile_seconds").inc(compile_s)
            outs.append(rows)
        return outs

    def _exec_one_shard(self, plan: Rel, tables: Dict[str, Table],
                        shard: int) -> Dict[str, np.ndarray]:
        eng = self._shard_engine(shard)
        self._last_shard_compile_s = 0.0
        try:
            for name, t in tables.items():
                eng.register(name, t)
            out = eng.execute(plan)
            # surface the fragment's true trace/compile tax to the caller
            # (executor.last_compile_seconds is per-execute)
            self._last_shard_compile_s = eng.executor.last_compile_seconds
            return out.to_host()
        except Exception as exc:  # noqa: BLE001 — degrade this shard to the host path
            METRICS.counter("distributed.shard_fallbacks").inc()
            JOURNAL.event("shard_fallback", "shard", shard=shard,
                          reason=type(exc).__name__)
            host = {name: t.to_host() for name, t in tables.items()}
            return FallbackEngine(host).execute(plan)

    def _run_coordinator(self, frag: ExchangeFragment, registry: dict):
        """Root fragment: merged registry rows + full base tables on the
        host engine (which also covers window/set rels the device engine
        does not lower)."""
        tables: Dict[str, Dict[str, np.ndarray]] = dict(self.db)
        for name, entry in registry.items():
            tables[name] = entry["rows"]
        return FallbackEngine(tables).execute(frag.plan)

    # -- exchange collectives -------------------------------------------------
    def _out_cap(self, shard_cap: int) -> int:
        per_dest = int(shard_cap * self.shuffle_slack / self.n_shards) + 8
        return kops.bucket_size(per_dest, minimum=8)

    @staticmethod
    def _rows_bytes(rows: Dict[str, np.ndarray]) -> int:
        return int(sum(np.asarray(v).nbytes for v in rows.values()))

    def _commit_exchange(self, frag: ExchangeFragment,
                         outs: List[Dict[str, np.ndarray]], registry: dict):
        name = boundary_name(frag.fid)
        if frag.run_once and frag.kind in ("broadcast", "merge"):
            # producer already holds the complete result — a logical
            # exchange with zero wire cost, still journaled for the tree
            registry[name] = {"rows": outs[0], "partition_key": None}
            self._record_exchange(frag, frag.kind, None,
                                  [self._rows_bytes(outs[0])], 0.0, None)
            return
        if frag.run_once:
            # replicated producer feeding a shuffle: source the collective
            # from shard 0, the rest contribute empty frames
            empty = {c: np.asarray(v)[:0] for c, v in outs[0].items()}
            outs = [outs[0]] + [dict(empty) for _ in range(self.n_shards - 1)]
        kind = frag.kind or "merge"
        key = frag.keys[0] if frag.kind == "shuffle" else None
        with JOURNAL.span(f"exchange:{frag.label}", "exchange",
                          fragment=frag.label, kind=kind, key=key) as sp:
            t0 = time.perf_counter()
            if kind == "shuffle":
                outs = self._predicate_transfer(frag, outs, registry)
                rows = self._collective(outs, "shuffle", key)
                registry[name] = {"rows": rows, "partition_key": key}
                # skew is about what each shard *receives* post-partition:
                # re-derive the destination row distribution from the
                # merged rows (host-side, same hash as the collective)
                counts = np.bincount(
                    np_partition_hash(key_to_int64(rows[key]),
                                      self.n_shards),
                    minlength=self.n_shards)
                total_rows = int(counts.sum())
                bpr = self._rows_bytes(rows) / max(total_rows, 1)
                bytes_per_shard = [int(c * bpr) for c in counts]
            else:
                rows = self._collective(outs, kind, None)
                registry[name] = {"rows": rows, "partition_key": None}
                # broadcast/merge replicate everything: the interesting
                # distribution is what each producer shard contributed
                bytes_per_shard = [self._rows_bytes(r) for r in outs]
            wall = time.perf_counter() - t0
            stat = self._record_exchange(frag, kind, key, bytes_per_shard,
                                         wall, len(next(iter(rows.values()))))
            sp.set(**{k: v for k, v in stat.items() if k != "wall_s"})

    def _record_exchange(self, frag: ExchangeFragment, kind: str,
                         key: Optional[str], bytes_per_shard: List[int],
                         wall: float, rows_out: Optional[int]) -> dict:
        stat = {
            "fragment": frag.label, "kind": kind, "key": key,
            "bytes_per_shard": [int(b) for b in bytes_per_shard],
            "skew_ratio": round(skew_ratio(bytes_per_shard), 4),
            "rows_out": int(rows_out) if rows_out is not None else None,
            "wall_s": round(wall, 6),
        }
        self.exchange_stats.append(stat)
        return stat

    def exchange_summary(self) -> List[dict]:
        """One row per exchange for the last query: speculative replicas
        commit the same (idempotent) exchange twice, so keep the latest
        entry per fragment — that is also the post-retry slack on
        overflow-retried shuffles."""
        latest: Dict[str, dict] = {}
        for stat in self.exchange_stats:
            latest[stat["fragment"]] = stat
        return list(latest.values())

    def _predicate_transfer(self, frag, outs, registry):
        """Semi-filter shuffle rows by a committed build side's keys before
        the collective (the Doris 'predicate transfer' sideways pass) —
        correctness-neutral for the inner/semi joins it is planned on."""
        if not (self.predicate_transfer and frag.pt):
            return outs
        bfid, pk, bk = frag.pt
        bentry = registry.get(boundary_name(bfid))
        if bentry is None or bk not in bentry["rows"] or \
                any(pk not in rows for rows in outs):
            return outs
        bkeys = np.unique(key_to_int64(bentry["rows"][bk]))
        pruned, filtered = 0, []
        for rows in outs:
            m = np.isin(key_to_int64(rows[pk]), bkeys)
            pruned += int((~m).sum())
            filtered.append({c: np.asarray(v)[m] for c, v in rows.items()})
        METRICS.counter("distributed.predicate_transfer_rows_pruned").inc(pruned)
        return filtered

    def _wire_encode(self, outs: List[Dict[str, np.ndarray]]):
        """Unify dtypes across shards and encode strings/dates to device
        integers; returns (encoded shards, decode metadata)."""
        cols = list(outs[0].keys())
        enc = [dict() for _ in outs]
        meta: Dict[str, tuple] = {}
        for c in cols:
            vals = [np.asarray(rows[c]) for rows in outs]
            kinds = {v.dtype.kind for v in vals}
            if kinds & set("UO"):
                d = np.unique(np.concatenate(
                    [np.asarray(v, "U") for v in vals])) if any(
                        len(v) for v in vals) else np.zeros(0, "U1")
                for i, v in enumerate(vals):
                    enc[i][c] = np.searchsorted(
                        d, np.asarray(v, "U")).astype(np.int64)
                meta[c] = ("str", d)
            elif "M" in kinds:
                for i, v in enumerate(vals):
                    enc[i][c] = (v.astype("datetime64[D]") - np.datetime64(
                        "1970-01-01", "D")).astype(np.int64)
                meta[c] = ("date", None)
            else:
                dt = np.result_type(*[v.dtype for v in vals])
                for i, v in enumerate(vals):
                    enc[i][c] = v.astype(dt)
                meta[c] = ("raw", dt)
        return enc, meta

    def _wire_decode(self, rows: Dict[str, np.ndarray],
                     meta: Dict[str, tuple]) -> Dict[str, np.ndarray]:
        out = {}
        for c, v in rows.items():
            tag, extra = meta[c]
            if tag == "str":
                out[c] = extra[v.astype(np.int64)]
            elif tag == "date":
                out[c] = (np.datetime64("1970-01-01", "D")
                          + v.astype("timedelta64[D]"))
            else:
                out[c] = v.astype(extra)
        return out

    def _stack(self, enc: List[Dict[str, np.ndarray]]):
        """Pad-and-mask per-shard rows into (n*cap,) device buffers; cap is
        a pow2 bucket (matching the pipeline compiler) so jit shapes are
        reused even when shard row counts are uneven or prime."""
        n = len(enc)
        counts = [len(next(iter(rows.values()))) if rows else 0
                  for rows in enc]
        cap = kops.bucket_size(max(counts + [1]), minimum=128)
        cols = {}
        for c in enc[0]:
            buf = np.zeros((n * cap,), enc[0][c].dtype)
            for s in range(n):
                buf[s * cap: s * cap + counts[s]] = enc[s][c]
            cols[c] = jnp.asarray(buf)
        valid = np.zeros((n * cap,), bool)
        for s in range(n):
            valid[s * cap: s * cap + counts[s]] = True
        return cols, jnp.asarray(valid), cap

    def _collective_fn(self, kind: str, out_cap: Optional[int],
                       schema: tuple):
        sig = (kind, out_cap, self.n_shards, schema)
        fn = self._collective_cache.get(sig)
        if fn is not None:
            return fn
        if kind == "shuffle":
            def step(cols, valid, key):
                out, overflow = shuffle(Frame(cols, valid), key, "data",
                                        out_cap)
                return out.columns, out.valid, overflow
            fn = compiled_shard_map(
                step, self.mesh,
                in_specs=(P("data"), P("data"), P("data")),
                out_specs=(P("data"), P("data"), P()), label="shuffle")
        else:   # broadcast / merge: all rows everywhere, one copy returned
            def step(cols, valid):
                out = broadcast(Frame(cols, valid), "data")
                return out.columns, out.valid
            fn = compiled_shard_map(
                step, self.mesh,
                in_specs=(P("data"), P("data")),
                out_specs=(P(), P()), label=kind)
        self._collective_cache[sig] = fn
        return fn

    def _collective(self, outs: List[Dict[str, np.ndarray]], kind: str,
                    key: Optional[str]) -> Dict[str, np.ndarray]:
        """Run one exchange as a shard_map collective and return the
        compacted merged host rows for the registry."""
        enc, meta = self._wire_encode(outs)
        cols, valid, cap = self._stack(enc)
        schema = tuple(sorted((c, str(v.dtype)) for c, v in cols.items()))
        if kind == "shuffle":
            keys64 = [key_to_int64(rows[key]) for rows in outs]
            kcol, _, _ = self._stack([{"__k": k} for k in keys64])
            out_cap = self._out_cap(cap)
            fn = self._collective_fn("shuffle", out_cap, schema)
            scols, svalid, overflow = self._timed(
                "exchange", fn, cols, valid, kcol["__k"])
            if int(np.asarray(overflow)) > 0:
                raise ExchangeOverflow
        else:
            fn = self._collective_fn(kind, None, schema)
            scols, svalid = self._timed("exchange", fn, cols, valid)
        sel = np.nonzero(np.asarray(svalid))[0]
        rows = {c: np.asarray(v)[sel] for c, v in scols.items()}
        return self._wire_decode(rows, meta)
