"""Swappable operator backend: route eligible operators to Pallas kernels.

Sirius's modular design lets developers switch operator implementations
between libcudf and custom CUDA kernels (§3.2.2).  The analogue here: the
executor consults this backend first; when an operator instance matches a
kernel's contract it runs on the Pallas path, otherwise it falls through to
the generic jnp implementation.  Enabled via ``SiriusEngine(use_kernels=True)``.

Eligibility contracts:
  * filter  — conjunction of closed/open range predicates over numeric/date
              columns (Q1/Q6/Q19-style hot filters) → fused filter kernel.
  * probe   — single-column integer PK-FK inner/semi/anti/mark join →
              int32-factorized open-addressing probe kernel.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..relational.expressions import Between, BinOp, Col, Expr, Lit
from ..relational.table import DATE, NUMERIC, Column, Table


def _collect_range_conjuncts(e: Expr, out: List[Tuple[str, float, float]]) -> bool:
    """Flatten an AND tree of range predicates; False if any leaf is foreign."""
    if isinstance(e, BinOp) and e.op == "and":
        return (_collect_range_conjuncts(e.left, out)
                and _collect_range_conjuncts(e.right, out))
    if isinstance(e, Between) and isinstance(e.operand, Col) \
            and isinstance(e.lo, Lit) and isinstance(e.hi, Lit):
        out.append((e.operand.name, float(e.lo.value), float(e.hi.value)))
        return True
    if isinstance(e, BinOp) and isinstance(e.left, Col) and isinstance(e.right, Lit):
        v = e.right.value
        if isinstance(v, str):
            return False
        v = float(v)
        if e.right.kind == DATE:   # int day counts: exact ±1 steps
            below = v - 1.0
            above = v + 1.0
        else:                      # f32 lattice neighbours for strict bounds
            below = float(np.nextafter(np.float32(v), np.float32(-np.inf)))
            above = float(np.nextafter(np.float32(v), np.float32(np.inf)))
        if e.op == "<":
            out.append((e.left.name, -np.inf, below))
        elif e.op == "<=":
            out.append((e.left.name, -np.inf, v))
        elif e.op == ">":
            out.append((e.left.name, above, np.inf))
        elif e.op == ">=":
            out.append((e.left.name, v, np.inf))
        elif e.op == "==":
            out.append((e.left.name, v, v))
        else:
            return False
        return True
    return False


class KernelBackend:
    """Tracks usage so tests/benchmarks can assert the kernel path fired."""

    def __init__(self, interpret: bool = True):
        self.interpret = interpret
        self.filter_hits = 0
        self.probe_hits = 0

    # -- fused range filter ---------------------------------------------------
    def try_filter(self, cond: Expr, t: Table) -> Optional[Table]:
        conjuncts: List[Tuple[str, float, float]] = []
        if not _collect_range_conjuncts(cond, conjuncts) or not conjuncts:
            return None
        cols = []
        for name, _, _ in conjuncts:
            if name not in t:
                return None
            c = t[name]
            if c.kind not in (NUMERIC, DATE):
                return None
            data = np.asarray(c.data)
            if data.dtype.kind == "f":
                # f32 lanes: only exact below 2^24 — money columns are fine at
                # bench scale; bail out beyond to preserve exactness
                if np.abs(data).max(initial=0.0) >= 2**24:
                    return None
            elif np.abs(data).max(initial=0) >= 2**24:
                return None
            cols.append(data.astype(np.float32))
        mat = jnp.asarray(np.stack(cols, axis=1))
        lo = jnp.asarray([c[1] for c in conjuncts], jnp.float32)
        hi = jnp.asarray([c[2] for c in conjuncts], jnp.float32)
        idx, count = kops.filter_select(mat, lo, hi, interpret=self.interpret)
        self.filter_hits += 1
        return t.take(idx[: int(count)])

    # -- hash-probe join --------------------------------------------------------
    def try_probe(self, probe: Table, build: Table, probe_keys, build_keys,
                  how: str) -> Optional[Table]:
        if len(probe_keys) != 1 or how not in ("inner", "semi", "anti", "mark"):
            return None
        pc, bc = probe[probe_keys[0]], build[build_keys[0]]
        if pc.kind != NUMERIC or bc.kind != NUMERIC:
            return None
        bk = np.asarray(bc.data)
        pk = np.asarray(pc.data)
        if bk.dtype.kind not in "iu" or pk.dtype.kind not in "iu":
            return None
        if len(np.unique(bk)) != len(bk):   # kernel contract: unique build keys
            return None
        b32, p32 = kops.factorize_keys_int32(bk.astype(np.int64),
                                             pk.astype(np.int64))
        sk, sr, placed = kops.build_table32(jnp.asarray(b32))
        if not bool(placed):
            return None
        row, found = kops.hash_probe(jnp.asarray(p32), sk, sr,
                                     interpret=self.interpret)
        self.probe_hits += 1
        found_np = np.asarray(found)
        if how == "mark":
            return probe.with_column("__mark", Column(jnp.asarray(found_np), "bool"))
        if how == "semi":
            return probe.take(jnp.asarray(np.nonzero(found_np)[0]))
        if how == "anti":
            return probe.take(jnp.asarray(np.nonzero(~found_np)[0]))
        # inner: gather matched probe rows + matched build rows
        sel = np.nonzero(found_np)[0]
        out = {n: c.take(jnp.asarray(sel)) for n, c in probe.columns.items()}
        bidx = np.asarray(row)[sel]
        for n, c in build.columns.items():
            if n not in out:
                out[n] = c.take(jnp.asarray(bidx))
        return Table(out)
