"""Swappable operator backend: route eligible operators to Pallas kernels.

Sirius's modular design lets developers switch operator implementations
between libcudf and custom CUDA kernels (§3.2.2).  The analogue here: the
executor consults this backend first; when an operator instance matches a
kernel's contract it runs on the Pallas path, otherwise it falls through to
the generic jnp implementation.  Enabled via ``SiriusEngine(use_kernels=True)``.

Eligibility contracts (checked against device metadata — dtype/kind — plus
device-side reductions; no column is ever copied to host to decide):
  * filter    — conjunction of closed/open range predicates over numeric/date
                columns (Q1/Q6/Q19-style hot filters) → fused filter kernel.
  * probe     — single-column integer PK-FK inner/semi/anti/mark join →
                int32-factorized open-addressing probe kernel.
  * aggregate — group-by with int-factorizable keys (int/dictionary-code/
                date/bool) and sum/count/avg/min/max aggregates → the MXU
                one-hot-matmul kernel (``groupby_sum`` / ``groupby_sum_large``)
                for the additive aggregates, device segment ops for min/max.
  * expand    — the eager join's run expansion (multi-match inner/left) →
                the binary-search ``join_expand`` kernel; covers the joins
                the unique-key probe kernel cannot.
  * topk      — single-key ORDER BY + LIMIT over integer/date keys within
                the f32-exact range → the tie-stable ``topk_select`` kernel.

Numerical note for the MXU path: the kernel accumulates in f32, so each
additive column is centered by its f64 mean before the matmul (the
accumulator carries deviations instead of magnitudes) and split into an
f32 hi/lo pair whose f64 sum reproduces the centered value exactly
(sum = kernel_sum(hi) + kernel_sum(lo) + c·count).  Together these keep
the TPC-H money sums inside f64-oracle tolerance.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..observability.metrics import METRICS
from ..relational.aggregate import AggSpec, factorize_groups
from ..relational.expressions import Between, BinOp, Col, Expr, Lit, evaluate
from ..relational.table import BOOL, DATE, NUMERIC, STRING, Column, Table
from .instrument import pull_scalar


def _collect_range_conjuncts(e: Expr, out: List[Tuple[str, float, float]]) -> bool:
    """Flatten an AND tree of range predicates; False if any leaf is foreign."""
    if isinstance(e, BinOp) and e.op == "and":
        return (_collect_range_conjuncts(e.left, out)
                and _collect_range_conjuncts(e.right, out))
    if isinstance(e, Between) and isinstance(e.operand, Col) \
            and isinstance(e.lo, Lit) and isinstance(e.hi, Lit):
        out.append((e.operand.name, float(e.lo.value), float(e.hi.value)))
        return True
    if isinstance(e, BinOp) and isinstance(e.left, Col) and isinstance(e.right, Lit):
        v = e.right.value
        if isinstance(v, str):
            return False
        v = float(v)
        if e.right.kind == DATE:   # int day counts: exact ±1 steps
            below = v - 1.0
            above = v + 1.0
        else:                      # f32 lattice neighbours for strict bounds
            below = float(np.nextafter(np.float32(v), np.float32(-np.inf)))
            above = float(np.nextafter(np.float32(v), np.float32(np.inf)))
        if e.op == "<":
            out.append((e.left.name, -np.inf, below))
        elif e.op == "<=":
            out.append((e.left.name, -np.inf, v))
        elif e.op == ">":
            out.append((e.left.name, above, np.inf))
        elif e.op == ">=":
            out.append((e.left.name, v, np.inf))
        elif e.op == "==":
            out.append((e.left.name, v, v))
        else:
            return False
        return True
    return False


_MXU_FNS = ("sum", "count", "count_star", "avg")
_AGG_FNS = _MXU_FNS + ("min", "max")


class KernelBackend:
    """Tracks usage so tests/benchmarks can assert the kernel path fired."""

    def __init__(self, interpret: bool = True):
        self.interpret = interpret
        self.filter_hits = 0
        self.probe_hits = 0
        self.agg_hits = 0
        self.expand_hits = 0
        self.topk_hits = 0

    def hit_counts(self) -> dict:
        return dict(filter=self.filter_hits, probe=self.probe_hits,
                    agg=self.agg_hits, expand=self.expand_hits,
                    topk=self.topk_hits)

    # -- fused range filter ---------------------------------------------------
    def try_filter(self, cond: Expr, t: Table) -> Optional[Table]:
        conjuncts: List[Tuple[str, float, float]] = []
        if not _collect_range_conjuncts(cond, conjuncts) or not conjuncts:
            return None
        cols = []
        for name, _, _ in conjuncts:
            if name not in t:
                return None
            c = t[name]
            if c.kind not in (NUMERIC, DATE):
                return None
            if t.num_rows:
                # f32 lanes: only exact below 2^24 — device-side reduction,
                # scalar pull only (never a column copy to host)
                if pull_scalar(jnp.max(jnp.abs(c.data))) >= 2**24:
                    return None
            cols.append(c.data.astype(jnp.float32))
        mat = jnp.stack(cols, axis=1)
        lo = jnp.asarray([c[1] for c in conjuncts], jnp.float32)
        hi = jnp.asarray([c[2] for c in conjuncts], jnp.float32)
        idx, count = kops.filter_select(mat, lo, hi, interpret=self.interpret)
        self.filter_hits += 1
        METRICS.counter("kernel.filter_hits").inc()
        return t.take(idx[: pull_scalar(count)])

    # -- hash-probe join --------------------------------------------------------
    def try_probe(self, probe: Table, build: Table, probe_keys, build_keys,
                  how: str) -> Optional[Table]:
        if len(probe_keys) != 1 or how not in ("inner", "semi", "anti", "mark"):
            return None
        pc, bc = probe[probe_keys[0]], build[build_keys[0]]
        if pc.kind != NUMERIC or bc.kind != NUMERIC:
            return None
        bk, pk = bc.data, pc.data
        if bk.dtype.kind not in "iu" or pk.dtype.kind not in "iu":
            return None
        if bk.shape[0] == 0 or pk.shape[0] == 0:
            return None
        bk = bk.astype(jnp.int64)
        n = bk.shape[0]
        # device-side build (jit-cached, bucketed shapes): the sorted ranks
        # double as the int32 factorization and as the uniqueness check —
        # the kernel contract (unique build keys) never copies a column
        # to host to verify
        nb = kops.bucket_size(n)
        valid = jnp.arange(nb) < n
        s, _, ranks, dup, sentinel_hit = kops.sorted_build(
            kops.pad_rows(bk, nb), valid)
        if pull_scalar(dup) or pull_scalar(sentinel_hit):
            return None
        b32 = jnp.where(valid, ranks, -1).astype(jnp.int32)
        sk, sr, placed = kops.build_table32(b32, valid)
        if not pull_scalar(placed):
            return None
        p32 = kops.map_probe_keys_jit(s, pk.astype(jnp.int64))
        row, found = kops.hash_probe(p32, sk, sr, interpret=self.interpret)
        self.probe_hits += 1
        METRICS.counter("kernel.probe_hits").inc()
        if how == "mark":
            return probe.with_column("__mark", Column(found, BOOL))
        if how == "semi":
            sel, k = kops.compact(found)
            return probe.take(sel[: pull_scalar(k)])
        if how == "anti":
            sel, k = kops.compact(~found)
            return probe.take(sel[: pull_scalar(k)])
        # inner: gather matched probe rows + matched build rows
        sel, k = kops.compact(found)
        sel = sel[: pull_scalar(k)]
        out = {nm: c.take(sel) for nm, c in probe.columns.items()}
        bidx = row[sel]
        for nm, c in build.columns.items():
            if nm not in out:
                out[nm] = c.take(bidx)
        return Table(out)

    # -- MXU group-by aggregation ----------------------------------------------
    def try_aggregate(self, t: Table, keys: Sequence[str],
                      aggs: Sequence[AggSpec]) -> Optional[Table]:
        """Route an eligible group-by to the one-hot-matmul Pallas kernel.

        Additive aggregates (sum/count/avg) become columns of one (N, V)
        value matrix summed per group in a single ``groupby_sum`` call —
        low-cardinality group-bys, the GPU's atomic-contention worst case,
        are the MXU's best case.  min/max ride along as device segment ops.
        Returns None (caller falls back to the generic path) if any key or
        aggregate is outside the contract; all checks are metadata-level.
        """
        if t.num_rows == 0:
            return None
        if t.num_rows >= 2**24:
            # a group's f32 count is only exact below 2^24 rows (same
            # exactness bound try_filter enforces); bail out past it
            return None
        for k in keys:
            if k not in t or t[k].data.dtype.kind not in "iub":
                return None       # int-factorizable keys only (codes/dates/ints)
        if not aggs or any(a.fn not in _AGG_FNS for a in aggs):
            return None

        # evaluated aggregate inputs (device compute; dtype checks after)
        values: List[Optional[Column]] = []
        for a in aggs:
            if a.fn == "count_star":
                values.append(None)
                continue
            col = evaluate(a.expr, t)
            if a.fn in _MXU_FNS and (col.kind == STRING
                                     or col.data.dtype.kind not in "ifb"):
                return None
            values.append(col)

        gids, uniq = factorize_groups(t, keys)
        n_groups = uniq.num_rows if keys else 1

        # (N, V) MXU value matrix: ones column (counts) + centered additive
        # columns split into hi/lo f32 pairs (v - c == hi + lo exactly to
        # ~2^-46 relative), so the f32 accumulator carries neither the
        # magnitude (centering) nor the representation error (splitting).
        # Centering constants stay on device (f64 scalars).
        mxu_cols = [jnp.ones(t.num_rows, jnp.float32)]
        routes = []                      # per agg: (hi column index, center)
        for a, col in zip(aggs, values):
            if a.fn in ("sum", "avg"):
                data = col.data.astype(jnp.float64)
                c = jnp.mean(data)
                centered = data - c
                hi = centered.astype(jnp.float32)
                lo = (centered - hi.astype(jnp.float64)).astype(jnp.float32)
                mxu_cols.extend([hi, lo])
                routes.append((len(mxu_cols) - 2, c))
            else:
                routes.append((None, None))  # counts column or non-MXU agg

        # group-count bucketing keeps the kernel's static arg stable across
        # runs, so repeated queries reuse the compiled kernel
        g_call = max(128, 1 << (n_groups - 1).bit_length())
        acc = kops.groupby_sum_large(
            gids.astype(jnp.int32), jnp.stack(mxu_cols, axis=1), g_call,
            interpret=self.interpret)[:n_groups]
        counts = acc[:, 0].astype(jnp.float64)

        out = dict(uniq.columns)
        for a, col, (slot, center) in zip(aggs, values, routes):
            if a.fn in ("count", "count_star"):
                out[a.name] = Column(jnp.rint(counts).astype(jnp.int64), NUMERIC)
            elif a.fn in ("sum", "avg"):
                s = (acc[:, slot].astype(jnp.float64)
                     + acc[:, slot + 1].astype(jnp.float64)
                     + center * counts)
                if a.fn == "avg":
                    out[a.name] = Column(s / jnp.maximum(counts, 1.0), NUMERIC)
                elif col.data.dtype.kind in "ib":
                    out[a.name] = Column(jnp.rint(s).astype(jnp.int64), NUMERIC)
                else:
                    out[a.name] = Column(s, NUMERIC)
            else:                        # min / max: device segment ops
                seg = jax.ops.segment_min if a.fn == "min" else jax.ops.segment_max
                res = seg(col.data, gids, n_groups)
                out[a.name] = Column(res, col.kind,
                                     col.dictionary if col.kind == STRING else None)
        self.agg_hits += 1
        METRICS.counter("kernel.agg_hits").inc()
        return Table(out)

    # -- join run expansion ----------------------------------------------------
    def try_expand(self, order, lo, counts, counts_out, total: int):
        """Route the eager join's run expansion to the Pallas kernel.

        Called from ``relational.hash_join`` after match counting; the
        contract is purely shape-level (int32-addressable rows/outputs), so
        every multi-match inner/left join is kernel-eligible — the coverage
        gap the unique-key probe kernel left open.
        """
        if total >= 2**31 or lo.shape[0] >= 2**31 or order.shape[0] >= 2**31:
            return None
        out = kops.join_expand(order, lo, counts, counts_out, total,
                               interpret=self.interpret)
        self.expand_hits += 1
        METRICS.counter("kernel.expand_hits").inc()
        return out

    # -- top-k for ORDER BY + LIMIT --------------------------------------------
    def try_topk(self, t: Table, keys, limit) -> Optional[Table]:
        """Route an eligible ORDER BY + LIMIT to the top-k selection kernel.

        Contract: integer-coded sort keys (numeric ints, dates, or string
        dictionary codes — order-preserving, the same invariant the eager
        lexsort leans on) packed into one composite rank whose range stays
        f32-exact (the 2^24 bound the filter kernel uses), and a small k.
        Tie-stable against the generic lexsort, so results are row-exact.
        The per-key min/max pulls go through ``pull_scalar``, so warm
        replays stay sync-free.
        """
        if limit is None or not (0 < limit <= 128) or not keys:
            return None
        if any(k.name not in t for k in keys):
            return None
        n = t.num_rows
        if n <= limit:
            return None
        comps = []
        total = 1
        for k in keys:
            c = t[k.name]
            if c.data.dtype.kind not in "iu":
                return None
            lo = int(pull_scalar(jnp.min(c.data)))
            hi = int(pull_scalar(jnp.max(c.data)))
            span = hi - lo + 1
            v = c.data - lo
            if not k.ascending:
                v = (span - 1) - v
            comps.append((v, span))
            total *= span
            if total > 2**24:      # composite must stay exact in f32
                return None
        comp, _ = comps[0]
        for v, span in comps[1:]:
            comp = comp * span + v
        idx = kops.topk_select(comp.astype(jnp.float32), limit,
                               interpret=self.interpret)
        self.topk_hits += 1
        METRICS.counter("kernel.topk_hits").inc()
        return t.take(idx)
