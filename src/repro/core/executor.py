"""Push-based pipeline executor (paper §3.2.2).

The plan is decomposed into **pipelines** at breakers (join build side,
aggregation, sort).  Each pipeline is a task on a global queue; idle CPU
worker threads pull tasks whose dependencies have completed and drive them —
exactly the DuckDB/Hyper/Velox-style model the paper adopts.  Within a
pipeline execution is **push-based**: the executor owns all state (build
tables, partial agg inputs) and pushes morsels into stateless operator
callables.

Three execution modes (DESIGN.md "Compiled pipelines & device residency" +
§12 "Observability & EXPLAIN ANALYZE"):

* **default** — each pipeline's contiguous Filter/Project/Probe chain is
  fused into a single jitted region by ``pipeline_compiler`` (cached across
  queries by plan signature), operators dispatch asynchronously, and the
  executor syncs **once per pipeline sink**;
* **analyze=True** (per call) — the same fused regions, but with opt-in
  sync points at every region/operator boundary so each stage's wall time
  and rows in/out land in a ``QueryProfile`` (``executor.last_profile``).
  Pipelines are serialized (one worker) so operator wall clocks never
  overlap and per-operator times sum to ≤ the query total;
* **profile=True** (per engine) — the legacy pre-fusion path: every
  operator runs eagerly with a ``block_until_ready`` barrier and
  per-operator wall time accumulated for the Figure-5 breakdown benchmark
  (also recorded into a QueryProfile, so both paths report one format).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..buffer.manager import BufferManager
from ..observability import (
    JOURNAL, METRICS, OperatorProfile, PipelineProfile, ProfileBuilder,
    QueryProfile,
)
from ..relational.aggregate import group_aggregate
from ..relational.expressions import Expr, Lit, evaluate
from ..relational.join import hash_join
from ..relational.sort import sort_table
from ..relational.table import BOOL, Column, Table
from . import instrument
from .pipeline_compiler import FusedSegment, PipelineCompiler
from .plan_cache import ExecutablePlan, PlanCache, RecordedPipeline, plan_signature
from .plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SortRel, explain, walk,
)


# ---------------------------------------------------------------------------
# operators (stateless; executor pushes morsels through them)
# ---------------------------------------------------------------------------


class _Op:
    category = "other"

    def __call__(self, t: Table) -> Table:  # pragma: no cover - interface
        raise NotImplementedError


class FilterOp(_Op):
    category = "filter"

    def __init__(self, cond: Expr, backend=None):
        self.cond = cond
        self.backend = backend

    def __call__(self, t: Table) -> Table:
        if self.backend is not None:
            out = self.backend.try_filter(self.cond, t)
            if out is not None:
                return out
        mask = evaluate(self.cond, t)
        return t.filter_mask(mask.data)


class ProjectOp(_Op):
    category = "project"

    def __init__(self, exprs, keep_input=False):
        self.exprs = exprs
        self.keep_input = keep_input

    def __call__(self, t: Table) -> Table:
        cols = dict(t.columns) if self.keep_input else {}
        for name, e in self.exprs:
            cols[name] = evaluate(e, t)
        return Table(cols)


class SelectOp(_Op):
    """Column pruning as a pipeline op (deferred ReadRel projection: the
    scan keeps filter columns alive until the fused filter consumed them)."""

    category = "project"

    def __init__(self, columns):
        self.columns = list(columns)

    def __call__(self, t: Table) -> Table:
        return t.select([c for c in self.columns if c in t])


class ProbeOp(_Op):
    """Probe side of a hash join; the build table is executor state."""

    category = "join"

    def __init__(self, rel: JoinRel, build_ref: "_Result", backend=None):
        self.rel = rel
        self.build_ref = build_ref
        self.backend = backend

    def __call__(self, t: Table) -> Table:
        out = None
        if self.backend is not None:
            out = self.backend.try_probe(
                t, self.build_ref.table, self.rel.probe_keys,
                self.rel.build_keys, self.rel.how)
        if out is None:
            out = hash_join(
                t, self.build_ref.table, self.rel.probe_keys,
                self.rel.build_keys, self.rel.how, self.rel.mark_name,
                backend=self.backend,
            )
        if self.rel.post_filter is not None:
            mask = evaluate(self.rel.post_filter, out)
            out = out.filter_mask(mask.data)
        return out


# ---------------------------------------------------------------------------
# sinks (pipeline breakers)
# ---------------------------------------------------------------------------


class _Result:
    """Cross-pipeline handle for a breaker's materialized output."""

    def __init__(self):
        self.table: Optional[Table] = None


class _Sink:
    category = "other"

    def __init__(self, result: _Result):
        self.result = result
        self.parts: List[Table] = []

    def push(self, t: Table) -> None:
        self.parts.append(t)

    def reset(self) -> None:
        """Clear pushed parts for a plan-cache replay; the ``_Result``
        handle keeps its identity (downstream pipelines hold references)."""
        self.parts = []

    def _gathered(self) -> Table:
        return self.parts[0] if len(self.parts) == 1 else Table.concat(self.parts)

    def finalize(self) -> None:
        self.result.table = self._gathered()


class BuildSink(_Sink):
    category = "join"


class AggSink(_Sink):
    category = "groupby"

    def __init__(self, result: _Result, rel: AggregateRel, backend=None):
        super().__init__(result)
        self.rel = rel
        self.backend = backend

    def finalize(self) -> None:
        t = self._gathered()
        out = None
        if self.backend is not None:
            # MXU one-hot-matmul aggregation for eligible group-bys
            out = self.backend.try_aggregate(t, self.rel.group_keys,
                                             self.rel.aggs)
        if out is None:
            out = group_aggregate(t, self.rel.group_keys, self.rel.aggs)
        if self.rel.having is not None:
            mask = evaluate(self.rel.having, out)
            out = out.filter_mask(mask.data)
        self.result.table = out


class SortSink(_Sink):
    category = "orderby"

    def __init__(self, result: _Result, rel: SortRel, backend=None):
        super().__init__(result)
        self.rel = rel
        self.backend = backend

    def finalize(self) -> None:
        t = self._gathered()
        out = None
        if self.backend is not None:
            # Pallas top-k selection for ORDER BY + LIMIT (small k, one
            # integer key): row-exact vs the lexsort, ties and all
            out = self.backend.try_topk(t, self.rel.keys, self.rel.limit)
        if out is None:
            out = sort_table(t, self.rel.keys, self.rel.limit)
        self.result.table = out


class FetchSink(_Sink):
    def __init__(self, result: _Result, count: int):
        super().__init__(result)
        self.count = count

    def finalize(self) -> None:
        self.result.table = self._gathered().head(self.count)


# ---------------------------------------------------------------------------
# pipelines
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Pipeline:
    pid: int
    source: object                 # ReadRel | _Result
    ops: List[_Op]
    sink: _Sink
    deps: List[int]


class PlanLowering:
    """Decompose a Rel tree into pipelines (breaker analysis)."""

    def __init__(self, backend=None):
        self.pipelines: List[Pipeline] = []
        self.backend = backend

    def new_pipeline(self, source, deps) -> Pipeline:
        p = Pipeline(len(self.pipelines), source, [], None, list(deps))
        self.pipelines.append(p)
        return p

    def lower(self, rel: Rel) -> Pipeline:
        """Returns the pipeline whose sink produces ``rel``'s output."""
        p = self._stream(rel)
        if p.sink is None:
            p.sink = _Sink(_Result())
        return p

    def _stream(self, rel: Rel) -> Pipeline:
        if isinstance(rel, ReadRel):
            return self.new_pipeline(rel, [])
        if isinstance(rel, FilterRel):
            p = self._stream(rel.input)
            p.ops.append(FilterOp(rel.condition, self.backend))
            return p
        if isinstance(rel, ProjectRel):
            p = self._stream(rel.input)
            p.ops.append(ProjectOp(rel.exprs, rel.keep_input))
            return p
        if isinstance(rel, ExchangeRel):
            # single-node: the exchange layer is bypassed entirely (§3.2.4)
            return self._stream(rel.input)
        if isinstance(rel, JoinRel):
            build_p = self._stream(rel.build)
            if build_p.sink is None:
                build_p.sink = BuildSink(_Result())
            probe_p = self._stream(rel.probe)
            probe_p.ops.append(ProbeOp(rel, build_p.sink.result, self.backend))
            probe_p.deps.append(build_p.pid)
            return probe_p
        if isinstance(rel, AggregateRel):
            child = self._stream(rel.input)
            if child.sink is None:
                child.sink = AggSink(_Result(), rel, self.backend)
            else:  # child already materialized; chain a fresh pipeline
                mid = self.new_pipeline(child.sink.result, [child.pid])
                mid.sink = AggSink(_Result(), rel, self.backend)
                child = mid
            out = self.new_pipeline(child.sink.result, [child.pid])
            return out
        if isinstance(rel, SortRel):
            child = self._stream(rel.input)
            sink = SortSink(_Result(), rel, self.backend)
            child = self._attach_sink(child, sink)
            return self.new_pipeline(child.sink.result, [child.pid])
        if isinstance(rel, FetchRel):
            child = self._stream(rel.input)
            sink = FetchSink(_Result(), rel.count)
            child = self._attach_sink(child, sink)
            return self.new_pipeline(child.sink.result, [child.pid])
        raise TypeError(f"cannot lower {type(rel)}")

    def _attach_sink(self, child: Pipeline, sink: _Sink) -> Pipeline:
        if child.sink is None:
            child.sink = sink
            return child
        mid = self.new_pipeline(child.sink.result, [child.pid])
        mid.sink = sink
        return mid


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


class PipelineExecutor:
    """Global task queue + worker threads pulling ready pipelines."""

    def __init__(self, buffers: BufferManager, num_workers: int = 2,
                 morsel_rows: Optional[int] = None, backend=None,
                 profile: bool = False, compile_pipelines: bool = True,
                 metrics=None):
        self.buffers = buffers
        self.num_workers = num_workers
        self.morsel_rows = morsel_rows
        self.backend = backend
        self.profile = profile
        self.compile_pipelines = compile_pipelines
        # instance-scoped registry: pooled shard engines get their own
        # labeled registry (mirroring into the process-global METRICS);
        # everything else publishes straight into METRICS as before
        self.metrics = metrics if metrics is not None else METRICS
        self.compiler = PipelineCompiler()
        self.op_times: Dict[str, float] = defaultdict(float)
        self.fallback_queries = 0
        # executable-plan cache (DESIGN.md §13): signature → recorded
        # pipelines + prepared stages + scalar-pull schedule.  Routers
        # flip ``cache_enabled`` off around fragments that read boundary
        # tables — those change between accelerate() calls under the same
        # plan signature, which would poison warm replays.
        self.plan_cache = PlanCache(metrics=self.metrics)
        self.cache_enabled = True
        self._exec_depth = 0
        # per-execute telemetry: trace/compile time this query incurred
        # (cold runs only; warm replays never trace) and how the plan
        # cache resolved it
        self.last_compile_seconds = 0.0
        self.last_plan_signature: Optional[str] = None
        self.last_plan_cache_hit = False
        self.last_query_id: Optional[str] = None
        # source-table injection for the whole-query replay trace: while
        # set, ReadRel sources resolve here instead of the buffer manager
        self._table_override: Optional[Dict[str, Table]] = None
        # EXPLAIN ANALYZE state: the active per-query collector (None on the
        # default path — its presence is what switches on per-stage syncs)
        # and the last completed QueryProfile
        self._builder: Optional[ProfileBuilder] = None
        self._analyze = False
        self._scan_filter_s = 0.0
        self.last_profile: Optional[QueryProfile] = None

    # -- scalar subqueries are resolved before pipeline lowering -------------
    def _resolve_subqueries(self, expr):
        if isinstance(expr, ScalarSubquery):
            sub = self.execute(expr.plan)
            val = np.asarray(sub[expr.column].data).reshape(-1)
            return Lit(float(val[0]) if val.dtype.kind == "f" else int(val[0]))
        if dataclasses.is_dataclass(expr) and isinstance(expr, Expr):
            for f in dataclasses.fields(expr):
                v = getattr(expr, f.name)
                if isinstance(v, Expr):
                    setattr(expr, f.name, self._resolve_subqueries(v))
                elif isinstance(v, (list, tuple)) and v and isinstance(v[0], tuple):
                    setattr(expr, f.name, [
                        tuple(self._resolve_subqueries(x) if isinstance(x, Expr) else x
                              for x in w) for w in v])
        return expr

    def _prepare(self, plan: Rel) -> None:
        for rel in walk(plan):
            for f in dataclasses.fields(rel):
                v = getattr(rel, f.name)
                if isinstance(v, Expr):
                    setattr(rel, f.name, self._resolve_subqueries(v))
                elif isinstance(v, list) and v and isinstance(v[0], tuple) and \
                        len(v[0]) == 2 and isinstance(v[0][1], Expr):
                    setattr(rel, f.name,
                            [(n, self._resolve_subqueries(e)) for n, e in v])
                elif isinstance(v, list):
                    for item in v:
                        if dataclasses.is_dataclass(item) and hasattr(item, "expr") \
                                and isinstance(getattr(item, "expr", None), Expr):
                            item.expr = self._resolve_subqueries(item.expr)

    def execute(self, plan: Rel, analyze: bool = False,
                query_text: Optional[str] = None) -> Table:
        """Run ``plan``.  With ``analyze=True`` (or engine ``profile=True``)
        a ``QueryProfile`` is assembled on ``self.last_profile``; the
        default path is bit-identical to before — no extra syncs, no
        per-stage timing.  Nested calls (scalar-subquery plans) record into
        the enclosing query's profile.

        Every call lands in the query journal: top-level calls with no
        ambient trace context root a fresh query tree; nested calls
        (scalar subqueries, shard-engine runs under an activated fragment
        context) become child spans of the enclosing query."""
        with JOURNAL.query_span("engine.execute") as jspan:
            return self._execute_journaled(plan, analyze, query_text, jspan)

    def _execute_journaled(self, plan: Rel, analyze: bool,
                           query_text: Optional[str], jspan) -> Table:
        owns_builder = (analyze or self.profile) and self._builder is None
        if owns_builder:
            self._builder = ProfileBuilder(
                query=query_text,
                engine={"use_kernels": self.backend is not None,
                        "compile_pipelines": self.compile_pipelines,
                        "profile_mode": self.profile,
                        "num_workers": self.num_workers})
            self._analyze = bool(analyze)
            metrics_before = self._metrics_snapshot()
            trace_s0 = self.compiler.stats["trace_seconds"]
            t_query = time.perf_counter()
        top_level = self._exec_depth == 0
        if top_level:
            self.last_plan_signature = None
            self.last_plan_cache_hit = False
            trace_all0 = self.compiler.stats["trace_seconds"]
        self._exec_depth += 1
        try:
            # the plan cache owns the default path; profiled/analyzed runs,
            # morsel-driven runs and router-suspended fragments keep the
            # uncached pipeline executor
            use_cache = (self.cache_enabled and self.compile_pipelines
                         and self._builder is None and not self.profile
                         and not self.morsel_rows)
            if use_cache:
                out = self._execute_cached(plan)
            else:
                out = self._execute_inner(plan)
        finally:
            self._exec_depth -= 1
            if top_level:
                # attribute trace time to the query that incurred it (cold
                # runs see their true compile tax; warm replays report 0)
                self.last_compile_seconds = (
                    self.compiler.stats["trace_seconds"] - trace_all0)
                self.last_query_id = jspan.query_id
                jspan.set(plan_cache_hit=self.last_plan_cache_hit,
                          compile_seconds=round(
                              self.last_compile_seconds, 6),
                          **self.buffers.watermarks())
            if owns_builder:
                total = time.perf_counter() - t_query
                builder, self._builder = self._builder, None
                self._analyze = False
                builder.plan_text = explain(plan)
                compile_s = self.compiler.stats["trace_seconds"] - trace_s0
                metrics = {
                    k: v - metrics_before.get(k, 0)
                    for k, v in self._metrics_snapshot().items()}
                self.last_profile = builder.finalize(total, compile_s, metrics)
                self.metrics.histogram("executor.query_seconds").observe(total)
        return out

    def _metrics_snapshot(self) -> Dict[str, float]:
        """Point-in-time view of this engine's counters; per-query deltas of
        two snapshots become ``QueryProfile.metrics``.  The key set is
        schema-stable: kernel counters appear (as zero) even without a
        kernel backend."""
        from ..relational import strings
        snap: Dict[str, float] = {}
        for k, v in self.compiler.stats.items():
            snap[f"compiler.{k}"] = v
        hits = (self.backend.hit_counts() if self.backend is not None
                else {"filter": 0, "probe": 0, "agg": 0,
                      "expand": 0, "topk": 0})
        for k, v in hits.items():
            snap[f"kernel.{k}_hits"] = v
        for k, v in self.plan_cache.stats.items():
            snap[f"plan_cache.{k}"] = v
        b = self.buffers
        snap["buffers.cold_copy_bytes"] = b.cold_copy_bytes
        snap["buffers.host_transfer_bytes"] = b.host_transfer_bytes
        snap["buffers.boundary_to_host_bytes"] = b.boundary_to_host_bytes
        snap["buffers.boundary_to_device_bytes"] = b.boundary_to_device_bytes
        snap["buffers.processing_peak"] = b.processing_peak
        snap["executor.sync_barriers"] = instrument.sync_barriers.value
        snap["executor.scalar_syncs"] = instrument.scalar_syncs.value
        for k, v in strings.stats.items():
            snap[f"strings.{k}"] = v
        return snap

    # -- executable-plan cache (DESIGN.md §13) -------------------------------
    def _execute_cached(self, plan: Rel) -> Table:
        """Default-path entry: replay a cached executable plan, or run cold
        while recording one.  The signature is computed over the unprepared
        plan (``_prepare`` mutates it), so fresh plan objects for the same
        query hit the same entry."""
        sig = plan_signature(plan)
        entry = self.plan_cache.lookup(sig)
        if entry is not None and not self._entry_fresh(entry):
            self.plan_cache.invalidate(sig)
            entry = None
        if entry is not None:
            try:
                out = self._replay_entry(entry)
                self.last_plan_signature = sig
                self.last_plan_cache_hit = True
                return out
            except Exception as exc:  # noqa: BLE001 — degrade to a cold run, never fail
                JOURNAL.event("plan_cache.poison", "cache",
                              reason=type(exc).__name__)
                self.plan_cache.invalidate(sig, mismatch=True)
        with JOURNAL.span("plan_cache.record", "cache"):
            out = self._execute_recording(plan, sig)
        self.last_plan_signature = sig
        return out

    def _execute_recording(self, plan: Rel, sig: str) -> Table:
        """Cold run that assembles the executable plan as it goes.

        Pipelines run serially on the calling thread in creation order
        (``PlanLowering`` emits dependencies first, so that *is* a
        topological order) — the scalar recording is thread-local and the
        replayed pull sequence must be deterministic."""
        self._prepare(plan)
        lowering = PlanLowering(self.backend)
        final = lowering.lower(plan)
        recorded = [self._run_pipeline_recorded(p) for p in lowering.pipelines]
        out = final.sink.result.table
        if out is not None:
            # the query's single host sync: materialize the result table
            jax.block_until_ready([c.data for c in out.columns.values()])
            instrument.count_sync()
        entry = ExecutablePlan(recorded, final)
        entry.epochs = {
            p.source.table: self.buffers.table_epochs.get(p.source.table, 0)
            for p in lowering.pipelines if isinstance(p.source, ReadRel)}
        if self.backend is None:
            # cold-attributed: one whole-query trace + XLA compile, so warm
            # replays dispatch a single program (interpret-mode kernel runs
            # keep the closure loop — tracing Pallas interpreters inside an
            # outer jit multiplies their already-slow cold cost)
            self._compile_replay(entry)
        self.plan_cache.store(sig, entry)
        return out

    def _run_pipeline_recorded(self, p: Pipeline) -> RecordedPipeline:
        ops = p.ops
        fuse_scan_filter = (self.backend is None and bool(p.ops)
                            and isinstance(p.source, ReadRel)
                            and p.source.filter is not None)
        if fuse_scan_filter:
            ops = [FilterOp(p.source.filter)]
            if p.source.columns:
                ops.append(SelectOp(p.source.columns))
            ops += list(p.ops)
        values: List = []
        with instrument.pipeline_scope():
            # probe lowering happens once, here; its eligibility pulls must
            # never join the replay schedule (warm runs skip prepare)
            with instrument.pulls_suspended():
                stages = self.compiler.prepare(ops, self.backend)
            with instrument.scalar_recording(values):
                src = self._source_table(p.source,
                                         skip_filter=fuse_scan_filter)
                approx_bytes = max(src.nbytes, 1)
                self.buffers.alloc_processing(approx_bytes)
                try:
                    t = src
                    for stage in stages:
                        t = stage(t)
                    p.sink.push(t)
                    p.sink.finalize()
                finally:
                    self.buffers.free_processing(approx_bytes)
        return RecordedPipeline(p, stages, values, fuse_scan_filter)

    def _replay_core(self, entry: ExecutablePlan, flags: List) -> Table:
        """Warm-path body: the loop over already-prepared closures.

        Runs both natively (the fallback warm path) and under ``jax.jit``
        tracing (``_compile_replay``) — everything inside must stay
        jnp-traceable on the paths cached entries take."""
        for rp in entry.pipelines:
            if not rp.must_run:
                continue
            p = rp.pipeline
            p.sink.reset()
            with instrument.pipeline_scope():
                with instrument.scalar_replay(rp.values, flags):
                    src = self._source_table(p.source,
                                             skip_filter=rp.fuse_scan_filter)
                    approx_bytes = max(src.nbytes, 1)
                    self.buffers.alloc_processing(approx_bytes)
                    try:
                        t = src
                        for stage in rp.stages:
                            t = stage(t)
                        p.sink.push(t)
                        p.sink.finalize()
                    finally:
                        self.buffers.free_processing(approx_bytes)
        return entry.final.sink.result.table

    def _compile_replay(self, entry: ExecutablePlan) -> None:
        """AOT-compile the whole warm replay into ONE XLA program.

        Once the recorded scalars replace every host pull, the entire
        query is static-shaped — so the closure loop itself is traceable:
        scans, eager ops, fused regions (inlined) and sinks collapse into
        a single compiled call, eliminating the per-op dispatch overhead
        that dominates small-query warm time.  ``lower().compile()`` runs
        the trace with abstract values (no duplicate cold compute); the
        verification flags become a fused device-side output.  Anything
        untraceable (string host passes, dynamic-unique key packing)
        aborts quietly — the closure loop remains the warm path for that
        entry."""
        names, layout, metas, arrays = set(), [], {}, []
        for rp in entry.pipelines:
            src = rp.pipeline.source
            if rp.must_run and isinstance(src, ReadRel):
                names.add(src.table)
        for n in sorted(names):
            t = self.buffers.get(n)
            metas[n] = [(cn, c.kind, c.dictionary)
                        for cn, c in t.columns.items()]
            layout.append((n, len(t.columns)))
            arrays.extend(c.data for c in t.columns.values())
        out_meta: List = []

        def fn(flat):
            tables, i = {}, 0
            for n, k in layout:
                tables[n] = Table({
                    cn: Column(a, kind, dct)
                    for (cn, kind, dct), a in zip(metas[n], flat[i:i + k])})
                i += k
            flags: List = []
            self._table_override = tables
            try:
                out = self._replay_core(entry, flags)
            finally:
                self._table_override = None
            del out_meta[:]
            out_meta.extend((cn, c.kind, c.dictionary)
                            for cn, c in out.columns.items())
            flag = (jnp.any(jnp.stack(flags)) if flags
                    else jnp.zeros((), jnp.bool_))
            return tuple(c.data for c in out.columns.values()), flag

        t0 = time.perf_counter()
        try:
            compiled = jax.jit(fn).lower(tuple(arrays)).compile()
            entry.compiled = (compiled, layout, metas, list(out_meta))
            self.metrics.counter("plan_cache.replay_compiles").inc()
        except Exception:  # noqa: BLE001 — untraceable: keep the closure loop
            entry.compiled = None
            if os.environ.get("REPRO_DEBUG_REPLAY_COMPILE"):
                import traceback
                traceback.print_exc()
        finally:
            self._table_override = None
            # the whole-query compile is trace time the cold run incurred:
            # surface it through the same attribution as region traces
            dt = time.perf_counter() - t0
            self.compiler.stats["trace_seconds"] += dt
            self.metrics.histogram(
                "pipeline_compiler.trace_seconds").observe(dt)

    def _replay_entry(self, entry: ExecutablePlan) -> Table:
        """The warm path.

        No parsing, no lowering, no probe builds, no traces, no scalar
        syncs — every ``pull_scalar`` is served from the recording and the
        device-side verification flags ride along to the single final
        barrier.  Any set flag means the data under a recorded cardinality
        changed: raise ``ReplayMismatch`` so the caller invalidates and
        re-runs cold.  Entries with a compiled replay program dispatch it
        as one call; the rest run the closure loop.  Either way the warm
        dispatch is a first-class journal span (its wall time is the
        dispatch wall the trace tooling reports) instead of vanishing."""
        with JOURNAL.span("plan_cache.replay", "cache",
                          mode=("compiled" if entry.compiled is not None
                                else "closure")):
            return self._replay_entry_inner(entry)

    def _replay_entry_inner(self, entry: ExecutablePlan) -> Table:
        if entry.compiled is not None:
            compiled, layout, metas, out_meta = entry.compiled
            arrays: List = []
            for n, _ in layout:
                t = self.buffers.get(n)
                arrays.extend(t[cn].data for cn, _k, _d in metas[n])
            outs, flag = compiled(tuple(arrays))
            jax.block_until_ready(list(outs) + [flag])
            instrument.count_sync()
            if bool(flag):  # already materialized: free host read
                raise instrument.ReplayMismatch(
                    "recorded scalar diverged on replay")
            return Table({cn: Column(a, kind, dct)
                          for (cn, kind, dct), a in zip(out_meta, outs)})
        flags: List = []
        out = self._replay_core(entry, flags)
        sync_targets = [c.data for c in out.columns.values()]
        if flags:
            flag = jnp.any(jnp.stack(flags))
            jax.block_until_ready(sync_targets + [flag])
            instrument.count_sync()
            if bool(flag):  # already materialized: free host read
                raise instrument.ReplayMismatch(
                    "recorded scalar diverged on replay")
        else:
            jax.block_until_ready(sync_targets)
            instrument.count_sync()
        return out

    def _entry_fresh(self, entry: ExecutablePlan) -> bool:
        """True while every table the entry scans is still the generation
        the recording read (epoch-checked so direct ``cache_table``
        re-caches — which bypass ``register`` — invalidate replays too)."""
        return all(self.buffers.table_epochs.get(n, 0) == e
                   for n, e in entry.epochs.items())

    def replay_signature(self, sig: str) -> Optional[Table]:
        """Warm front-door for the engine's text/wire caches: replay the
        entry under ``sig`` or return None (missing / mismatched) so the
        caller falls back to its full parse/route path."""
        entry = self.plan_cache.lookup(sig)
        if entry is not None and not self._entry_fresh(entry):
            self.plan_cache.invalidate(sig)
            entry = None
        if entry is None:
            return None
        with JOURNAL.query_span("engine.execute", entry="warm") as jspan:
            try:
                out = self._replay_entry(entry)
            except Exception as exc:  # noqa: BLE001
                JOURNAL.event("plan_cache.poison", "cache",
                              reason=type(exc).__name__)
                self.plan_cache.invalidate(sig, mismatch=True)
                return None
            self.last_plan_signature = sig
            self.last_plan_cache_hit = True
            self.last_compile_seconds = 0.0
            self.last_query_id = jspan.query_id
            jspan.set(plan_cache_hit=True, compile_seconds=0.0,
                      **self.buffers.watermarks())
        return out

    def _execute_inner(self, plan: Rel) -> Table:
        self._prepare(plan)
        lowering = PlanLowering(self.backend)
        final = lowering.lower(plan)
        pipelines = lowering.pipelines

        remaining = {p.pid: len(p.deps) for p in pipelines}
        dependents: Dict[int, List[int]] = defaultdict(list)
        for p in pipelines:
            for d in p.deps:
                dependents[d].append(p.pid)

        ready: "queue.Queue[int]" = queue.Queue()
        for p in pipelines:
            if remaining[p.pid] == 0:
                ready.put(p.pid)

        done = threading.Event()
        errors: List[BaseException] = []
        lock = threading.Lock()
        finished = {"n": 0}

        def worker():
            while not done.is_set():
                try:
                    pid = ready.get(timeout=0.02)
                except queue.Empty:
                    continue
                try:
                    self._run_pipeline(pipelines[pid])
                except BaseException as e:  # noqa: BLE001
                    errors.append(e)
                    done.set()
                    return
                with lock:
                    finished["n"] += 1
                    for dep in dependents[pid]:
                        remaining[dep] -= 1
                        if remaining[dep] == 0:
                            ready.put(dep)
                    if finished["n"] == len(pipelines):
                        done.set()

        # profiling serializes pipelines so per-operator wall clocks never
        # overlap (sum of operator times must stay <= query total)
        n_workers = 1 if self._builder is not None else self.num_workers
        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(n_workers)]
        for t in threads:
            t.start()
        done.wait()
        for t in threads:
            t.join(timeout=5)
        if errors:
            raise errors[0]
        out = final.sink.result.table
        if out is not None and not self.profile and not self._analyze:
            # the query's single host sync: materialize the result table
            jax.block_until_ready([c.data for c in out.columns.values()])
            instrument.count_sync()
        return out

    # -- single pipeline ------------------------------------------------------
    def _source_table(self, source, skip_filter: bool = False) -> Table:
        if isinstance(source, ReadRel):
            t = (self._table_override[source.table]
                 if self._table_override is not None
                 else self.buffers.get(source.table))
            if source.filter is not None and not skip_filter:
                t0 = time.perf_counter()
                out = (self.backend.try_filter(source.filter, t)
                       if self.backend is not None else None)
                if out is None:
                    mask = evaluate(source.filter, t)
                    out = t.filter_mask(mask.data)
                if self._builder is not None:
                    jax.block_until_ready(
                        [c.data for c in out.columns.values()])
                    instrument.count_sync()
                dt = time.perf_counter() - t0
                # keeps the pushed-down filter attributable as "filter" in
                # the profile (the scan record subtracts it)
                self._scan_filter_s = dt
                t = out
                if self.profile:
                    self.op_times["filter"] += dt
            if source.columns:
                keep = [c for c in source.columns if c in t]
                if skip_filter and source.filter is not None:
                    # deferred filter: its columns ride along until the fused
                    # region applies the filter and the SelectOp prunes them
                    keep += [c for c in source.filter.columns()
                             if c in t and c not in keep]
                t = t.select(keep)
            return t
        if isinstance(source, _Result):
            assert source.table is not None, "dependency not materialized"
            return source.table
        raise TypeError(type(source))

    def _morsels(self, t: Table):
        if not self.morsel_rows or t.num_rows <= self.morsel_rows:
            yield t
            return
        for lo in range(0, t.num_rows, self.morsel_rows):
            yield t.take(jnp.arange(lo, min(lo + self.morsel_rows, t.num_rows)))

    def _run_pipeline(self, p: Pipeline) -> None:
        with instrument.pipeline_scope():
            self._run_pipeline_inner(p)

    def _run_pipeline_inner(self, p: Pipeline) -> None:
        # pushed-down ReadRel filters join the fused region as its first op
        # (default mode, no kernel backend — the backend's fused filter
        # kernel keeps the eager route so its eligibility contract applies)
        ops = p.ops
        # only worthwhile when there are downstream ops to fuse with — a
        # scan-only pipeline pays region padding for no fusion gain
        fuse_scan_filter = (not self.profile and self.compile_pipelines
                            and self.backend is None and bool(p.ops)
                            and isinstance(p.source, ReadRel)
                            and p.source.filter is not None)
        if fuse_scan_filter:
            ops = [FilterOp(p.source.filter)]
            if p.source.columns:
                ops.append(SelectOp(p.source.columns))
            ops += list(p.ops)
        builder = self._builder
        rec = None
        if builder is not None:
            label = (f"scan:{p.source.table}" if isinstance(p.source, ReadRel)
                     else "result")
            rec = builder.start_pipeline(label, list(p.deps))
            rows_in = (self.buffers.get(p.source.table).num_rows
                       if isinstance(p.source, ReadRel) else None)
            self._scan_filter_s = 0.0
            t0 = time.perf_counter()
        src = self._source_table(p.source, skip_filter=fuse_scan_filter)
        if builder is not None:
            jax.block_until_ready([c.data for c in src.columns.values()])
            instrument.count_sync()
            dt = time.perf_counter() - t0
            filt_s = self._scan_filter_s
            base_rows = src.num_rows if rows_in is None else rows_in
            if filt_s > 0:
                # pushed-down ReadRel filter: report fetch and filter as
                # separate operators so the breakdown stays category-exact
                builder.add_operator(rec, label, "scan", base_rows, base_rows,
                                     max(dt - filt_s, 0.0))
                builder.add_operator(rec, "ReadFilter", "filter", base_rows,
                                     src.num_rows, filt_s)
            else:
                builder.add_operator(rec, label, "scan", base_rows,
                                     src.num_rows, dt)
        approx_bytes = max(src.nbytes, 1)
        self.buffers.alloc_processing(approx_bytes)
        try:
            if self.profile:
                self._run_profiled(p, src, rec)
                return
            # default path: fused regions, fully async dispatch — downstream
            # pipelines consume the sink's device arrays without a barrier;
            # the single blocking sync happens at the query's final sink
            # (see ``execute``)
            stages = (self.compiler.prepare(ops, self.backend)
                      if self.compile_pipelines else ops)
            if builder is not None:
                self._run_analyzed(p, src, stages, rec, builder)
                return
            for morsel in self._morsels(src):
                t = morsel
                for stage in stages:
                    t = stage(t)
                p.sink.push(t)
            p.sink.finalize()
        finally:
            self.buffers.free_processing(approx_bytes)

    def _stage_telemetry(self, stage):
        """Name/category/attrs for a pipeline stage, read *after* its timer
        stopped.  Fused regions also contribute their HLO cost estimates
        (``est_flops`` / ``est_bytes``); the AOT lowering that computes them
        runs here, outside the stage's wall-clock window."""
        if isinstance(stage, FusedSegment):
            info = stage.last_call_info or {}
            attrs = {}
            if "cache_hit" in info:
                attrs["cache_hit"] = bool(info["cache_hit"])
            if info.get("degraded"):
                attrs["degraded"] = True
            region = info.get("region")
            if region is not None and "cost_args" in info:
                attrs.update(region.cost_summary(*info["cost_args"]))
            return stage.describe(), "fused", attrs
        return type(stage).__name__, getattr(stage, "category", "other"), {}

    def _run_analyzed(self, p: Pipeline, src: Table, stages, rec,
                      builder: ProfileBuilder) -> None:
        """EXPLAIN ANALYZE path: the *same* stages as the default path
        (fused regions included) plus an opt-in barrier + timer per stage.
        The extra syncs are the point — they pin wall time onto operators
        that async dispatch would otherwise smear into the final sink."""
        pushed = 0
        sink_s = 0.0
        for morsel in self._morsels(src):
            t = morsel
            for stage in stages:
                rows_in = t.num_rows
                t0 = time.perf_counter()
                t = stage(t)
                jax.block_until_ready([c.data for c in t.columns.values()])
                instrument.count_sync()
                dt = time.perf_counter() - t0
                name, cat, attrs = self._stage_telemetry(stage)
                builder.add_operator(rec, name, cat, rows_in, t.num_rows, dt,
                                     **attrs)
            pushed += t.num_rows
            t0 = time.perf_counter()
            p.sink.push(t)
            sink_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        p.sink.finalize()
        out = p.sink.result.table
        if out is not None:
            jax.block_until_ready([c.data for c in out.columns.values()])
            instrument.count_sync()
        sink_s += time.perf_counter() - t0
        builder.add_operator(rec, type(p.sink).__name__, p.sink.category,
                             pushed, out.num_rows if out is not None else 0,
                             sink_s)

    def _run_profiled(self, p: Pipeline, src: Table, rec=None) -> None:
        """Pre-fusion path: eager per-op dispatch with a barrier + timer per
        operator, feeding the Figure-5 breakdown benchmark.  When a profile
        builder is live the same measurements also land in the query's
        ``QueryProfile``."""
        builder = self._builder
        pushed = 0
        sink_s = 0.0
        for morsel in self._morsels(src):
            t = morsel
            for op in p.ops:
                rows_in = t.num_rows
                t0 = time.perf_counter()
                t = op(t)
                jax.block_until_ready([c.data for c in t.columns.values()])
                instrument.count_sync()
                dt = time.perf_counter() - t0
                self.op_times[op.category] += dt
                if builder is not None:
                    builder.add_operator(rec, type(op).__name__, op.category,
                                         rows_in, t.num_rows, dt)
            pushed += t.num_rows
            t0 = time.perf_counter()
            p.sink.push(t)
            dt = time.perf_counter() - t0
            self.op_times[p.sink.category] += dt
            sink_s += dt
        t0 = time.perf_counter()
        p.sink.finalize()
        out = p.sink.result.table
        if out is not None:
            jax.block_until_ready([c.data for c in out.columns.values()])
            instrument.count_sync()
        dt = time.perf_counter() - t0
        self.op_times[p.sink.category] += dt
        sink_s += dt
        if builder is not None:
            builder.add_operator(rec, type(p.sink).__name__, p.sink.category,
                                 pushed, out.num_rows if out is not None else 0,
                                 sink_s)


# ---------------------------------------------------------------------------
# engine facade with graceful fallback (paper §3.2.2)
# ---------------------------------------------------------------------------


class SiriusEngine:
    """The public query engine: caches tables, executes plans, falls back."""

    def __init__(self, caching_bytes: int = 8 << 30, processing_bytes: int = 8 << 30,
                 num_workers: int = 2, morsel_rows: Optional[int] = None,
                 use_kernels: bool = False, profile: bool = False,
                 compile_pipelines: bool = True, metrics=None):
        self.buffers = BufferManager(caching_bytes, processing_bytes)
        backend = None
        if use_kernels:
            from .kernel_backend import KernelBackend
            backend = KernelBackend()
        self.backend = backend
        self.metrics = metrics if metrics is not None else METRICS
        self.executor = PipelineExecutor(self.buffers, num_workers, morsel_rows,
                                         backend, profile=profile,
                                         compile_pipelines=compile_pipelines,
                                         metrics=self.metrics)
        self.host_tables: Dict[str, dict] = {}
        # journal query ID of the most recent front-door call (sql /
        # accelerate / execute) — how callers correlate results with
        # their span tree in JOURNAL
        self.last_query_id: Optional[str] = None
        # routing report of the most recent ``accelerate`` call
        self.last_accelerate_report: Optional[dict] = None
        # QueryProfile of the most recent analyzed/profiled query
        self.last_profile: Optional[QueryProfile] = None
        # host-side string dictionaries harvested at registration — kept
        # instead of the Tables themselves so the buffer manager stays free
        # to spill device columns (a pinned Table would defeat eviction)
        self.table_dictionaries: Dict[str, Dict[str, object]] = {}
        # warm front-door keys (DESIGN.md §13): normalized SQL text and
        # canonical wire bytes map straight to executable-plan signatures,
        # skipping lexer/parser/binder/optimizer (sql) and ingest/router
        # (accelerate) entirely on a hit.  Cleared with the plan cache on
        # every register().
        self._sql_plan_sigs: Dict[str, str] = {}
        self._wire_plan_cache: Dict[bytes, tuple] = {}

    @property
    def compiler(self):
        """The signature-keyed compiled-pipeline cache (stats live here)."""
        return self.executor.compiler

    def register(self, name: str, table: Table, host_data: Optional[dict] = None):
        # registered data is the one thing allowed to change between
        # queries: every cached executable plan and front-door key built
        # over the old data is invalid from here on
        self.executor.plan_cache.clear()
        self._sql_plan_sigs.clear()
        self._wire_plan_cache.clear()
        self.buffers.cache_table(name, table)
        dicts = {c: col.dictionary for c, col in table.columns.items()
                 if col.dictionary is not None}
        if dicts:
            self.table_dictionaries[name] = dicts
        else:
            # re-registration may drop string columns; never leave stale
            # dictionaries steering the optimizer's selectivity estimates
            self.table_dictionaries.pop(name, None)
        if host_data is not None:
            self.host_tables[name] = host_data

    def execute(self, plan: Rel, analyze: bool = False,
                query_text: Optional[str] = None) -> Table:
        out = self.executor.execute(plan, analyze=analyze,
                                    query_text=query_text)
        self.last_query_id = self.executor.last_query_id
        if analyze or self.executor.profile:
            self.last_profile = self.executor.last_profile
        return out

    def sql(self, text: str, catalog=None, optimize: bool = True,
            analyze: bool = False):
        """Drop-in entry point: SQL text → parse → optimize → execute.

        The optimizer's catalog is enriched with the registered tables'
        string dictionaries, so LIKE / IN / prefix predicates are costed by
        their measured dictionary hit rate instead of constants.

        ``EXPLAIN ANALYZE <query>`` runs the query with per-operator
        telemetry and returns the ``QueryProfile`` instead of the result
        table.  ``analyze=True`` does the same but still returns the result
        table; either way the profile lands on ``self.last_profile``.

        Repeated queries take the warm path: normalized query text keys an
        executable-plan signature, so a hit skips lexer, parser, binder,
        optimizer *and* plan lowering and goes straight to the cached
        dispatch schedule (``PipelineExecutor.replay_signature``).
        """
        with JOURNAL.query_span("sql",
                                text=" ".join(text.split())[:200]) as jq:
            out = self._sql_impl(text, catalog, optimize, analyze)
            if jq.query_id is not None:
                self.last_query_id = jq.query_id
            return out

    def _sql_impl(self, text: str, catalog, optimize: bool, analyze: bool):
        from ..sql import EXPLAIN_ANALYZE_RE, run_sql, sql_to_plan
        from ..sql.binder import DEFAULT_CATALOG
        m = EXPLAIN_ANALYZE_RE.match(text)
        cacheable = (m is None and not analyze and catalog is None
                     and optimize)
        if cacheable:
            key = " ".join(text.split()).rstrip(";")
            sig = self._sql_plan_sigs.get(key)
            if sig is not None:
                out = self.executor.replay_signature(sig)
                if out is not None:
                    return out
        cat = (catalog or DEFAULT_CATALOG).with_dictionaries(
            self.table_dictionaries)
        if m:
            text = text[m.end():]
            plan = sql_to_plan(text, catalog=cat, optimize=optimize)
            self.execute(plan, analyze=True, query_text=text.strip())
            return self.last_profile
        if analyze:
            plan = sql_to_plan(text, catalog=cat, optimize=optimize)
            return self.execute(plan, analyze=True, query_text=text.strip())
        out = run_sql(text, self, catalog=cat, optimize=optimize)
        if cacheable and self.executor.last_plan_signature is not None:
            self._sql_plan_sigs[key] = self.executor.last_plan_signature
        return out

    def accelerate(self, wire_plan, registry=None, analyze: bool = False):
        """The drop-in front door: execute a serialized Substrait-style plan.

        ``wire_plan`` is what an external host engine hands over — the wire
        dict produced by ``repro.substrait.emit`` (or its JSON text/bytes).
        The plan is ingested, split by the capability ``registry`` into
        maximal device fragments and host fragments (executed on the numpy
        fallback oracle), and run with boundary transfers accounted through
        the buffer manager.  Unsupported rels degrade to hybrid execution
        instead of raising — Sirius's fallback contract.

        Returns a device ``Table``; the routing report (fragment placements,
        boundary bytes, ``device_rel_fraction``) is kept on
        ``self.last_accelerate_report``.

        Repeated wire plans take the warm path: the canonical wire bytes
        key an executable-plan signature (cached only when routing placed
        the whole plan on device as a single fragment), so a hit skips
        ingest, fragment analysis and routing and replays the cached
        dispatch schedule directly.
        """
        with JOURNAL.query_span("wire") as jq:
            out = self._accelerate_impl(wire_plan, registry, analyze)
            if jq.query_id is not None:
                self.last_query_id = jq.query_id
            return out

    def _accelerate_impl(self, wire_plan, registry, analyze: bool):
        from ..relational.table import Table as _Table
        from ..substrait import HybridRouter, ingest, wire_bytes

        wire_key = None
        if not analyze and registry is None:
            try:
                if isinstance(wire_plan, bytes):
                    wire_key = wire_plan
                elif isinstance(wire_plan, str):
                    wire_key = wire_plan.encode("utf-8")
                else:
                    wire_key = wire_bytes(wire_plan)
            except Exception:  # noqa: BLE001 — unkeyable plans just run cold
                wire_key = None
            cached = (self._wire_plan_cache.get(wire_key)
                      if wire_key is not None else None)
            if cached is not None:
                sig, report_template = cached
                out = self.executor.replay_signature(sig)
                if out is not None:
                    self.last_accelerate_report = dict(report_template,
                                                       plan_cache_hit=True)
                    return out

        plan = ingest(wire_plan)
        t0 = time.perf_counter()
        result, report = HybridRouter(self, registry).execute(plan,
                                                              analyze=analyze)
        if (wire_key is not None and isinstance(result, _Table)
                and report["host_fragments"] == 0
                and report["device_fragments"] == 1
                and self.executor.last_plan_signature is not None):
            # single all-device fragment: the executor's entry covers the
            # whole plan, so the routing report is replayable verbatim
            self._wire_plan_cache[wire_key] = (
                self.executor.last_plan_signature, dict(report))
        if not isinstance(result, _Table):
            # host-rooted plan: the result itself crosses back to device
            result = _Table.from_pydict(result)
            self.buffers.account_boundary_to_device(result.nbytes)
            report["boundary_to_device_bytes"] += result.nbytes
        self.last_accelerate_report = report
        if analyze:
            self.last_profile = self._merge_fragment_profiles(
                report, plan, time.perf_counter() - t0)
        return result

    def _merge_fragment_profiles(self, report: dict, plan: Rel,
                                 total_seconds: float) -> QueryProfile:
        """Stitch per-fragment profiles from an analyzed ``accelerate`` run
        into one ``QueryProfile``.  Device fragments contribute their full
        per-operator pipelines (sources prefixed ``frag<N>:``); host
        fragments appear as a single opaque operator — the numpy oracle has
        no operator-level clock."""
        from .plan import explain
        pipelines: List[PipelineProfile] = []
        compile_s = 0.0
        metrics: Dict[str, float] = {}
        for frag in report["fragments"]:
            prof = frag.pop("_profile", None)
            fid = frag["fid"]
            if prof is not None:
                compile_s += prof.compile_seconds
                for k, v in prof.metrics.items():
                    metrics[k] = metrics.get(k, 0) + v
                for p in prof.pipelines:
                    pipelines.append(PipelineProfile(
                        len(pipelines), f"frag{fid}:{p.source}", [],
                        list(p.operators)))
            else:
                rec = PipelineProfile(len(pipelines), f"frag{fid}:host", [])
                rec.operators.append(OperatorProfile(
                    "HostFragment", "other", 0,
                    int(frag.get("rows_out", 0)),
                    float(frag.get("seconds", 0.0))))
                pipelines.append(rec)
        totals: Dict[str, float] = {}
        for p in pipelines:
            for op in p.operators:
                totals[op.category] = totals.get(op.category, 0.0) + op.seconds
        compile_s = min(max(compile_s, 0.0), total_seconds)
        return QueryProfile(
            query=None,
            engine={"accelerate": True,
                    "use_kernels": self.backend is not None,
                    "compile_pipelines": self.executor.compile_pipelines},
            total_seconds=float(total_seconds),
            compile_seconds=float(compile_s),
            execute_seconds=float(max(total_seconds - compile_s, 0.0)),
            pipelines=pipelines, operator_totals=totals, metrics=metrics,
            plan=explain(plan), fragments=list(report["fragments"]))

    def execute_with_fallback(self, plan: Rel):
        """Run on the accelerator engine; on failure, degrade to the host path."""
        try:
            return self.execute(plan), "accelerator"
        except Exception:  # noqa: BLE001
            from .fallback import FallbackEngine
            self.executor.fallback_queries += 1
            fb = FallbackEngine(self.host_tables)
            return fb.execute(plan), "fallback"
