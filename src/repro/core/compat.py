"""jax API compatibility shims (0.4.x ⇄ newer-release surface drift).

Two call-surface drifts broke the seed's distributed and model tests on
jax 0.4.37:

* ``jax.shard_map`` — promoted to the top-level namespace (with a
  ``check_vma`` kwarg) only in newer releases; on 0.4.x it lives at
  ``jax.experimental.shard_map.shard_map`` and the kwarg is ``check_rep``.
* ``jax.sharding.get_abstract_mesh`` — newer releases track an ambient
  abstract mesh; 0.4.x only exposes the thread-resources physical mesh.

Every module that touches either API goes through this shim
(``core.distributed``, ``exchange.service``, ``launch.sql_dryrun``,
``models.layers``, ``models.lm``) so a jax upgrade is a one-file change.
"""
from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map``.

    Replication checking defaults to off: the exchange kernels return
    per-shard buffers alongside psum'd scalars, a mix the static
    replication checker cannot prove consistent.
    """
    if hasattr(jax, "shard_map"):  # newer jax: check_vma kwarg
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def axis_size(axis_name):
    """Size of a mapped mesh axis (inside shard_map / pmap).

    ``jax.lax.axis_size`` is a newer addition; 0.4.x spells it
    ``psum(1, axis)``, which constant-folds to the static axis size.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``.

    0.4.x returns a one-element list of dicts (per device assignment);
    newer jax returns the dict directly.  Always → a plain dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def get_abstract_mesh():
    """Ambient mesh if one is active, else ``None``.

    Callers treat ``None`` (or a mesh without their axis) as "constraints
    are identity", so the 0.4.x fallback reports the thread-resources
    physical mesh and maps the empty mesh to ``None``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if mesh is None or getattr(mesh, "empty", False):
            return None
        return mesh
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - internal layout changed
        return None
    if mesh is None or getattr(mesh, "empty", True) or not mesh.axis_names:
        return None
    return mesh


def set_mesh(mesh):
    """Install ``mesh`` as the ambient mesh.

    Newer jax has ``jax.sharding.set_mesh``; on 0.4.x entering the mesh
    context manager (without exiting) installs it into thread resources,
    which is exactly where :func:`get_abstract_mesh` falls back to.
    """
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        setter(mesh)
        return
    mesh.__enter__()
