"""Static-shape relational operators for compiled fragments.

These run inside jit / shard_map (the distributed path and the multi-pod
dry-run), so every shape is fixed: row counts are carried by validity masks,
joins probe fixed-capacity hash tables, and aggregation is sort-based within
the shard (the TPU-native substitute for dynamic hash tables — argsort +
segment boundaries + segment_sum, all dense vector ops).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..exchange.service import Frame
from ..relational.join import StaticHashTable

I64_MAX = jnp.iinfo(jnp.int64).max


def pack_keys(cols: Sequence[jnp.ndarray], cards: Sequence[int]) -> jnp.ndarray:
    """Pack dense non-negative int key columns into one int64 (static cards)."""
    out = cols[0].astype(jnp.int64)
    for c, card in zip(cols[1:], cards[1:]):
        out = out * card + c.astype(jnp.int64)
    return out


def local_sort_agg(frame: Frame, key: jnp.ndarray,
                   sums: Dict[str, jnp.ndarray],
                   firsts: Dict[str, jnp.ndarray] | None = None
                   ) -> Tuple[Frame, jnp.ndarray]:
    """Shard-local group-by: sort rows by key, segment-reduce runs.

    ``sums``   name -> per-row value to sum within each key group
    ``firsts`` name -> per-row value carried through (same for all rows of a
               key, e.g. o_orderdate for key o_orderkey)
    Returns (Frame with 'key', sums, firsts, and '__count'; valid marks the
    unique keys), plus the sorted key array (for debugging).
    """
    cap = frame.capacity
    skey = jnp.where(frame.valid, key.astype(jnp.int64), I64_MAX)
    order = jnp.argsort(skey)
    k_sorted = jnp.take(skey, order)
    v_sorted = jnp.take(frame.valid, order)

    is_start = jnp.concatenate([
        jnp.ones((1,), bool), k_sorted[1:] != k_sorted[:-1]]) & v_sorted
    gid = jnp.cumsum(is_start) - 1                     # segment id per row
    gid = jnp.where(v_sorted, gid, cap)                # invalid rows dumped

    out_cols: Dict[str, jnp.ndarray] = {}
    ones = v_sorted.astype(jnp.float64)
    out_cols["__count"] = jax.ops.segment_sum(ones, gid, cap + 1)[:-1]
    for name, vals in sums.items():
        vs = jnp.take(vals, order).astype(jnp.float64)
        vs = jnp.where(v_sorted, vs, 0.0)
        out_cols[name] = jax.ops.segment_sum(vs, gid, cap + 1)[:-1]
    out_key = jnp.full((cap + 1,), I64_MAX, jnp.int64).at[gid].set(
        k_sorted, mode="drop")[:-1]
    out_cols["key"] = out_key
    if firsts:
        for name, vals in firsts.items():
            vs = jnp.take(vals, order)
            buf = jnp.zeros((cap + 1,), vs.dtype).at[gid].set(vs, mode="drop")
            out_cols[name] = buf[:-1]
    out_valid = out_key != I64_MAX
    return Frame(out_cols, out_valid), k_sorted


def static_semi_join(frame: Frame, key: jnp.ndarray, build_keys: jnp.ndarray,
                     build_valid: jnp.ndarray, anti: bool = False) -> Frame:
    """Filter frame rows by membership of ``key`` in the build key set."""
    safe = jnp.where(build_valid, build_keys.astype(jnp.int64), -1)
    ht = StaticHashTable.build(safe, valid=build_valid)
    _, found = ht.lookup(key.astype(jnp.int64))
    keep = ~found if anti else found
    return frame.with_mask(keep)


def static_inner_join(probe: Frame, probe_key: jnp.ndarray, build: Frame,
                      build_key: jnp.ndarray) -> Frame:
    """PK-FK inner join: build side unique keys; output rows = probe rows."""
    safe = jnp.where(build.valid, build_key.astype(jnp.int64), -1)
    ht = StaticHashTable.build(safe, valid=build.valid)
    row, found = ht.lookup(probe_key.astype(jnp.int64))
    safe_row = jnp.clip(row, 0, None)
    cols = dict(probe.columns)
    for name, col in build.columns.items():
        if name not in cols:
            cols[name] = jnp.take(col, safe_row, axis=0)
    return Frame(cols, probe.valid & found)


def static_topk(frame: Frame, score: jnp.ndarray, k: int,
                descending: bool = True) -> Frame:
    """Keep the k best rows by score (masked)."""
    s = score.astype(jnp.float64)
    neg_inf = jnp.finfo(jnp.float64).min
    masked = jnp.where(frame.valid, s if descending else -s, neg_inf)
    _, idx = jax.lax.top_k(masked, k)
    taken_valid = jnp.take(frame.valid, idx)
    return frame.take(idx, taken_valid)
