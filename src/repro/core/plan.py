"""Substrait-like query plan IR (the drop-in boundary of the paper, §3.1-3.2).

The host database layer (our mini SQL frontend, or hand-built TPC-H plans
standing in for DuckDB's optimizer output) produces this IR; the execution
engine consumes it.  Like Substrait, the IR is a tree of relational operators
with embedded scalar expressions and is JSON-round-trippable, so a plan can
cross a process/system boundary — that is what makes Sirius "drop-in".

Node vocabulary mirrors Substrait relations: ReadRel, FilterRel, ProjectRel,
JoinRel, AggregateRel, SortRel, FetchRel (limit), ExchangeRel.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    Substr, UnOp,
)
from ..relational.sort import SortKey


class Rel:
    """Base class for plan nodes."""

    def inputs(self) -> List["Rel"]:
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Rel):
                out.append(v)
        return out


@dataclasses.dataclass
class ReadRel(Rel):
    table: str
    columns: Optional[List[str]] = None           # projection pushdown
    filter: Optional[Expr] = None                 # predicate pushdown


@dataclasses.dataclass
class FilterRel(Rel):
    input: Rel
    condition: Expr


@dataclasses.dataclass
class ProjectRel(Rel):
    input: Rel
    exprs: List[Tuple[str, Expr]]                 # (output name, expression)
    keep_input: bool = False                      # append instead of replace


@dataclasses.dataclass
class JoinRel(Rel):
    """probe ⋈ build.  ``build`` is the pipeline breaker side (paper §3.2.2)."""
    probe: Rel
    build: Rel
    probe_keys: List[str]
    build_keys: List[str]
    how: str = "inner"                            # inner|left|semi|anti|mark
    mark_name: str = "__mark"
    post_filter: Optional[Expr] = None            # non-equi residual predicate


@dataclasses.dataclass
class AggregateRel(Rel):
    input: Rel
    group_keys: List[str]
    aggs: List[AggSpec]
    having: Optional[Expr] = None


@dataclasses.dataclass
class SortRel(Rel):
    input: Rel
    keys: List[SortKey]
    limit: Optional[int] = None


@dataclasses.dataclass
class FetchRel(Rel):
    input: Rel
    count: int


@dataclasses.dataclass
class ExchangeRel(Rel):
    """Exchange as a dedicated physical operator (paper §3.2.4)."""
    input: Rel
    kind: str                                     # shuffle|broadcast|merge|multicast
    keys: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ScalarSubquery(Expr):
    """Uncorrelated scalar subquery — executed first, bound as a literal.

    DuckDB's optimizer does the same materialization before the plan reaches
    Sirius; we keep the node so plans stay single-tree and serializable.
    """
    plan: Rel
    column: str

    def __hash__(self):
        return id(self)


# ---------------------------------------------------------------------------
# JSON serialization (the "Substrait wire format" of this repro)
# ---------------------------------------------------------------------------

_EXPR_TYPES = {c.__name__: c for c in
               (Col, Lit, BinOp, UnOp, Between, InList, Like, Case,
                ExtractYear, Substr, Cast)}
_REL_TYPES = {c.__name__: c for c in
              (ReadRel, FilterRel, ProjectRel, JoinRel, AggregateRel, SortRel,
               FetchRel, ExchangeRel)}


def _enc(obj: Any) -> Any:
    if isinstance(obj, ScalarSubquery):
        return {"@expr": "ScalarSubquery", "plan": _enc(obj.plan), "column": obj.column}
    if isinstance(obj, Expr):
        d = {"@expr": type(obj).__name__}
        for f in dataclasses.fields(obj):
            d[f.name] = _enc(getattr(obj, f.name))
        return d
    if isinstance(obj, Rel):
        d = {"@rel": type(obj).__name__}
        for f in dataclasses.fields(obj):
            d[f.name] = _enc(getattr(obj, f.name))
        return d
    if isinstance(obj, AggSpec):
        return {"@agg": True, "fn": obj.fn, "expr": _enc(obj.expr), "name": obj.name}
    if isinstance(obj, SortKey):
        return {"@sortkey": True, "name": obj.name, "ascending": obj.ascending}
    if isinstance(obj, (list, tuple)):
        return [_enc(x) for x in obj]
    return obj


def _dec(d: Any) -> Any:
    if isinstance(d, list):
        return [_dec(x) for x in d]
    if not isinstance(d, dict):
        return d
    if "@expr" in d:
        name = d.pop("@expr")
        if name == "ScalarSubquery":
            return ScalarSubquery(_dec(d["plan"]), d["column"])
        cls = _EXPR_TYPES[name]
        kwargs = {k: _dec(v) for k, v in d.items()}
        if name in ("Case",):
            kwargs["whens"] = [tuple(w) for w in kwargs["whens"]]
        return cls(**kwargs)
    if "@rel" in d:
        name = d.pop("@rel")
        cls = _REL_TYPES[name]
        kwargs = {k: _dec(v) for k, v in d.items()}
        if name == "ProjectRel":
            kwargs["exprs"] = [tuple(e) for e in kwargs["exprs"]]
        return cls(**kwargs)
    if d.get("@agg"):
        return AggSpec(d["fn"], _dec(d["expr"]), d["name"])
    if d.get("@sortkey"):
        return SortKey(d["name"], d["ascending"])
    return d


def plan_to_json(plan: Rel) -> str:
    return json.dumps(_enc(plan))


def plan_from_json(s: str) -> Rel:
    return _dec(json.loads(s))


def walk(plan: Rel):
    """Pre-order traversal."""
    yield plan
    for child in plan.inputs():
        yield from walk(child)


def explain(plan: Rel, indent: int = 0) -> str:
    pad = "  " * indent
    name = type(plan).__name__
    extra = ""
    if isinstance(plan, ReadRel):
        extra = f" {plan.table}" + (f" filter={plan.filter!r}" if plan.filter else "")
    elif isinstance(plan, JoinRel):
        extra = f" {plan.how} on {plan.probe_keys}={plan.build_keys}"
    elif isinstance(plan, AggregateRel):
        extra = f" by {plan.group_keys} aggs={[a.name for a in plan.aggs]}"
    elif isinstance(plan, ExchangeRel):
        extra = f" {plan.kind} keys={plan.keys}"
    lines = [f"{pad}{name}{extra}"]
    for child in plan.inputs():
        lines.append(explain(child, indent + 1))
    return "\n".join(lines)
