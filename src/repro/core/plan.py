"""Substrait-like query plan IR (the drop-in boundary of the paper, §3.1-3.2).

The host database layer produces this IR; the execution engine consumes it.
The **primary** producer is the SQL frontend (``repro.sql.sql_to_plan`` /
``run_sql``): SQL text is tokenized, parsed, bound against the catalog and
lowered to this IR, then rewritten by the rule-based optimizer
(``repro.optimizer.optimize``) — the same parse→optimize→Substrait pipeline
DuckDB runs in front of Sirius.  The hand-built TPC-H plan builders in
``repro.data.tpch_queries`` remain as the fallback/oracle path: pre-optimized
plans standing in for DuckDB's output, used to validate the frontend
row-for-row.  Like Substrait, the IR is a tree of relational operators with
embedded scalar expressions and is JSON-round-trippable, so a plan can cross
a process/system boundary — that is what makes Sirius "drop-in".

Node vocabulary mirrors Substrait relations: ReadRel, FilterRel, ProjectRel,
JoinRel, AggregateRel, SortRel, FetchRel (limit), ExchangeRel.

Optimizer passes annotate nodes with ``estimated_rows`` (a plain attribute,
deliberately not a dataclass field so the wire format is unchanged);
``explain`` prints the annotation, which is what the EXPLAIN-level plan
observability of the Terabyte-Scale-Analytics line of work keys on.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp,
)
from ..relational.sort import SortKey


# Leaf tables with this name prefix are hybrid-router cut points: the scan
# reads a materialized fragment result, not a base table (substrait.router).
HYBRID_BOUNDARY_PREFIX = "__substrait_frag"


class Rel:
    """Base class for plan nodes."""

    # Cardinality annotation set by repro.optimizer.annotate (class-level
    # default keeps it out of dataclass fields and the JSON wire format).
    estimated_rows: Optional[float] = None

    def inputs(self) -> List["Rel"]:
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Rel):
                out.append(v)
            elif isinstance(v, list):
                out.extend(x for x in v if isinstance(x, Rel))
        return out


@dataclasses.dataclass
class ReadRel(Rel):
    table: str
    columns: Optional[List[str]] = None           # projection pushdown
    filter: Optional[Expr] = None                 # predicate pushdown


@dataclasses.dataclass
class FilterRel(Rel):
    input: Rel
    condition: Expr


@dataclasses.dataclass
class ProjectRel(Rel):
    input: Rel
    exprs: List[Tuple[str, Expr]]                 # (output name, expression)
    keep_input: bool = False                      # append instead of replace


@dataclasses.dataclass
class JoinRel(Rel):
    """probe ⋈ build.  ``build`` is the pipeline breaker side (paper §3.2.2)."""
    probe: Rel
    build: Rel
    probe_keys: List[str]
    build_keys: List[str]
    how: str = "inner"                            # inner|left|semi|anti|mark
    mark_name: str = "__mark"
    post_filter: Optional[Expr] = None            # non-equi residual predicate


@dataclasses.dataclass
class AggregateRel(Rel):
    input: Rel
    group_keys: List[str]
    aggs: List[AggSpec]
    having: Optional[Expr] = None


@dataclasses.dataclass
class SortRel(Rel):
    input: Rel
    keys: List[SortKey]
    limit: Optional[int] = None


@dataclasses.dataclass
class FetchRel(Rel):
    input: Rel
    count: int


@dataclasses.dataclass
class ExchangeRel(Rel):
    """Exchange as a dedicated physical operator (paper §3.2.4)."""
    input: Rel
    kind: str                                     # shuffle|broadcast|merge|multicast
    keys: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SetRel(Rel):
    """Set operation (UNION ALL).  Part of the interchange vocabulary but not
    of the device pipeline engine — the capability registry routes it to the
    host fallback, exercising Sirius's hybrid-degradation contract."""
    operands: List[Rel]
    op: str = "union_all"


@dataclasses.dataclass
class WindowRel(Rel):
    """Window function over partitions (no frame clause).

    ``row_number``/``rank`` rank rows within a partition by ``order_keys``;
    aggregate functions (sum/count/avg/min/max over ``arg``) broadcast the
    partition-wide value to every row.  Like SetRel, this rel is known to the
    wire format but unsupported on the device engine: ingesting a plan that
    contains one degrades to hybrid execution instead of raising.
    """
    input: Rel
    partition_keys: List[str]
    order_keys: List[SortKey]
    func: str                                     # row_number|rank|sum|count|avg|min|max
    arg: Optional[str] = None                     # input column (aggregates)
    name: str = "__window"


@dataclasses.dataclass
class ScalarSubquery(Expr):
    """Uncorrelated scalar subquery — executed first, bound as a literal.

    DuckDB's optimizer does the same materialization before the plan reaches
    Sirius; we keep the node so plans stay single-tree and serializable.
    """
    plan: Rel
    column: str

    def __hash__(self):
        return id(self)


# ---------------------------------------------------------------------------
# JSON serialization (the "Substrait wire format" of this repro)
# ---------------------------------------------------------------------------

_EXPR_TYPES = {c.__name__: c for c in
               (Col, Lit, BinOp, UnOp, Between, InList, Like, StartsWith,
                Case, ExtractYear, Substr, Cast)}
_REL_TYPES = {c.__name__: c for c in
              (ReadRel, FilterRel, ProjectRel, JoinRel, AggregateRel, SortRel,
               FetchRel, ExchangeRel, SetRel, WindowRel)}


def _enc(obj: Any) -> Any:
    if isinstance(obj, ScalarSubquery):
        return {"@expr": "ScalarSubquery", "plan": _enc(obj.plan), "column": obj.column}
    if isinstance(obj, Expr):
        d = {"@expr": type(obj).__name__}
        for f in dataclasses.fields(obj):
            d[f.name] = _enc(getattr(obj, f.name))
        return d
    if isinstance(obj, Rel):
        d = {"@rel": type(obj).__name__}
        for f in dataclasses.fields(obj):
            d[f.name] = _enc(getattr(obj, f.name))
        return d
    if isinstance(obj, AggSpec):
        return {"@agg": True, "fn": obj.fn, "expr": _enc(obj.expr), "name": obj.name}
    if isinstance(obj, SortKey):
        return {"@sortkey": True, "name": obj.name, "ascending": obj.ascending}
    if isinstance(obj, (list, tuple)):
        return [_enc(x) for x in obj]
    return obj


def _dec(d: Any) -> Any:
    if isinstance(d, list):
        return [_dec(x) for x in d]
    if not isinstance(d, dict):
        return d
    if "@expr" in d:
        name = d.pop("@expr")
        if name == "ScalarSubquery":
            return ScalarSubquery(_dec(d["plan"]), d["column"])
        cls = _EXPR_TYPES[name]
        kwargs = {k: _dec(v) for k, v in d.items()}
        if name in ("Case",):
            kwargs["whens"] = [tuple(w) for w in kwargs["whens"]]
        return cls(**kwargs)
    if "@rel" in d:
        name = d.pop("@rel")
        cls = _REL_TYPES[name]
        kwargs = {k: _dec(v) for k, v in d.items()}
        if name == "ProjectRel":
            kwargs["exprs"] = [tuple(e) for e in kwargs["exprs"]]
        return cls(**kwargs)
    if d.get("@agg"):
        return AggSpec(d["fn"], _dec(d["expr"]), d["name"])
    if d.get("@sortkey"):
        return SortKey(d["name"], d["ascending"])
    return d


def plan_to_json(plan: Rel) -> str:
    return json.dumps(_enc(plan))


def plan_from_json(s: str) -> Rel:
    return _dec(json.loads(s))


def walk(plan: Rel):
    """Pre-order traversal."""
    yield plan
    for child in plan.inputs():
        yield from walk(child)


def rel_exprs(rel: Rel) -> List[Expr]:
    """All Expr objects directly attached to ``rel`` (scan filters, join
    residuals, projection expressions, aggregate measures, having...)."""
    out: List[Expr] = []
    for f in dataclasses.fields(rel):
        v = getattr(rel, f.name)
        if isinstance(v, Expr):
            out.append(v)
        elif isinstance(v, list):
            for item in v:
                if isinstance(item, Expr):
                    out.append(item)
                elif isinstance(item, tuple):
                    out.extend(x for x in item if isinstance(x, Expr))
                elif isinstance(item, AggSpec) and isinstance(item.expr, Expr):
                    out.append(item.expr)
    return out


def walk_deep(plan: Rel):
    """Pre-order traversal that also descends into scalar-subquery sub-plans
    (``walk`` stays expression-blind; capability analysis must not)."""
    from ..relational.expressions import walk_expr

    yield plan
    for e in rel_exprs(plan):
        for node in walk_expr(e):
            if isinstance(node, ScalarSubquery):
                yield from walk_deep(node.plan)
    for child in plan.inputs():
        yield from walk_deep(child)


def _expr_str(e: Expr) -> str:
    """Compact expression rendering: scalar-subquery sub-plans are elided so
    EXPLAIN lines stay one plan node per line."""
    from ..relational.expressions import Col as _Col, transform_expr

    def strip(n):
        if isinstance(n, ScalarSubquery):
            return _Col(f"<scalar-subquery:{n.column}>")
        return n

    return repr(transform_expr(e, strip))


def explain(plan: Rel, indent: int = 0) -> str:
    pad = "  " * indent
    name = type(plan).__name__
    extra = ""
    if isinstance(plan, ReadRel):
        extra = f" {plan.table}"
        if plan.table.startswith(HYBRID_BOUNDARY_PREFIX):
            extra += "  [hybrid boundary]"
        if plan.columns:
            extra += f" cols={plan.columns}"
        if plan.filter is not None:
            extra += f" filter={_expr_str(plan.filter)}"
    elif isinstance(plan, FilterRel):
        extra = f" {_expr_str(plan.condition)}"
    elif isinstance(plan, ProjectRel):
        extra = f" {[n for n, _ in plan.exprs]}"
    elif isinstance(plan, JoinRel):
        extra = f" {plan.how} on {plan.probe_keys}={plan.build_keys}"
        if plan.post_filter is not None:
            extra += " post_filter=..."
    elif isinstance(plan, AggregateRel):
        extra = f" by {plan.group_keys} aggs={[a.name for a in plan.aggs]}"
        if plan.having is not None:
            extra += " having=..."
    elif isinstance(plan, SortRel):
        extra = " by " + ", ".join(
            k.name + ("" if k.ascending else " desc") for k in plan.keys)
        if plan.limit is not None:
            extra += f" limit={plan.limit}"
    elif isinstance(plan, ExchangeRel):
        extra = f" {plan.kind} keys={plan.keys}"
    elif isinstance(plan, SetRel):
        extra = f" {plan.op} over {len(plan.operands)} inputs"
    elif isinstance(plan, WindowRel):
        extra = f" {plan.func}"
        if plan.arg:
            extra += f"({plan.arg})"
        extra += f" partition by {plan.partition_keys}"
        if plan.order_keys:
            extra += " order by " + ", ".join(
                k.name + ("" if k.ascending else " desc")
                for k in plan.order_keys)
        extra += f" as {plan.name}"
    if plan.estimated_rows is not None:
        extra += f"  [~{plan.estimated_rows:,.0f} rows]"
    lines = [f"{pad}{name}{extra}"]
    for child in plan.inputs():
        lines.append(explain(child, indent + 1))
    return "\n".join(lines)


def plan_equal(a: Rel, b: Rel) -> bool:
    """Structural equality over plan trees.

    The dataclass-generated ``__eq__`` on Rel nodes is unusable because the
    embedded Expr nodes overload ``==`` to *build* comparison expressions;
    this compares node types and fields recursively instead.
    """
    from ..relational.expressions import expr_equal

    if type(a) is not type(b):
        return False
    if isinstance(a, AggSpec):
        return (a.fn == b.fn and a.name == b.name
                and expr_equal(a.expr, b.expr, rel_eq=plan_equal))
    if isinstance(a, SortKey):
        return a.name == b.name and a.ascending == b.ascending
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, Rel) or isinstance(vb, Rel):
            if not (isinstance(va, Rel) and isinstance(vb, Rel)
                    and plan_equal(va, vb)):
                return False
        elif isinstance(va, Expr) or isinstance(vb, Expr):
            if not expr_equal(va, vb, rel_eq=plan_equal):
                return False
        elif isinstance(va, (list, tuple)) and isinstance(vb, (list, tuple)):
            if len(va) != len(vb):
                return False
            for xa, xb in zip(va, vb):
                if isinstance(xa, Rel):
                    if not (isinstance(xb, Rel) and plan_equal(xa, xb)):
                        return False
                elif isinstance(xa, (AggSpec, SortKey)):
                    if not plan_equal(xa, xb):
                        return False
                elif not expr_equal(xa, xb, rel_eq=plan_equal):
                    return False
        elif va != vb:
            return False
    return True
