"""Pure-numpy reference engine.

Three roles (DESIGN.md):
  1. the **graceful CPU fallback path** of the paper (§3.2.2) — executes the
     same plan IR when the accelerator engine raises;
  2. the **correctness oracle** for the jnp engine, the static-shape path, the
     Pallas kernels and the distributed executor (independent implementation:
     python strings, datetime64 dates, no dictionary encoding);
  3. the **host-database CPU baseline** for the Figure-4 style benchmark.

Tables are plain ``dict[str, np.ndarray]`` — the "host database format" that
the buffer manager deep-copies from (§3.2.3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence

import numpy as np

from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, BinOp, Case, Cast, Col, Expr, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp, like_to_regex,
)
from ..relational.table import DATE, STRING
from .plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SetRel, SortRel, WindowRel,
)

_EPOCH = np.datetime64("1970-01-01", "D")
HostTable = Dict[str, np.ndarray]


def _num_rows(t: HostTable) -> int:
    return len(next(iter(t.values()))) if t else 0


def _take(t: HostTable, idx: np.ndarray) -> HostTable:
    return {k: v[idx] for k, v in t.items()}


# ---------------------------------------------------------------------------
# numpy expression evaluation
# ---------------------------------------------------------------------------

_ARITH = {"+": np.add, "-": np.subtract, "*": np.multiply, "/": np.divide}
_CMP = {"==": np.equal, "!=": np.not_equal, "<": np.less, "<=": np.less_equal,
        ">": np.greater, ">=": np.greater_equal}


def np_eval(expr: Expr, t: HostTable, engine: "FallbackEngine" = None) -> np.ndarray:
    if isinstance(expr, Col):
        return t[expr.name]
    if isinstance(expr, ScalarSubquery):
        sub = engine.execute(expr.plan)
        return np.full(_num_rows(t), sub[expr.column][0])
    if isinstance(expr, Lit):
        v = expr.value
        if expr.kind == DATE:
            v = _EPOCH + np.timedelta64(int(v), "D")
        return np.full(_num_rows(t), v)
    if isinstance(expr, BinOp):
        if expr.op in ("and", "or"):
            l = np_eval(expr.left, t, engine)
            r = np_eval(expr.right, t, engine)
            return np.logical_and(l, r) if expr.op == "and" else np.logical_or(l, r)
        l = np_eval(expr.left, t, engine)
        r = np_eval(expr.right, t, engine)
        if expr.op in _CMP:
            if l.dtype.kind in "UO" or (hasattr(r, "dtype") and
                                        getattr(r, "dtype", None) is not None
                                        and np.asarray(r).dtype.kind in "UO"):
                l = np.asarray(l, dtype="U")
                r = np.asarray(r, dtype="U")
            return _CMP[expr.op](l, r)
        if expr.op == "/":
            return np.divide(np.asarray(l, np.float64), np.asarray(r, np.float64))
        if l.dtype.kind == "M" and np.asarray(r).dtype.kind == "M":
            return (l - r).astype("timedelta64[D]").astype(np.int64)
        return _ARITH[expr.op](l, r)
    if isinstance(expr, UnOp):
        v = np_eval(expr.operand, t, engine)
        return np.logical_not(v) if expr.op == "not" else -v
    if isinstance(expr, Between):
        v = np_eval(expr.operand, t, engine)
        lo = np_eval(expr.lo, t, engine)
        hi = np_eval(expr.hi, t, engine)
        return (v >= lo) & (v <= hi)
    if isinstance(expr, InList):
        v = np_eval(expr.operand, t, engine)
        if v.dtype.kind in "UO":
            hit = np.isin(np.asarray(v, dtype="U"),
                          np.asarray(list(expr.values), dtype="U"))
        else:
            hit = np.isin(v, list(expr.values))
        return ~hit if expr.negate else hit
    if isinstance(expr, Like):
        v = np.asarray(np_eval(expr.operand, t, engine), dtype="U")
        rx = like_to_regex(expr.pattern)
        hit = np.fromiter((rx.match(s) is not None for s in v), bool, len(v))
        return ~hit if expr.negate else hit
    if isinstance(expr, StartsWith):
        v = np.asarray(np_eval(expr.operand, t, engine), dtype="U")
        hit = np.char.startswith(v, expr.prefix)
        return ~hit if expr.negate else hit
    if isinstance(expr, Case):
        default = np_eval(expr.default, t, engine)
        conds = [np_eval(c, t, engine) for c, _ in expr.whens]
        vals = [np_eval(v, t, engine) for _, v in expr.whens]
        return np.select(conds, vals, default)
    if isinstance(expr, ExtractYear):
        v = np_eval(expr.operand, t, engine)
        return v.astype("datetime64[Y]").astype(np.int64) + 1970
    if isinstance(expr, Substr):
        v = np.asarray(np_eval(expr.operand, t, engine), dtype="U")
        return np.asarray([s[expr.start - 1: expr.start - 1 + expr.length] for s in v])
    if isinstance(expr, Cast):
        return np_eval(expr.operand, t, engine).astype(expr.dtype)
    raise TypeError(f"np_eval: {type(expr)}")


# ---------------------------------------------------------------------------
# join / aggregate on host tables
# ---------------------------------------------------------------------------


def _factorize_pair(l: np.ndarray, r: np.ndarray):
    if l.dtype.kind in "UOM" or r.dtype.kind in "UOM":
        both = np.concatenate([np.asarray(l, "U"), np.asarray(r, "U")]) \
            if l.dtype.kind in "UO" else np.concatenate([l, r])
        uni, inv = np.unique(both, return_inverse=True)
        return inv[: len(l)].astype(np.int64), inv[len(l):].astype(np.int64)
    return l.astype(np.int64), r.astype(np.int64)


def _pack_keys(lcols: List[np.ndarray], rcols: List[np.ndarray]):
    lk, rk = _factorize_pair(lcols[0], rcols[0])
    for lc, rc in zip(lcols[1:], rcols[1:]):
        l2, r2 = _factorize_pair(lc, rc)
        m = min(l2.min(initial=0), r2.min(initial=0))
        l2, r2 = l2 - m, r2 - m
        card = int(max(l2.max(initial=0), r2.max(initial=0))) + 1
        both = np.concatenate([lk, rk])
        uni, inv = np.unique(both, return_inverse=True)
        lk, rk = inv[: len(lk)].astype(np.int64), inv[len(lk):].astype(np.int64)
        lk = lk * card + l2
        rk = rk * card + r2
    return lk, rk


def np_join(probe: HostTable, build: HostTable, pkeys, bkeys, how="inner",
            mark_name="__mark") -> HostTable:
    pk, bk = _pack_keys([probe[k] for k in pkeys], [build[k] for k in bkeys])
    order = np.argsort(bk, kind="stable")
    bks = bk[order]
    lo = np.searchsorted(bks, pk, "left")
    hi = np.searchsorted(bks, pk, "right")
    counts = hi - lo
    if how == "mark":
        out = dict(probe)
        out[mark_name] = counts > 0
        return out
    if how == "semi":
        return _take(probe, np.nonzero(counts > 0)[0])
    if how == "anti":
        return _take(probe, np.nonzero(counts == 0)[0])
    counts_out = np.maximum(counts, 1) if how == "left" else counts
    total = int(counts_out.sum())
    pidx = np.repeat(np.arange(len(pk)), counts_out)
    starts = np.zeros(len(pk), np.int64)
    np.cumsum(counts_out[:-1], out=starts[1:])
    intra = np.arange(total) - np.repeat(starts, counts_out)
    bpos = lo[pidx] + intra
    matched = counts[pidx] > 0
    bpos = np.where(matched, np.clip(bpos, 0, max(len(bk) - 1, 0)), 0)
    bidx = order[bpos] if len(bk) else np.zeros(total, np.int64)
    out = {k: v[pidx] for k, v in probe.items()}
    for k, v in build.items():
        if k not in out:
            out[k] = v[bidx] if len(bk) else np.zeros(total, v.dtype)
    if how == "left":
        out["__matched"] = matched
    return out


def np_group_aggregate(t: HostTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                       engine=None) -> HostTable:
    n = _num_rows(t)
    if keys:
        cols = []
        for k in keys:
            v = t[k]
            if v.dtype.kind in "UOM":
                _, inv = np.unique(np.asarray(v, "U") if v.dtype.kind in "UO" else v,
                                   return_inverse=True)
                cols.append(inv.astype(np.int64))
            else:
                cols.append(v.astype(np.int64))
        packed = cols[0]
        for c in cols[1:]:
            c = c - c.min(initial=0)
            card = int(c.max(initial=0)) + 1
            _, packed = np.unique(packed, return_inverse=True)
            packed = packed.astype(np.int64) * card + c
        uniq, gids = np.unique(packed, return_inverse=True)
        ngroups = len(uniq)
        rep = np.zeros(ngroups, np.int64)
        rep[gids[::-1]] = np.arange(n)[::-1]  # first occurrence index
        out: HostTable = {k: t[k][rep] for k in keys}
    else:
        gids = np.zeros(n, np.int64)
        ngroups = 1
        out = {}
    counts = np.zeros(ngroups, np.int64)
    np.add.at(counts, gids, 1)
    for a in aggs:
        if a.fn == "count_star":
            out[a.name] = counts.copy()
            continue
        v = np_eval(a.expr, t, engine)
        if a.fn == "count":
            out[a.name] = counts.copy()
        elif a.fn == "sum":
            acc = np.zeros(ngroups, np.float64 if v.dtype.kind == "f" else np.int64)
            np.add.at(acc, gids, v.astype(acc.dtype))
            out[a.name] = acc
        elif a.fn == "avg":
            acc = np.zeros(ngroups, np.float64)
            np.add.at(acc, gids, v.astype(np.float64))
            out[a.name] = acc / np.maximum(counts, 1)
        elif a.fn in ("min", "max"):
            if v.dtype.kind in "UO":
                v = np.asarray(v, "U")
            ufunc = np.minimum if a.fn == "min" else np.maximum
            if v.dtype.kind in "UM":
                order = np.lexsort((v,)) if a.fn == "min" else np.lexsort((v,))[::-1]
                acc = np.empty(ngroups, v.dtype)
                acc[gids[order][::-1]] = v[order][::-1]
                out[a.name] = acc
            else:
                init = np.inf if a.fn == "min" else -np.inf
                acc = np.full(ngroups, init)
                ufunc.at(acc, gids, v.astype(np.float64))
                out[a.name] = acc if v.dtype.kind == "f" else acc.astype(v.dtype)
        elif a.fn == "count_distinct":
            pairs = np.unique(np.stack([gids, _factorize_pair(v, v[:0])[0]]), axis=1)
            cd = np.zeros(ngroups, np.int64)
            np.add.at(cd, pairs[0], 1)
            out[a.name] = cd
        else:
            raise ValueError(a.fn)
    return out


def _sortable(a: np.ndarray, ascending: bool = True) -> np.ndarray:
    """Lexsort-ready int/float view of a column (strings → ranks)."""
    if a.dtype.kind in "UO":
        _, inv = np.unique(np.asarray(a, "U"), return_inverse=True)
        a = inv.astype(np.int64)
    if a.dtype.kind == "M":
        a = a.astype(np.int64)
    if a.dtype.kind == "b":
        a = a.astype(np.int8)
    if not ascending:
        a = -a.astype(np.float64) if a.dtype.kind == "f" else -a.astype(np.int64)
    return a


def np_window(t: HostTable, partition_keys: Sequence[str],
              order_keys, func: str, arg, name: str) -> HostTable:
    """WindowRel semantics: rank rows / broadcast partition aggregates."""
    n = _num_rows(t)
    if partition_keys:
        packed = np.zeros(n, np.int64)
        for k in partition_keys:
            c = _sortable(t[k])
            c = c - c.min(initial=0)
            card = int(c.max(initial=0)) + 1
            _, packed = np.unique(packed, return_inverse=True)
            packed = packed.astype(np.int64) * card + c.astype(np.int64)
        _, gids = np.unique(packed, return_inverse=True)
    else:
        gids = np.zeros(n, np.int64)
    ngroups = int(gids.max(initial=0)) + 1 if n else 0
    out = dict(t)
    if func in ("row_number", "rank"):
        arrays = [_sortable(t[k.name], k.ascending) for k in order_keys]
        order = np.lexsort(tuple(reversed(arrays)) + (gids,))
        gsorted = gids[order]
        starts = np.r_[0, np.nonzero(np.diff(gsorted))[0] + 1] \
            if n else np.zeros(0, np.int64)
        group_start = np.zeros(ngroups, np.int64)
        if n:
            group_start[gsorted[starts]] = starts
        pos = np.arange(n) - group_start[gsorted]
        rn = np.empty(n, np.int64)
        rn[order] = pos + 1
        if func == "rank" and arrays:
            # rank: ties (equal order keys within a partition) share the
            # lowest row_number of their run
            key = np.stack([a[order] for a in arrays] + [gsorted])
            new_run = np.r_[True, (np.diff(key) != 0).any(axis=0)] if n \
                else np.zeros(0, bool)
            run_first = np.maximum.accumulate(
                np.where(new_run, np.arange(n), 0))
            rr = np.empty(n, np.int64)
            rr[order] = run_first - group_start[gsorted] + 1
            rn = rr
        out[name] = rn
        return out
    if func != "count" and arg is None:
        raise ValueError(f"window aggregate {func!r} requires an argument "
                         "column")
    v = t[arg].astype(np.float64) if func != "count" else None
    counts = np.zeros(ngroups, np.int64)
    np.add.at(counts, gids, 1)
    if func == "count":
        out[name] = counts[gids]
    elif func == "sum":
        acc = np.zeros(ngroups, np.float64)
        np.add.at(acc, gids, v)
        res = acc[gids]
        out[name] = res if t[arg].dtype.kind == "f" else res.astype(np.int64)
    elif func == "avg":
        acc = np.zeros(ngroups, np.float64)
        np.add.at(acc, gids, v)
        out[name] = (acc / np.maximum(counts, 1))[gids]
    elif func in ("min", "max"):
        ufunc = np.minimum if func == "min" else np.maximum
        acc = np.full(ngroups, np.inf if func == "min" else -np.inf)
        ufunc.at(acc, gids, v)
        res = acc[gids]
        out[name] = res if t[arg].dtype.kind == "f" else res.astype(np.int64)
    else:
        raise ValueError(f"unknown window function {func!r}")
    return out


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class FallbackEngine:
    def __init__(self, tables: Dict[str, HostTable]):
        self.tables = tables

    def execute(self, plan: Rel) -> HostTable:
        if isinstance(plan, ReadRel):
            t = dict(self.tables[plan.table])
            if plan.filter is not None:
                mask = np_eval(plan.filter, t, self)
                t = _take(t, np.nonzero(mask)[0])
            if plan.columns:
                t = {k: t[k] for k in plan.columns if k in t}
            return t
        if isinstance(plan, FilterRel):
            t = self.execute(plan.input)
            return _take(t, np.nonzero(np_eval(plan.condition, t, self))[0])
        if isinstance(plan, ProjectRel):
            t = self.execute(plan.input)
            out = dict(t) if plan.keep_input else {}
            for name, e in plan.exprs:
                out[name] = np_eval(e, t, self)
            return out
        if isinstance(plan, ExchangeRel):
            return self.execute(plan.input)
        if isinstance(plan, JoinRel):
            probe = self.execute(plan.probe)
            build = self.execute(plan.build)
            out = np_join(probe, build, plan.probe_keys, plan.build_keys,
                          plan.how, plan.mark_name)
            if plan.post_filter is not None:
                out = _take(out, np.nonzero(np_eval(plan.post_filter, out, self))[0])
            return out
        if isinstance(plan, AggregateRel):
            t = self.execute(plan.input)
            out = np_group_aggregate(t, plan.group_keys, plan.aggs, self)
            if plan.having is not None:
                out = _take(out, np.nonzero(np_eval(plan.having, out, self))[0])
            return out
        if isinstance(plan, SortRel):
            t = self.execute(plan.input)
            arrays = []
            for k in plan.keys:
                a = t[k.name]
                if a.dtype.kind in "UO":
                    a = np.asarray(a, "U")
                    uni, inv = np.unique(a, return_inverse=True)
                    a = inv.astype(np.int64)
                if a.dtype.kind == "M":
                    a = a.astype(np.int64)
                if a.dtype.kind == "b":
                    a = a.astype(np.int8)
                if not k.ascending:
                    a = -a.astype(np.float64) if a.dtype.kind == "f" else -a.astype(np.int64)
                arrays.append(a)
            order = np.lexsort(tuple(reversed(arrays)))
            if plan.limit is not None:
                order = order[: plan.limit]
            return _take(t, order)
        if isinstance(plan, FetchRel):
            t = self.execute(plan.input)
            return _take(t, np.arange(min(plan.count, _num_rows(t))))
        if isinstance(plan, SetRel):
            if plan.op != "union_all":
                raise ValueError(f"unsupported set op {plan.op!r}")
            if not plan.operands:
                raise ValueError("SetRel requires at least one operand")
            parts = [self.execute(p) for p in plan.operands]
            cols = list(parts[0])
            return {k: np.concatenate([np.asarray(p[k]) for p in parts])
                    for k in cols}
        if isinstance(plan, WindowRel):
            t = self.execute(plan.input)
            return np_window(t, plan.partition_keys, plan.order_keys,
                             plan.func, plan.arg, plan.name)
        raise TypeError(type(plan))
