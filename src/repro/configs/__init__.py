"""Assigned architecture configs (+ the paper's own sirius-tpch workload)."""
from .base import (  # noqa: F401
    ArchConfig, LM_SHAPES, MambaCfg, MLACfg, MoECfg, Shape, all_configs,
    get_config, reduced, register,
)
