"""Architecture config schema for the assigned model suite.

Each assigned architecture gets one module in this package defining CONFIG
(exact published numbers, source cited in the assignment) plus the reduced
smoke-test variant via ``reduced()``.  ``--arch <id>`` resolves through
``repro.configs.get_config``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class MoECfg:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass
class MambaCfg:
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4
    dt_rank: Optional[int] = None          # default ceil(d_model/16)


@dataclasses.dataclass
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                               # train | prefill | decode


# the assigned shape set (LM family)
LM_SHAPES = [
    Shape("train_4k", 4_096, 256, "train"),
    Shape("prefill_32k", 32_768, 32, "prefill"),
    Shape("decode_32k", 32_768, 128, "decode"),
    Shape("long_500k", 524_288, 1, "decode"),
]


@dataclasses.dataclass
class ArchConfig:
    name: str
    family: str                             # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    moe_every: int = 1                      # MoE layer cadence (jamba: 2)
    first_dense_layers: int = 0             # deepseek: layer 0 is dense FFN
    mamba: Optional[MambaCfg] = None
    mla: Optional[MLACfg] = None
    # hybrid pattern: for each layer index in a period, 'attn' or 'mamba'
    period: int = 1
    attn_idx_in_period: Tuple[int, ...] = (0,)
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                        # fixed encoder frames (stub frontend)
    # vlm (llava)
    n_img_tiles: int = 0                    # anyres tiles per sample
    img_patches: int = 0                    # patch embeddings per tile
    dtype: str = "bfloat16"
    mlp_kind: str = "swiglu"                # swiglu (3 mats) | gelu (2 mats)
    # which assigned shapes apply (long_500k only for sub-quadratic archs)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 for even ('model',) sharding of the
        embedding/head tables (MaxText-style padding; loss masks the tail)."""
        return ((self.vocab + 255) // 256) * 256

    def shapes(self) -> List[Shape]:
        out = [s for s in LM_SHAPES if s.name not in self.skip_shapes]
        return out

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        per_layer_attn = 0
        if self.mla is not None:
            m = self.mla
            q_dim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            per_layer_attn = (d * q_dim                       # W_q
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * self.n_heads
                              * (m.qk_nope_head_dim + m.v_head_dim)
                              + self.n_heads * m.v_head_dim * d)
        else:
            per_layer_attn = (d * self.n_heads * hd
                              + 2 * d * self.n_kv_heads * hd
                              + self.n_heads * hd * d)

        def ffn_params(ff):
            return (3 if self.mlp_kind == "swiglu" else 2) * d * ff

        def moe_params():
            m = self.moe
            routed = m.n_experts * ffn_params(m.expert_d_ff)
            shared = m.n_shared * ffn_params(m.expert_d_ff)
            return routed + shared + d * m.n_experts

        def mamba_params():
            mm = self.mamba
            d_in = mm.expand * d
            dt_rank = mm.dt_rank or -(-d // 16)
            return (d * 2 * d_in + d_in * mm.d_conv
                    + d_in * (dt_rank + 2 * mm.d_state) + dt_rank * d_in
                    + d_in * mm.d_state + d_in + d_in * d)

        total = 0
        for li in range(self.n_layers):
            in_period = li % self.period
            is_attn = in_period in self.attn_idx_in_period
            if self.family in ("ssm",) or (self.family == "hybrid" and not is_attn):
                total += mamba_params()
            else:
                total += per_layer_attn
            if self.moe is not None and li >= self.first_dense_layers \
                    and (li % self.moe_every == (self.moe_every - 1)):
                total += moe_params()
            elif self.family != "ssm":
                total += ffn_params(self.d_ff)
            total += 2 * d  # norms
        if self.family == "ssm":
            pass
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        if self.enc_layers:
            total += self.enc_layers * (per_layer_attn + ffn_params(self.d_ff)
                                        + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(
            1 for li in range(self.n_layers)
            if li >= self.first_dense_layers
            and li % self.moe_every == (self.moe_every - 1))
        per_expert = 3 * self.d_model * m.expert_d_ff
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return full - inactive


_REGISTRY: Dict[str, "ArchConfig"] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import (  # noqa: F401
        deepseek_v2_lite_16b, falcon_mamba_7b, jamba_v01_52b,
        llama32_3b, llava_next_mistral_7b, phi35_moe_42b, qwen2_72b,
        qwen2_7b, qwen3_4b, whisper_medium,
    )


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    small = dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, max(cfg.period, 2) * 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        head_dim=16,
        d_ff=128,
        vocab=503,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=32 if cfg.enc_seq else 0,
        n_img_tiles=2 if cfg.n_img_tiles else 0,
        img_patches=8 if cfg.img_patches else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        small.moe = MoECfg(n_experts=min(cfg.moe.n_experts, 8),
                           top_k=min(cfg.moe.top_k, 2),
                           expert_d_ff=64, n_shared=cfg.moe.n_shared and 1)
    if cfg.mamba is not None:
        small.mamba = MambaCfg(d_state=8, expand=2, d_conv=4)
    if cfg.mla is not None:
        small.mla = MLACfg(kv_lora_rank=32, qk_nope_head_dim=16,
                           qk_rope_head_dim=8, v_head_dim=16)
    return small
