"""phi3.5-moe-42b-a6.6b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=6400 vocab=32064.

16 experts, top-2 routing, every layer MoE.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from .base import ArchConfig, MoECfg, register

CONFIG = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, head_dim=128,
    moe=MoECfg(n_experts=16, top_k=2, expert_d_ff=6400, n_shared=0),
    skip_shapes=("long_500k",),
))
