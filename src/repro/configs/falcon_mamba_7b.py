"""falcon-mamba-7b [ssm] — 64L d=4096 attn-free vocab=65024 ssm_state=16.

Mamba1 architecture (selective SSM, depthwise causal conv, expand=2).
Runs long_500k (sub-quadratic decode).  [arXiv:2410.05355; unverified]
"""
from .base import ArchConfig, MambaCfg, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024, mamba=MambaCfg(d_state=16, expand=2, d_conv=4),
    attn_idx_in_period=(),   # no attention layers at all
))
