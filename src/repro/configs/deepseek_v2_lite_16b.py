"""deepseek-v2-lite-16b [moe] — 27L d=2048 16H d_ff(expert)=1408 vocab=102400.

MLA attention (kv_lora_rank=512, rope head 64, nope 128, v 128); MoE with
64 routed experts top-6 + 2 shared; layer 0 dense FFN (d_ff=10944).
The assignment line lists both "64e top-6" and "160 routed"; we follow the
HF V2-Lite config (64 routed) — see DESIGN.md §Config fidelity.
[arXiv:2405.04434; hf]
"""
from .base import ArchConfig, MLACfg, MoECfg, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400,
    mla=MLACfg(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
               v_head_dim=128),
    moe=MoECfg(n_experts=64, top_k=6, expert_d_ff=1408, n_shared=2),
    first_dense_layers=1,
    skip_shapes=("long_500k",),   # MLA is still full attention
))
