"""jamba-v0.1-52b [hybrid] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba+attention 1:7 interleave (period 8, attention at in-period index 4),
MoE 16 experts top-2 on every 2nd layer.  Runs long_500k (hybrid is
sub-quadratic-dominated).  [arXiv:2403.19887; hf]
"""
from .base import ArchConfig, MambaCfg, MoECfg, register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, head_dim=128,
    mamba=MambaCfg(d_state=16, expand=2, d_conv=4),
    moe=MoECfg(n_experts=16, top_k=2, expert_d_ff=14336, n_shared=0),
    moe_every=2,
    period=8, attn_idx_in_period=(4,),
))
