"""llava-next-mistral-7b [vlm] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Transformer BACKBONE only; the anyres vision frontend is a STUB —
input_specs() provides precomputed patch embeddings (16 tiles x 576 patches)
prepended to the token sequence.  [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, rope_theta=1_000_000.0,
    n_img_tiles=16, img_patches=576,
    skip_shapes=("long_500k",),
))
