"""whisper-medium [audio] — 24+24L d=1024 16H d_ff=4096 vocab=51865.

Encoder-decoder; conv frontend is a STUB — input_specs() provides 1500
precomputed frame embeddings.  Decoder runs the decode shapes (enc-dec, not
encoder-only); decoder positions beyond the trained 448 are a shape exercise,
noted in DESIGN.md.  [arXiv:2212.04356; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, rope_theta=0.0,      # learned/sinusoidal positions, no rope
    enc_layers=24, enc_seq=1500, mlp_kind="gelu",
    skip_shapes=("long_500k",),
))
