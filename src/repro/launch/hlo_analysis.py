"""HLO text analysis: collective bytes for the roofline's third term.

`cost_analysis()` has no collective accounting, so we parse the optimized
HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its result-shape bytes (all-reduce counts
double: reduce-scatter + all-gather equivalent).  Collectives inside while
bodies (scan'd layers) are multiplied by the loop trip count, recovered from
the largest integer constant in the loop condition (best effort — validated
against a known scan+psum program in tests), with nested loops multiplying.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[sufbc]\d+|bf16)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, list] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and ("->" in stripped
                                           or stripped.startswith("ENTRY")):
                name = stripped.split()[0].lstrip("%")
                if name == "ENTRY":
                    name = stripped.split()[1].lstrip("%")
                current = name
                comps[current] = []
        else:
            if stripped == "}":
                current = None
            else:
                comps[current].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


_CALL_RE = re.compile(
    r"\b(body|condition|calls|to_apply|branch_computations)="
    r"(\{[^}]*\}|%?[\w\.\-]+)")


def _effective_multipliers(comps: Dict[str, str]) -> Dict[str, float]:
    """Loop-trip multiplier per computation: while bodies multiply by their
    trip count; fusion/call/to_apply children inherit the caller's."""
    children: Dict[str, list] = defaultdict(list)   # parent → [(child, kind)]
    base_trip: Dict[str, int] = {}
    referenced = set()
    for cname, body in comps.items():
        for m in _CALL_RE.finditer(body):
            kind = m.group(1)
            names = [n.strip().lstrip("%")
                     for n in m.group(2).strip("{}").split(",")]
            for child in names:
                if child not in comps:
                    continue
                referenced.add(child)
                children[cname].append((child, kind))
                if kind == "body":
                    # trip count from the sibling condition computation
                    cond_m = re.search(
                        r"condition=%?([\w\.\-]+)", body[max(0, m.start()-200):
                                                         m.end()+200])
                    cond = cond_m.group(1) if cond_m else None
                    consts = [int(c) for c in
                              _CONST_RE.findall(comps.get(cond, ""))]
                    base_trip[child] = max(consts) if consts else 1

    eff: Dict[str, float] = defaultdict(lambda: 1.0)

    def propagate(cname: str, mult: float, depth: int):
        if depth > 50:
            return
        for child, kind in children.get(cname, []):
            m = mult * (base_trip.get(child, 1) if kind == "body" else 1)
            if m > eff[child]:
                eff[child] = m
                propagate(child, m, depth + 1)

    for root in comps:
        if root not in referenced:
            eff[root] = 1.0
            propagate(root, 1.0, 0)
    return eff


def collective_bytes(hlo: str) -> Dict[str, float]:
    """→ {'all-reduce': bytes, ..., 'total': bytes, 'loops_detected': 0/1}."""
    comps = _computations(hlo)
    eff = _effective_multipliers(comps)

    totals: Dict[str, float] = defaultdict(float)
    any_loops = False
    for cname, body in comps.items():
        mult = eff[cname]
        if mult > 1:
            any_loops = True
        for line in body.splitlines():
            m = _OP_RE.search(line)
            if not m or "-done(" in line:
                continue
            op = m.group(1)
            lhs = line.split("=", 1)
            if len(lhs) < 2:
                continue
            # result shapes: everything before the op token on the rhs
            pre = lhs[1][: m.start(1) - len(lhs[0]) - 1]
            shapes = _SHAPE_RE.findall(pre)
            if not shapes:
                continue
            b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            factor = 2.0 if op == "all-reduce" else 1.0
            totals[op] += b * factor * mult

    out = dict(totals)
    out["total"] = sum(totals.values())
    out["loops_detected"] = float(any_loops)
    return out


def hbm_traffic_estimate(cost: dict) -> float:
    for k in ("bytes accessed",):
        if k in cost:
            return float(cost[k])
    return sum(float(v) for k, v in cost.items()
               if k.startswith("bytes accessed"))


# ---------------------------------------------------------------------------
# loop-corrected FLOPs (XLA's cost_analysis counts while bodies ONCE)
# ---------------------------------------------------------------------------

_DOT_LINE_RE = re.compile(r"=\s*.*?\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(?:\()?(pred|[sufbc]\d+|bf16)\[([\d,]*)\]")
# operands may carry an inline `f32[64,128]{1,0}` type prefix (newer HLO
# emitters) or be a bare `%name` reference — accept both
_OPERAND = (r"(?:(?:pred|[sufbc]\d+|bf16)\[[\d,]*\]"
            r"(?:\{[\d,]*\})?\s+)?%?([\w\.\-]+)")
_DOT_ARGS_RE = re.compile(r"\bdot\(\s*" + _OPERAND + r"\s*,\s*" + _OPERAND)


def dot_flops(hlo: str) -> float:
    """Matmul FLOPs with loop trip counts applied.

    flops(dot) = 2 × |result| × (product of lhs contracting dim sizes).
    Operand shapes are resolved through a per-computation symbol table
    (HLO bodies reference operands by name only).  Elementwise FLOPs are not
    counted (matmuls dominate every assigned workload); pair with
    cost_analysis and take the max.
    """
    comps = _computations(hlo)
    eff = _effective_multipliers(comps)
    total = 0.0
    for cname, body in comps.items():
        mult = eff[cname]
        symbols: Dict[str, list] = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if dm:
                symbols[dm.group(1)] = [int(d) for d in
                                        dm.group(3).split(",") if d]
        for line in body.splitlines():
            if not _DOT_LINE_RE.search(line):
                continue
            dm = _DEF_RE.match(line)
            am = _DOT_ARGS_RE.search(line)
            cm = _CONTRACT_RE.search(line)
            if not (dm and am and cm):
                continue
            result_dims = [int(d) for d in dm.group(3).split(",") if d]
            lhs_dims = symbols.get(am.group(1))
            if lhs_dims is None:
                # operand may carry an inline shape (entry computations)
                inline = _SHAPE_RE.findall(line.split("dot(", 1)[1])
                lhs_dims = ([int(d) for d in inline[0][1].split(",") if d]
                            if inline else None)
            if lhs_dims is None:
                continue
            cdims = [int(i) for i in cm.group(1).split(",") if i]
            contract = 1
            for i in cdims:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
            res = 1
            for d in result_dims:
                res *= d
            total += 2.0 * res * contract * mult
    return total


def loop_corrected_flops(hlo: str, cost_flops: float) -> dict:
    df = dot_flops(hlo)
    return {"cost_analysis_flops": cost_flops,
            "dot_flops_loop_corrected": df,
            "flops": max(df, cost_flops)}
