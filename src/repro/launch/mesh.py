"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sql_mesh(*, multi_pod: bool = False):
    """SQL-engine mesh: fragments shard over a flat 'data' axis (one shard
    per chip; the pod axis nests for hierarchical shuffles)."""
    if multi_pod:
        return jax.make_mesh((2, 256), ("pod", "data"))
    return jax.make_mesh((256,), ("data",))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh (pod folds into data parallelism)."""
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)
