"""SQL-engine fragment dry-run: the paper's own workload on the production mesh.

Lowers whole TPC-H SF100 distributed fragments — scan→filter→(semi join)→
shuffle→join→aggregate→top-k — as ONE compiled shard_map program per
fragment (the compiled-pipeline fusion the eager libcudf engine cannot do,
DESIGN.md §2).  Single-pod: flat 256-shard 'data' mesh; multi-pod: 2 pods ×
256, with the **hierarchical pod-aware shuffle**.

Money columns are f32 on the TPU path (v5e has no native f64; the runnable
CPU engine keeps f64, and the precision strategy — int64-cents fixed point —
is documented in DESIGN.md §2).
"""
from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.static_ops import local_sort_agg, static_inner_join, static_semi_join, static_topk
from ..core import compat
from ..exchange.service import Frame, shuffle, shuffle_hierarchical
from ..relational.table import date_to_days
from .mesh import make_sql_mesh

SF = 100
ROWS = {
    "lineitem": int(6_001_215 * SF),
    "orders": int(1_500_000 * SF),
    "customer": int(150_000 * SF),
}


def _round_up(x: int, m: int = 128) -> int:
    return ((x + m - 1) // m) * m


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _caps(n_shards: int):
    return {t: _round_up(-(-r // n_shards)) for t, r in ROWS.items()}


def q3_inputs(n_shards: int, compress: bool = False):
    """compress=True: planner-narrowed physical types (paper future work —
    'lightweight compression'): SF100 orderkeys fit int32, discount is a
    dictionary of 11 two-decimal values → uint8 codes, shipdate stays int32,
    money f32.  Halves the dominant shuffle/sort payload widths."""
    c = _caps(n_shards)
    key_t = "int32" if compress else "int64"
    disc_t = "uint8" if compress else "float32"
    li = {
        "l_orderkey": _sds((n_shards * c["lineitem"],), key_t),
        "l_extendedprice": _sds((n_shards * c["lineitem"],), "float32"),
        "l_discount": _sds((n_shards * c["lineitem"],), disc_t),
        "l_shipdate": _sds((n_shards * c["lineitem"],), "int32"),
    }
    oo = {
        "o_orderkey": _sds((n_shards * c["orders"],), key_t),
        "o_custkey": _sds((n_shards * c["orders"],), key_t),
        "o_orderdate": _sds((n_shards * c["orders"],), "int32"),
        "o_shippriority": _sds((n_shards * c["orders"],), "int8"
                               if compress else "int32"),
    }
    cu = {
        "c_custkey": _sds((n_shards * c["customer"],), key_t),
        "c_mktsegment": _sds((n_shards * c["customer"],), "int8"
                             if compress else "int32"),
    }
    valid = {t: _sds((n_shards * c[t],), "bool")
             for t in ("lineitem", "orders", "customer")}
    return li, oo, cu, valid, c


def build_q3_fragment(multi_pod: bool, predicate_transfer: bool = False,
                      compress: bool = False):
    """→ (jitted fn, input ShapeDtypeStructs).  One fused fragment.

    predicate_transfer=True inserts the Bloom pre-filter (beyond-paper,
    DESIGN.md §7): lineitem rows that cannot join any filtered order are
    dropped before the all_to_all.
    """
    mesh = make_sql_mesh(multi_pod=multi_pod)
    n_data = mesh.shape["data"]
    n_shards = n_data * (mesh.shape.get("pod", 1))
    li, oo, cu, valid, caps = q3_inputs(n_shards, compress)
    cutoff = date_to_days("1995-03-15")
    seg_code = 1  # BUILDING's dictionary code (structural stand-in)
    slack = 2.0
    # Predicate transfer tightens the planner's lineitem-shuffle cardinality
    # estimate: only ~9%% of lineitem joins a BUILDING+date-filtered order
    # (catalog estimate + Bloom FP margin) → smaller static buckets → fewer
    # all_to_all bytes in the compiled fragment.
    pt_sel = 0.15 if predicate_transfer else 1.0
    o_out = _round_up(int(caps["orders"] * slack / n_data) + 8, 8)
    l_out = _round_up(int(caps["lineitem"] * slack * pt_sel / n_data) + 8, 8)
    o_pod = _round_up(int(caps["orders"] * slack / 2) + 8, 8)
    l_pod = _round_up(int(caps["lineitem"] * slack * pt_sel / 2) + 8, 8)
    TOPK = 10

    def fragment(lcols, lvalid, ocols, ovalid, ccols, cvalid):
        # customer filter + co-located semi join
        cmask = cvalid & (ccols["c_mktsegment"] == seg_code)
        ofr = Frame({k: ocols[k] for k in ("o_orderkey", "o_orderdate",
                                           "o_shippriority")},
                    ovalid & (ocols["o_orderdate"] < cutoff))
        ofr = static_semi_join(ofr, ocols["o_custkey"], ccols["c_custkey"],
                               cmask)
        # exchange: orders shuffled to orderkey shards
        if multi_pod:
            ofr, ov1 = shuffle_hierarchical(ofr, "o_orderkey", "pod", "data",
                                            o_pod, o_out)
        else:
            ofr, ov1 = shuffle(ofr, ofr.columns["o_orderkey"], "data", o_out)
        # lineitem filter (+ optional Bloom predicate transfer) + shuffle
        lmask = lvalid & (lcols["l_shipdate"] > cutoff)
        if predicate_transfer:
            from ..exchange.bloom import (
                bloom_build, bloom_maybe_contains, bloom_or_across)
            axes = ("pod", "data") if multi_pod else ("data",)
            bloom = bloom_or_across(
                bloom_build(ofr.columns["o_orderkey"], ofr.valid, 1 << 22),
                axes)
            lmask = lmask & bloom_maybe_contains(bloom, lcols["l_orderkey"])
        lfr = Frame({k: lcols[k] for k in ("l_orderkey", "l_extendedprice",
                                           "l_discount")}, lmask)
        if multi_pod:
            lfr, ov2 = shuffle_hierarchical(lfr, "l_orderkey", "pod", "data",
                                            l_pod, l_out)
        else:
            lfr, ov2 = shuffle(lfr, lfr.columns["l_orderkey"], "data", l_out)
        # co-located PK-FK join + grouped agg + local top-k
        j = static_inner_join(lfr, lfr.columns["l_orderkey"], ofr,
                              ofr.columns["o_orderkey"])
        disc = j.columns["l_discount"]
        if compress:   # dequantize the dictionary code at use
            disc = disc.astype(jnp.float32) * 0.01
        rev = j.columns["l_extendedprice"] * (1.0 - disc)
        agg, _ = local_sort_agg(
            j, j.columns["l_orderkey"], sums={"revenue": rev},
            firsts={"o_orderdate": j.columns["o_orderdate"],
                    "o_shippriority": j.columns["o_shippriority"]})
        top = static_topk(agg, agg.columns["revenue"], TOPK)
        return (top.columns["key"], top.columns["revenue"],
                top.columns["o_orderdate"], top.columns["o_shippriority"],
                top.valid, ov1 + ov2)

    spec = P(("pod", "data")) if multi_pod else P("data")
    fn = jax.jit(compat.shard_map(
        fragment, mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(spec, spec, spec, spec, spec, P())))
    args = (li, valid["lineitem"], oo, valid["orders"], cu,
            valid["customer"])
    return fn, args, {"n_shards": n_shards, "caps": caps,
                      "shuffle_out_caps": {"orders": o_out, "lineitem": l_out}}


def build_q1_fragment(multi_pod: bool):
    """Q1: scan→filter→9-group aggregate→psum (compute-bound contrast)."""
    mesh = make_sql_mesh(multi_pod=multi_pod)
    n_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    c = _caps(n_shards)["lineitem"]
    cutoff = date_to_days("1998-09-02")
    G = 9
    cols = {
        "l_shipdate": _sds((n_shards * c,), "int32"),
        "l_returnflag": _sds((n_shards * c,), "int32"),
        "l_linestatus": _sds((n_shards * c,), "int32"),
        "l_quantity": _sds((n_shards * c,), "float32"),
        "l_extendedprice": _sds((n_shards * c,), "float32"),
        "l_discount": _sds((n_shards * c,), "float32"),
        "l_tax": _sds((n_shards * c,), "float32"),
    }
    vspec = _sds((n_shards * c,), "bool")
    axes = ("pod", "data") if multi_pod else ("data",)

    def fragment(cc, valid):
        mask = valid & (cc["l_shipdate"] <= cutoff)
        gid = cc["l_returnflag"] * 3 + cc["l_linestatus"]
        gid = jnp.where(mask, gid, G)
        ext, disc = cc["l_extendedprice"], cc["l_discount"]
        disc_price = ext * (1.0 - disc)
        vals = jnp.stack([cc["l_quantity"], ext, disc_price,
                          disc_price * (1.0 + cc["l_tax"]), disc,
                          jnp.ones_like(ext)], axis=1)
        vals = jnp.where(mask[:, None], vals, 0.0)
        partial = jax.ops.segment_sum(vals, gid, G + 1)[:G]
        for ax in axes:
            partial = jax.lax.psum(partial, ax)
        return partial

    spec = P(("pod", "data")) if multi_pod else P("data")
    fn = jax.jit(compat.shard_map(fragment, mesh=mesh,
                                  in_specs=(spec, spec), out_specs=P()))
    return fn, (cols, vspec), {"n_shards": n_shards, "cap": c}


def lower_sql_fragment(shape_name: str, multi_pod: bool):
    t0 = time.time()
    if shape_name.startswith("q3"):
        variant = shape_name.split("_")[0][2:]     # '', 'pt', 'ptc', 'c'
        fn, args, extra = build_q3_fragment(
            multi_pod, predicate_transfer="pt" in variant,
            compress="c" in variant)
    elif shape_name.startswith("q1"):
        fn, args, extra = build_q1_fragment(multi_pod)
    else:
        raise ValueError(f"unknown sql dry-run shape {shape_name}")
    lowered = fn.lower(*args)
    lt = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    ct = time.time() - t0
    extra = {"kind": "sql-fragment", "sf": SF, **extra}
    return compiled, lt, ct, extra
