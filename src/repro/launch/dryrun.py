import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices.  Do not set that flag globally (smoke tests and benches
must see 1 device).

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. declares every model input as ShapeDtypeStruct (no allocation),
  3. jit(...).lower(...).compile() with explicit in/out shardings,
  4. records memory_analysis() (proves per-chip fit vs the 16 GB v5e budget)
     and cost_analysis() FLOPs/bytes + HLO collective bytes → JSON artifact
     consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --sweep            # every cell, both meshes
  python -m repro.launch.dryrun --arch sirius-tpch ...   # SQL fragments
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.base import ArchConfig, Shape, all_configs, get_config  # noqa: E402
from ..core import compat  # noqa: E402
from .hlo_analysis import (  # noqa: E402
    collective_bytes, hbm_traffic_estimate, loop_corrected_flops,
)
from .mesh import data_axes, make_production_mesh, make_sql_mesh  # noqa: E402

HBM_PER_CHIP = 16 * 1024**3          # v5e
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: Shape) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), "int32"),
                 "targets": _sds((b, s), "int32")}
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), "int32")}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": _sds((b, 1), "int32")}
    if cfg.n_img_tiles and shape.kind != "decode":
        n_img = cfg.n_img_tiles * cfg.img_patches
        batch["img_embeds"] = _sds((b, n_img, cfg.d_model), cfg.dtype)
    if cfg.enc_layers and shape.kind != "decode":
        batch["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return batch


# ---------------------------------------------------------------------------
# sharding spec builders
# ---------------------------------------------------------------------------


def _batch_spec(mesh, b: int) -> P:
    axes = data_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if b % n == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P()          # e.g. long_500k batch=1: no batch parallelism


def cache_shardings(cache_struct, mesh, b):
    """KV/latent caches: batch over data axes, sequence over 'model'."""
    bspec = _batch_spec(mesh, b)
    baxes = bspec[0] if len(bspec) else None

    def leaf(path, x):
        name = path[-1] if path else ""
        nd = len(x.shape)

        def pad(tail):
            # stacked caches carry a leading scan-periods dim → pad left
            return NamedSharding(mesh, P(*([None] * (nd - len(tail))
                                           + list(tail))))

        if name == "length":
            return NamedSharding(mesh, P(baxes) if baxes else P())
        if name in ("k", "v"):            # (…, B, S, KVH, hd): S over model
            return pad([baxes, "model", None, None])
        if name in ("ckv", "krope"):      # (…, B, S, rank): S over model
            return pad([baxes, "model", None])
        if name == "enc_out":             # (B, 1500, d): d over model
            return pad([baxes, None, "model"])
        if name == "conv":                # (…, B, K-1, din): din over model
            return pad([baxes, None, "model"])
        if name == "ssm":                 # (…, B, din, N): din over model
            return pad([baxes, "model", None])
        if nd >= 1:
            return NamedSharding(mesh, P(*([None] * nd)))
        return NamedSharding(mesh, P())

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        return leaf(path, tree)

    return walk(cache_struct)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    from ..models import lm
    from ..training.train_step import (
        batch_shardings, make_train_step, param_shardings, state_shardings,
    )
    from ..training.optimizer import init_opt_state

    cfg = get_config(arch)
    shape = next(s for s in cfg.shapes() if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    b = shape.global_batch
    batch_struct = input_specs(cfg, shape)

    compat.set_mesh(mesh)   # ambient mesh: activation constraints bind
    t0 = time.time()
    if shape.kind == "train":
        state_struct = jax.eval_shape(
            lambda: {"params": lm.init_params(jax.random.PRNGKey(0), cfg),
                     "opt": init_opt_state(
                         lm.init_params(jax.random.PRNGKey(0), cfg))})
        n_exp = cfg.moe.n_experts if cfg.moe else None
        in_sh = (state_shardings(state_struct, mesh, fsdp=True,
                                 n_experts=n_exp),
                 batch_shardings(batch_struct, mesh))
        step = make_train_step(cfg)
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=(in_sh[0], None)).lower(
            state_struct, batch_struct)
    elif shape.kind == "prefill":
        params_struct = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        # serving params are bf16 TP-sharded
        params_struct = jax.tree.map(
            lambda x: _sds(x.shape, cfg.dtype), params_struct)
        n_exp = cfg.moe.n_experts if cfg.moe else None
        p_sh = param_shardings(params_struct, mesh, fsdp=False,
                               n_experts=n_exp)
        b_sh = batch_shardings(batch_struct, mesh)

        def serve_prefill(params, batch):
            return lm.prefill(params, cfg, batch["tokens"],
                              img_embeds=batch.get("img_embeds"),
                              frames=batch.get("frames"))

        lowered = jax.jit(serve_prefill, in_shardings=(p_sh, b_sh)).lower(
            params_struct, batch_struct)
    else:  # decode
        params_struct = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        params_struct = jax.tree.map(
            lambda x: _sds(x.shape, cfg.dtype), params_struct)
        n_exp = cfg.moe.n_experts if cfg.moe else None
        p_sh = param_shardings(params_struct, mesh, fsdp=False,
                               n_experts=n_exp)
        cache_struct = jax.eval_shape(
            lambda: lm.init_cache(cfg, b, shape.seq_len))
        c_sh = cache_shardings(cache_struct, mesh, b)
        tok_sh = {"tokens": NamedSharding(mesh, _batch_spec(mesh, b))}

        def serve_decode(params, cache, batch):
            return lm.decode_step(params, cfg, cache, batch["tokens"])

        lowered = jax.jit(
            serve_decode, in_shardings=(p_sh, c_sh, tok_sh),
            out_shardings=(None, c_sh)).lower(
            params_struct, cache_struct, batch_struct)
    lower_time = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_time = time.time() - t0
    return cfg, shape, compiled, lower_time, compile_time


def analyze(compiled, n_chips: int) -> dict:
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    flops = loop_corrected_flops(hlo, float(cost.get("flops", 0.0)))
    out = {
        "flops_per_device": flops["flops"],
        "flops_detail": flops,
        "bytes_accessed_per_device": hbm_traffic_estimate(cost),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem,
                                            "generated_code_size_in_bytes",
                                            None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "n_chips": n_chips,
    }
    arg = out["memory"]["argument_bytes"] or 0
    tmp = out["memory"]["temp_bytes"] or 0
    outb = out["memory"]["output_bytes"] or 0
    alias = out["memory"]["alias_bytes"] or 0
    # aliased outputs (donated state) do not double-count
    resident = arg + tmp + max(outb - alias, 0)
    out["memory"]["resident_bytes_per_chip"] = resident
    out["memory"]["fits_16gb_v5e"] = bool(resident <= HBM_PER_CHIP)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: Optional[str] = None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "ok"}
    try:
        if arch == "sirius-tpch":
            from .sql_dryrun import lower_sql_fragment
            compiled, lt, ct, extra = lower_sql_fragment(
                shape_name, multi_pod=multi_pod)
            record.update(extra)
        else:
            cfg, shape, compiled, lt, ct = lower_cell(arch, shape_name,
                                                      multi_pod)
            record["model_params"] = cfg.param_count()
            record["active_params"] = cfg.active_param_count()
            record["seq_len"] = shape.seq_len
            record["global_batch"] = shape.global_batch
            record["kind"] = shape.kind
        record.update(analyze(compiled, n_chips))
        record["lower_time_s"] = round(lt, 2)
        record["compile_time_s"] = round(ct, 2)
        mem = record["memory"]
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK  "
              f"flops/dev={record['flops_per_device']:.3e}  "
              f"resident/chip={mem['resident_bytes_per_chip']/2**30:.2f}GiB "
              f"fits_v5e={mem['fits_16gb_v5e']}")
        print(f"  memory_analysis: {mem}")
        coll = record["collective_bytes_per_device"]
        print(f"  collectives/dev: total={coll.get('total', 0):.3e}B "
              f"{ {k: round(v/2**20, 1) for k, v in coll.items() if k not in ('total', 'loops_detected')} } MiB")
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
              f"FAILED {record['error']}")
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
        with open(os.path.join(outdir, fname), "w") as f:
            json.dump(record, f, indent=1, default=str)
    return record


def all_cells():
    cells = []
    for name, cfg in sorted(all_configs().items()):
        for s in cfg.shapes():
            cells.append((name, s.name))
    cells.append(("sirius-tpch", "q3_sf100"))
    cells.append(("sirius-tpch", "q3pt_sf100"))   # predicate-transfer variant
    cells.append(("sirius-tpch", "q1_sf100"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--outdir", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}
    todo = all_cells() if args.sweep else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in todo:
        for mp in meshes[args.mesh]:
            rec = run_cell(arch, shape, mp, outdir=args.outdir)
            failures += rec["status"] != "ok"
    print(f"[dryrun] done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
