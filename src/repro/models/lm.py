"""Unified causal-LM builder for the 10-architecture suite.

A config's layers are planned as (mixer, ffn) block kinds:
  mixer ∈ {attn, mla, mamba};  ffn ∈ {mlp, moe, none}
and grouped into scan segments: an optional unrolled prefix
(deepseek's dense layer 0) plus a stacked scan whose step applies one
*period* of the pattern (1 layer for uniform archs, 8 for jamba) — so a
72B/80L model lowers as one scanned layer body.

Serving: `init_cache` builds per-layer decode state (KV for attention, latent
(c_kv,k_rope) for MLA — the MLA cache-compression win — and (conv,ssm) state
for Mamba); `decode_step` advances one token; `prefill` runs the full forward
and materializes the cache.

Whisper (enc-dec) and LLaVA (VLM) wrap this core; their modality frontends
are stubs per the assignment — `input_specs()` feeds precomputed frame/patch
embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import compat
from . import layers as L


def _constrain_sp(x: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel residual-stream constraint (Megatron-SP style).

    Binds (B, S, d) activations to P(batch_axes, 'model', None) when an
    ambient mesh with a 'model' axis is set (the dry-run lowers under
    jax.set_mesh) and S divides the model axis; otherwise identity — smoke
    tests and single-device runs are unaffected.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names or x.ndim != 3:
        return x
    m = mesh.shape["model"]
    if x.shape[1] % m != 0:
        return x
    baxes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    if baxes and x.shape[0] % __import__("math").prod(
            mesh.shape[a] for a in baxes) != 0:
        baxes = ()
    from jax.sharding import PartitionSpec as _P
    spec = _P(baxes if len(baxes) > 1 else (baxes[0] if baxes else None),
              "model", None)
    return jax.lax.with_sharding_constraint(x, spec)


@dataclasses.dataclass(frozen=True)
class BlockKind:
    mixer: str   # attn | mla | mamba
    ffn: str     # mlp | moe | none


def layer_plan(cfg: ArchConfig) -> List[BlockKind]:
    plan = []
    for li in range(cfg.n_layers):
        if cfg.family == "ssm":
            plan.append(BlockKind("mamba", "none"))
            continue
        in_p = li % cfg.period
        if cfg.family == "hybrid":
            mixer = "attn" if in_p in cfg.attn_idx_in_period else "mamba"
        elif cfg.mla is not None:
            mixer = "mla"
        else:
            mixer = "attn"
        if cfg.moe is not None and li >= cfg.first_dense_layers \
                and li % cfg.moe_every == (cfg.moe_every - 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        plan.append(BlockKind(mixer, ffn))
    return plan


def _period_len(cfg: ArchConfig) -> int:
    p = cfg.period
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe_every)
    return p


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(rng, cfg: ArchConfig, kind: BlockKind) -> Dict:
    ks = jax.random.split(rng, 3)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    elif kind.mixer == "mla":
        p["attn"] = L.init_mla(ks[0], cfg)
    else:
        p["mamba"] = L.init_mamba(ks[0], cfg)
    if kind.ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ffn"] = (L.init_moe(ks[1], cfg) if kind.ffn == "moe"
                    else L.init_mlp(ks[1], cfg))
    return p


def init_params(rng, cfg: ArchConfig) -> Dict:
    plan = layer_plan(cfg)
    period = _period_len(cfg)
    n_prefix = cfg.first_dense_layers
    body = plan[n_prefix:]
    assert len(body) % period == 0, (len(body), period)
    n_periods = len(body) // period
    pattern = body[:period]

    k_embed, k_head, k_prefix, k_stack, k_extra = jax.random.split(rng, 5)
    params: Dict[str, Any] = {
        "embed": L.normal(k_embed, (cfg.padded_vocab, cfg.d_model), 0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.normal(k_head, (cfg.d_model, cfg.padded_vocab),
                                  cfg.d_model ** -0.5)
    params["prefix"] = [
        _init_block(k, cfg, plan[i])
        for i, k in enumerate(jax.random.split(k_prefix, max(n_prefix, 1))
                              [:n_prefix])]

    def init_period(k):
        sub = {}
        for j, kind in enumerate(pattern):
            sub[f"sub{j}"] = _init_block(jax.random.fold_in(k, j), cfg, kind)
        return sub

    stack_keys = jax.random.split(k_stack, n_periods)
    params["stack"] = jax.vmap(init_period)(stack_keys)

    if cfg.enc_layers:                      # whisper encoder
        ke = jax.random.split(k_extra, cfg.enc_layers + 1)
        params["enc_pos"] = L.normal(ke[0], (cfg.enc_seq, cfg.d_model), 0.02)

        def init_enc(k):
            return {
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": L.init_attention(k, cfg),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "ffn": L.init_mlp(jax.random.fold_in(k, 1), cfg),
            }

        params["enc"] = jax.vmap(init_enc)(
            jax.random.split(ke[1], cfg.enc_layers))
        params["dec_pos"] = L.normal(
            jax.random.fold_in(k_extra, 7), (32768, cfg.d_model), 0.02)

        def init_cross(k):
            return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                    "attn": L.init_attention(k, cfg)}

        params["cross"] = jax.vmap(init_cross)(
            jax.random.split(jax.random.fold_in(k_extra, 9), cfg.n_layers))
    return params


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------


def _block_train(p: Dict, cfg: ArchConfig, kind: BlockKind,
                 x: jnp.ndarray) -> jnp.ndarray:
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.mixer == "attn":
        x = x + L.attention_train(p["attn"], cfg, h)
    elif kind.mixer == "mla":
        x = x + L.mla_train(p["attn"], cfg, h)
    else:
        x = x + L.mamba_train(p["mamba"], cfg, h)
    if kind.ffn != "none":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + (L.moe(p["ffn"], cfg, h) if kind.ffn == "moe"
                 else L.mlp(p["ffn"], cfg, h))
    return x


def _encoder(params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"].astype(
        jnp.dtype(cfg.dtype))

    def body(x, p):
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + L.attention_train(p["attn"], cfg, h, causal=False)
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp(p["ffn"], cfg, h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return x


def _cross_attend(p, cfg: ArchConfig, x: jnp.ndarray,
                  enc_out: jnp.ndarray) -> jnp.ndarray:
    """Simple full cross-attention (1500 encoder keys)."""
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    ap = p["attn"]
    dt = x.dtype
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (h @ ap["wq"].astype(dt)).reshape(b, s, cfg.n_heads, hd)
    k = (enc_out @ ap["wk"].astype(dt)).reshape(
        b, enc_out.shape[1], cfg.n_kv_heads, hd)
    v = (enc_out @ ap["wv"].astype(dt)).reshape(
        b, enc_out.shape[1], cfg.n_kv_heads, hd)
    o = L.blockwise_attention(q, k, v, causal=False)
    o = o.reshape(b, s, cfg.n_heads * hd)
    return x + o @ ap["wo"].astype(dt)


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray,
            img_embeds: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """→ final hidden states (B, S_total, d)."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.n_img_tiles:                     # VLM: patch embeddings prefix
        assert img_embeds is not None
        x = jnp.concatenate([img_embeds.astype(dt), x], axis=1)
    enc_out = None
    if cfg.enc_layers:
        assert frames is not None
        enc_out = _encoder(params, cfg, frames)
        s = x.shape[1]
        x = x + params["dec_pos"][:s].astype(dt)

    plan = layer_plan(cfg)
    period = _period_len(cfg)
    pattern = plan[cfg.first_dense_layers:][:period]

    for i, bp in enumerate(params["prefix"]):
        x = _block_train(bp, cfg, plan[i], x)

    x = _constrain_sp(x)
    # Cast the stacked layer params to compute dtype BEFORE the scan: the
    # FSDP all-gather inside the scan body then moves bf16, not f32 — halves
    # the dominant collective term of large train cells (EXPERIMENTS §Perf b).
    dt_ = jnp.dtype(cfg.dtype)
    stack_params = jax.tree.map(
        lambda w: w.astype(dt_) if (hasattr(w, "dtype")
                                    and w.dtype == jnp.float32
                                    and w.ndim >= 3) else w,
        params["stack"])
    if cfg.enc_layers:
        # interleave cross-attention after each decoder self-attn block
        @jax.checkpoint
        def body_fn(x, inputs):
            p, cp = inputs
            x = _block_train(p["sub0"], cfg, pattern[0], x)
            x = _cross_attend(cp, cfg, x, enc_out)
            return _constrain_sp(x)

        x, _ = jax.lax.scan(lambda c, i: (body_fn(c, i), None), x,
                            (stack_params, params["cross"]))
    else:
        # remat each scan step: backward recomputes one period's activations
        @jax.checkpoint
        def body_fn(x, p):
            for j, kind in enumerate(pattern):
                x = _block_train(p[f"sub{j}"], cfg, kind, x)
            return _constrain_sp(x)

        x, _ = jax.lax.scan(lambda c, i: (body_fn(c, i), None), x,
                            stack_params)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(params, cfg: ArchConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = hidden.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:   # mask the padding tail exactly
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def loss_fn(params, cfg: ArchConfig, batch: Dict) -> jnp.ndarray:
    """Next-token cross entropy; ignores positions with target < 0."""
    hidden = forward(params, cfg, batch["tokens"],
                     img_embeds=batch.get("img_embeds"),
                     frames=batch.get("frames"))
    if cfg.n_img_tiles:                     # only text positions carry loss
        hidden = hidden[:, -batch["tokens"].shape[1]:]
    logits = logits_fn(params, cfg, hidden)
    targets = batch["targets"]
    mask = targets >= 0
    safe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _block_cache(cfg: ArchConfig, kind: BlockKind, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    if kind.mixer == "attn":
        hd = cfg.resolved_head_dim
        shape = (batch, max_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if kind.mixer == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
                "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt)}
    mm = cfg.mamba
    din = mm.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, mm.d_conv - 1, din), dt),
            "ssm": jnp.zeros((batch, din, mm.d_state), jnp.float32)}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    plan = layer_plan(cfg)
    period = _period_len(cfg)
    pattern = plan[cfg.first_dense_layers:][:period]
    n_periods = (cfg.n_layers - cfg.first_dense_layers) // period
    cache: Dict[str, Any] = {
        "prefix": [
            _block_cache(cfg, plan[i], batch, max_len)
            for i in range(cfg.first_dense_layers)],
        "stack": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(),
            {f"sub{j}": _block_cache(cfg, kind, batch, max_len)
             for j, kind in enumerate(pattern)}),
        "length": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.enc_layers:
        cache["enc_out"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    return cache


def _block_decode(p, cfg: ArchConfig, kind: BlockKind, x, cache, length):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.mixer == "attn":
        o, ck, cv = L.attention_decode(p["attn"], cfg, h, cache["k"],
                                       cache["v"], length)
        cache = {"k": ck, "v": cv}
        x = x + o
    elif kind.mixer == "mla":
        o, ckv, kr = L.mla_decode(p["attn"], cfg, h, cache["ckv"],
                                  cache["krope"], length)
        cache = {"ckv": ckv, "krope": kr}
        x = x + o
    else:
        o, conv, ssm = L.mamba_decode(p["mamba"], cfg, h, cache["conv"],
                                      cache["ssm"])
        cache = {"conv": conv, "ssm": ssm}
        x = x + o
    if kind.ffn != "none":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + (L.moe(p["ffn"], cfg, h) if kind.ffn == "moe"
                 else L.mlp(p["ffn"], cfg, h))
    return x, cache


def decode_step(params, cfg: ArchConfig, cache: Dict,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """tokens (B,1) → (logits (B,1,V), updated cache)."""
    dt = jnp.dtype(cfg.dtype)
    length = cache["length"]
    x = params["embed"].astype(dt)[tokens]
    if cfg.enc_layers:   # whisper decoder: learned positions
        safe = jnp.clip(length, 0, params["dec_pos"].shape[0] - 1)
        x = x + params["dec_pos"].astype(dt)[safe][:, None]
    plan = layer_plan(cfg)
    period = _period_len(cfg)
    pattern = plan[cfg.first_dense_layers:][:period]

    new_prefix = []
    for i, bp in enumerate(params["prefix"]):
        x, c = _block_decode(bp, cfg, plan[i], x, cache["prefix"][i], length)
        new_prefix.append(c)

    if cfg.enc_layers:
        enc_out = cache["enc_out"]

        def body(x, inputs):
            p, cp, c = inputs
            x, c_new = _block_decode(p["sub0"], cfg, pattern[0], x, c["sub0"],
                                     length)
            x = _cross_attend(cp, cfg, x, enc_out)
            return x, {"sub0": c_new}

        x, new_stack = jax.lax.scan(
            body, x, (params["stack"], params["cross"], cache["stack"]))
    else:
        def body(x, inputs):
            p, c = inputs
            c_new = {}
            for j, kind in enumerate(pattern):
                x, cj = _block_decode(p[f"sub{j}"], cfg, kind, x,
                                      c[f"sub{j}"], length)
                c_new[f"sub{j}"] = cj
            return x, c_new

        x, new_stack = jax.lax.scan(body, x, (params["stack"],
                                              cache["stack"]))

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)
    new_cache = dict(cache)
    new_cache["prefix"] = new_prefix
    new_cache["stack"] = new_stack
    new_cache["length"] = length + 1
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray,
            img_embeds=None, frames=None):
    """Run the full forward; return last-position logits.

    (The dry-run's `prefill_32k` lowers this — cache materialization for
    subsequent decode reuses forward activations in a real server; here the
    serving example decodes from a decode_step-built cache instead, which
    keeps the prefill graph purely feed-forward.)
    """
    hidden = forward(params, cfg, tokens, img_embeds=img_embeds,
                     frames=frames)
    return logits_fn(params, cfg, hidden[:, -1:])
