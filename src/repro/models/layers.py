"""Model layer library for the assigned architecture suite.

Pure functions over explicit param pytrees (no flax).  Compute dtype follows
the config (bf16 at scale, f32 in smoke tests); params are stored f32 and
cast at use (mixed precision).  Attention over long sequences is blockwise
(online softmax, jax.checkpoint per q-block) so train_4k / prefill_32k lower
with flash-style memory instead of S² score materialization.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import compat

Params = Dict[str, jnp.ndarray]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """Ambient-mesh sharding constraint; identity when no mesh is set.

    ``axes`` entries: None, 'model', or 'batch' (expands to the mesh's
    ('pod','data') axes).  Used to pin large intermediates (MoE dispatch
    buffers) that GSPMD propagation would otherwise replicate.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return x
    baxes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    batch = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    spec = []
    for i, a in enumerate(axes):
        if a == "batch":
            n = 1
            for ax in (baxes or ()):
                n *= mesh.shape[ax]
            spec.append(batch if n and x.shape[i] % n == 0 else None)
        elif a == "model":
            spec.append("model" if x.shape[i] % mesh.shape["model"] == 0
                        else None)
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(x, _P(*spec))


def normal(rng, shape, scale):
    return (jax.random.normal(rng, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., S, H, D) with pos (..., S) — rotate pairs (first/second half)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs        # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (...,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise causal attention (training / prefill)
# ---------------------------------------------------------------------------


def _attend_block(q, k, v, q_off, k_off, causal, scale, kv_len):
    """q (B,H,bq,Dk) vs k (B,KVH,bk,Dk) / v (B,KVH,bk,Dv), GQA grouped."""
    b, h, bq, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, bq, d)
    s = jnp.einsum("bkgqd,bkjd->bkgqj", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = k_off + jnp.arange(k.shape[2])
    mask = jnp.broadcast_to((kpos < kv_len)[None, :], (bq, k.shape[2]))
    if causal:
        qpos = q_off + jnp.arange(bq)
        mask = mask & (qpos[:, None] >= kpos[None, :])
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqj,bkjd->bkgqd", p, v.astype(jnp.float32))
    return m, l, o


def blockwise_attention(q, k, v, causal=True, block_q=512, block_kv=1024):
    """Flash-style attention: q (B,Sq,H,Dk), k (B,Skv,KVH,Dk),
    v (B,Skv,KVH,Dv) → (B,Sq,H,Dv).  Sq may differ from Skv (cross-attn) and
    Dv from Dk (MLA)."""
    b, sq, h, dk = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = 1.0 / (dk ** 0.5)
    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    q_len = sq
    if sq % bq:                           # pad q (e.g. whisper's 1500 frames)
        qpad = bq - sq % bq
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        sq += qpad
    kv_len = skv
    if skv % bk:                          # pad + mask kv
        pad = bk - skv % bk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv += pad
    nq, nk = sq // bq, skv // bk

    qt = q.transpose(0, 2, 1, 3).reshape(b, h, nq, bq, dk)
    kt = k.transpose(0, 2, 1, 3).reshape(b, kvh, nk, bk, dk)
    vt = v.transpose(0, 2, 1, 3).reshape(b, kvh, nk, bk, dv)
    # Pin head-sharding through the block scans: without this GSPMD
    # re-gathers ~1 GiB activations on EVERY (q-block × kv-block) step —
    # 80×32 times for qwen2-72b train (EXPERIMENTS §Perf b).  kvh < axis
    # size falls back to replicated k/v blocks (small), q stays h-sharded.
    qt = constrain(qt, "batch", "model", None, None, None)
    kt = constrain(kt, "batch", "model", None, None, None)
    vt = constrain(vt, "batch", "model", None, None, None)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_block(qi, q_blk):
        g = h // kvh

        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kt, kj, 2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vt, kj, 2, keepdims=False)
            m, l, o = _attend_block(q_blk, kb, vb, qi * bq, kj * bk,
                                    causal, scale, kv_len)
            m = m.reshape(b, h, q_blk.shape[2])
            l = l.reshape(b, h, q_blk.shape[2])
            o = o.reshape(b, h, q_blk.shape[2], dv)
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)
            beta = jnp.exp(m - m_new)
            l_new = l_run * alpha + l * beta
            acc = acc * alpha[..., None] + o * beta[..., None]
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, dv), jnp.float32)
        # NOTE: all kv blocks are visited; causal masking zeroes the upper
        # triangle (2x the minimal causal FLOPs — a known target recorded in
        # EXPERIMENTS.md §Perf for the hillclimb).
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l_f, 1e-30)[..., None]

    def scan_q(_, qi):
        q_blk = jax.lax.dynamic_index_in_dim(qt, qi, 2, keepdims=False)
        return None, q_block(qi, q_blk)

    _, blocks = jax.lax.scan(scan_q, None, jnp.arange(nq))
    # blocks: (nq, B, H, bq, D) → (B, S, H, D)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)
    return out[:, :q_len].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ArchConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 6)
    p = {
        "wq": normal(ks[0], (d, h * hd), d ** -0.5),
        "wk": normal(ks[1], (d, kvh * hd), d ** -0.5),
        "wv": normal(ks[2], (d, kvh * hd), d ** -0.5),
        "wo": normal(ks[3], (h * hd, d), (h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray, pos: jnp.ndarray):
    dt = x.dtype
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def attention_train(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                    causal: bool = True) -> jnp.ndarray:
    b, s, d = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _qkv(p, cfg, x, pos)
    o = blockwise_attention(q, k, v, causal=causal)
    o = o.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    return o @ p["wo"].astype(x.dtype)


def attention_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     length: jnp.ndarray):
    """x (B,1,d); cache (B,S,KVH,hd); length (B,) current cache fill."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = length[:, None].astype(jnp.int32)                   # (B,1)
    q, k, v = _qkv(p, cfg, x, pos)
    # index literals must match i's dtype exactly (x64 mode promotes bare
    # 0 to int64, which lax.dynamic_update_slice rejects)
    cache_k = jax.vmap(
        lambda c, kk, i: jax.lax.dynamic_update_slice(c, kk, (i, i * 0, i * 0))
    )(cache_k, k, length.astype(jnp.int32))
    cache_v = jax.vmap(
        lambda c, vv, i: jax.lax.dynamic_update_slice(c, vv, (i, i * 0, i * 0))
    )(cache_v, v, length.astype(jnp.int32))
    # masked decode attention over the cache (kernel-accelerated on TPU)
    from ..kernels.ref import decode_attention_ref
    o = decode_attention_ref(q[:, 0], cache_k, cache_v, length + 1)
    o = o.reshape(b, 1, cfg.n_heads * hd)
    return o @ p["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) attention
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ArchConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 5)
    return {
        "wq": normal(ks[0], (d, h * qd), d ** -0.5),
        "wdkv": normal(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim), d ** -0.5),
        "wuk": normal(ks[2], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                      m.kv_lora_rank ** -0.5),
        "wuv": normal(ks[3], (m.kv_lora_rank, h * m.v_head_dim),
                      m.kv_lora_rank ** -0.5),
        "wo": normal(ks[4], (h * m.v_head_dim, d), (h * m.v_head_dim) ** -0.5),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
    }


def _mla_qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray, pos: jnp.ndarray,
             c_kv: jnp.ndarray, k_rope: jnp.ndarray):
    """Expand latent cache into per-head K/V; build rope-augmented Q."""
    m = cfg.mla
    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    q = (x @ p["wq"].astype(dt)).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    sl = c_kv.shape[1]
    k_nope = (c_kv @ p["wuk"].astype(dt)).reshape(b, sl, h, m.qk_nope_head_dim)
    v = (c_kv @ p["wuv"].astype(dt)).reshape(b, sl, h, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, sl, h, m.qk_rope_head_dim))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    return qq, k, v


def mla_train(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    m = cfg.mla
    dt = x.dtype
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    dkv = x @ p["wdkv"].astype(dt)
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None], pos, cfg.rope_theta)[:, :, 0]
    q, k, v = _mla_qkv(p, cfg, x, pos, c_kv, k_rope)
    o = blockwise_attention(q, k, v, causal=True)
    o = o.reshape(b, s, cfg.n_heads * m.v_head_dim)
    return o @ p["wo"].astype(dt)


def mla_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
               cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
               length: jnp.ndarray):
    """MLA decode: the cache stores only (kv_lora + rope_dim) per token."""
    m = cfg.mla
    dt = x.dtype
    b = x.shape[0]
    pos = length[:, None].astype(jnp.int32)
    dkv = x @ p["wdkv"].astype(dt)
    c_kv_t, k_rope_t = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv_t = rmsnorm(c_kv_t, p["kv_norm"], cfg.norm_eps)
    k_rope_t = apply_rope(k_rope_t[:, :, None], pos, cfg.rope_theta)[:, :, 0]
    cache_ckv = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice(c, t, (i, i * 0))
    )(cache_ckv, c_kv_t, length.astype(jnp.int32))
    cache_krope = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice(c, t, (i, i * 0))
    )(cache_krope, k_rope_t, length.astype(jnp.int32))
    q, k, v = _mla_qkv(p, cfg, x, pos, cache_ckv, cache_krope)
    # masked single-token attention
    sl = k.shape[1]
    qf = q[:, 0].astype(jnp.float32)                          # (B,H,qd)
    kf = k.astype(jnp.float32)
    s_ = jnp.einsum("bhd,bshd->bhs", qf, kf) / (q.shape[-1] ** 0.5)
    mask = jnp.arange(sl)[None, None] < (length + 1)[:, None, None]
    s_ = jnp.where(mask, s_, -1e30)
    pr = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", pr, v.astype(jnp.float32)).astype(dt)
    o = o.reshape(b, 1, cfg.n_heads * m.v_head_dim)
    return o @ p["wo"].astype(dt), cache_ckv, cache_krope


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp_kind == "gelu":
        return {"w1": normal(ks[0], (d, ff), d ** -0.5),
                "w2": normal(ks[1], (ff, d), ff ** -0.5)}
    return {"wg": normal(ks[0], (d, ff), d ** -0.5),
            "wu": normal(ks[1], (d, ff), d ** -0.5),
            "wd": normal(ks[2], (ff, d), ff ** -0.5)}


def mlp(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    if cfg.mlp_kind == "gelu":
        return jax.nn.gelu(x @ p["w1"].astype(dt)) @ p["w2"].astype(dt)
    g = jax.nn.silu(x @ p["wg"].astype(dt))
    return (g * (x @ p["wu"].astype(dt))) @ p["wd"].astype(dt)


def init_moe(rng, cfg: ArchConfig) -> Params:
    m = cfg.moe
    d, ff, e = cfg.d_model, m.expert_d_ff, m.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": normal(ks[0], (d, e), d ** -0.5),
        "wg": normal(ks[1], (e, d, ff), d ** -0.5),
        "wu": normal(ks[2], (e, d, ff), d ** -0.5),
        "wd": normal(ks[3], (e, ff, d), ff ** -0.5),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.n_shared * ff)
    return p


def _moe_groups(t: int) -> int:
    """Dispatch groups = data shards of the ambient mesh (1 when unset).

    Grouped dispatch keeps every routing tensor local to its token group, so
    GSPMD shards the (G, E, C, d) buffers on G — the production-MoE layout;
    a flat global sort would force replicated scatters."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n if n > 0 and t % n == 0 else 1


def moe(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Grouped sort-based token dispatch (capacity-bounded per group).

    Per data-shard group: route top-k, sort token-expert pairs by expert id
    (the TPU compaction idiom), pack into (E, C_local, d) buffers.  Expert
    FFNs run as one batched einsum over (G, E, C, d) — G sharded over the
    data axes, E over 'model' (expert parallelism).  FLOPs = active experts
    only (E·C ≈ T·k·capacity_factor) — roofline-faithful.
    """
    m = cfg.moe
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    ng = _moe_groups(t)
    tl = t // ng
    cap = max((int(tl * k * m.capacity_factor / e) + 7) // 8 * 8, 8)

    xg = constrain(x.reshape(ng, tl, d), "batch", None, None)

    def route(xf):                             # (tl, d) — one group
        logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(gates, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        flat_w = top_w.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = jnp.take(flat_e, order)
        tok_sorted = jnp.take(flat_tok, order)
        w_sorted = jnp.take(flat_w, order)
        starts = jnp.searchsorted(e_sorted, jnp.arange(e))
        pos = jnp.arange(tl * k) - jnp.take(starts, e_sorted)
        keep = pos < cap
        slot = jnp.where(keep, e_sorted * cap + pos, e * cap)
        xbuf = jnp.zeros((e * cap + 1, d), dt).at[slot].set(
            jnp.take(xf, tok_sorted, axis=0), mode="drop")[:-1]
        return xbuf, slot, tok_sorted, (w_sorted * keep)

    xbufs, slots, toks, ws = jax.vmap(route)(xg)       # (G, E*C, d), ...
    xbufs = constrain(xbufs.reshape(ng, e, cap, d),
                      "batch", "model", None, None)
    gg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xbufs,
                                p["wg"].astype(dt)))
    uu = jnp.einsum("gecd,edf->gecf", xbufs, p["wu"].astype(dt))
    yb = jnp.einsum("gecf,efd->gecd", gg * uu, p["wd"].astype(dt))
    yb = constrain(yb, "batch", "model", None, None).reshape(ng, e * cap, d)

    def combine(ybuf, slot, tok, w):                   # per group
        contrib = jnp.take(ybuf, jnp.clip(slot, 0, e * cap - 1), axis=0)
        contrib = contrib * w.astype(dt)[:, None]
        return jnp.zeros((tl, d), dt).at[tok].add(contrib)

    y = jax.vmap(combine)(yb, slots, toks, ws).reshape(t, d)
    if m.n_shared:
        y = y + mlp(p["shared"], cfg, x.reshape(t, d))
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM)
# ---------------------------------------------------------------------------


def _dt_rank(cfg: ArchConfig) -> int:
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def init_mamba(rng, cfg: ArchConfig) -> Params:
    mm = cfg.mamba
    d = cfg.d_model
    din = mm.expand * d
    r = _dt_rank(cfg)
    ks = jax.random.split(rng, 7)
    return {
        "win": normal(ks[0], (d, 2 * din), d ** -0.5),
        "conv": normal(ks[1], (mm.d_conv, din), 0.2),
        "wx": normal(ks[2], (din, r + 2 * mm.d_state), din ** -0.5),
        "wdt": normal(ks[3], (r, din), r ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (din,)) * 0.1, 1e-3, None))),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mm.d_state + 1, dtype=jnp.float32), (din, mm.d_state))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "wout": normal(ks[6], (din, d), din ** -0.5),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,din), w (K,din)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
    return out


def mamba_train(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    mm = cfg.mamba
    dt_ = x.dtype
    b, s, d = x.shape
    din = mm.expand * d
    r = _dt_rank(cfg)
    xz = x @ p["win"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = jax.nn.silu(_causal_conv(xin, p["conv"]))
    proj = xin @ p["wx"].astype(dt_)
    dt_r, bmat, cmat = jnp.split(proj, [r, r + mm.d_state], axis=-1)
    delta = jax.nn.softplus(dt_r @ p["wdt"].astype(dt_)
                            + p["dt_bias"].astype(dt_))      # (B,S,din)
    a = -jnp.exp(p["a_log"])                                  # (din,N)

    da = jnp.exp(delta.astype(jnp.float32)[..., None] * a)    # (B,S,din,N)
    dbx = (delta * xin).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[..., None, :]              # (B,S,din,N)
    # Pin scan tensors to (batch, -, model, -): the recurrence is elementwise
    # in (din, N), so a consistent din-sharding makes every scan step
    # collective-free (otherwise GSPMD reshards ~17MB per step × S × layers —
    # the falcon-mamba hillclimb in EXPERIMENTS.md §Perf).
    da = constrain(da, "batch", None, "model", None)
    dbx = constrain(dbx, "batch", None, "model", None)

    def step(h, inputs):
        da_t, dbx_t = inputs
        h = da_t * h + dbx_t
        return h, h

    h0 = constrain(jnp.zeros((b, din, mm.d_state), jnp.float32),
                   "batch", "model", None)
    _, hs = jax.lax.scan(step, h0,
                         (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3)                              # (B,S,din,N)
    hs = constrain(hs, "batch", None, "model", None)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
    y = y.astype(dt_) + xin * p["d_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["wout"].astype(dt_)


def mamba_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                 conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token step: conv_state (B,K-1,din), ssm_state (B,din,N)."""
    mm = cfg.mamba
    dt_ = x.dtype
    b = x.shape[0]
    d = cfg.d_model
    r = _dt_rank(cfg)
    xz = x[:, 0] @ p["win"].astype(dt_)                        # (B,2din)
    xin, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_state, xin[:, None]], axis=1)  # (B,K,din)
    conv_w = p["conv"].astype(dt_)
    xin = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, conv_w))
    new_conv_state = window[:, 1:]
    proj = xin @ p["wx"].astype(dt_)
    dt_r, bmat, cmat = jnp.split(proj, [r, r + mm.d_state], axis=-1)
    delta = jax.nn.softplus(dt_r @ p["wdt"].astype(dt_) + p["dt_bias"].astype(dt_))
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(delta.astype(jnp.float32)[..., None] * a)     # (B,din,N)
    dbx = (delta * xin).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[:, None, :]
    h = da * ssm_state + dbx
    y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32)).astype(dt_)
    y = y + xin * p["d_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    return (y @ p["wout"].astype(dt_))[:, None], new_conv_state, h
