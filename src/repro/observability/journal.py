"""Fleet-level query journal: structured spans keyed by query ID.

The PR-5 observability layer (tracer/metrics/profile) attributes time
*inside one engine in one process*.  This module is the fleet-level
complement (DESIGN.md §15): a process-wide, **always-on**, append-only
event journal whose unit of correlation is a **query ID** minted at every
front door (``engine.sql``, ``engine.accelerate``,
``DistributedEngine.run_plan``) and threaded — via an explicit
``TraceContext`` — across threads, speculative replicas, and the shard
mesh, so that every fragment attempt, per-shard engine run, collective
exchange, retry, elastic rebuild, checkpoint, and warm plan-cache replay
lands in **one tree per query** no matter which thread emitted it.

Design constraints, in order:

1. **Cheap enough to leave on.**  Emitting a span is two
   ``perf_counter`` calls, a dict, and one lock-guarded deque append.
   The journal never touches device values — every attribute is a host
   int/float/str — so the one-sync-per-query and zero-in-pipeline
   transfer contracts hold with the journal enabled (guarded by
   ``tests/test_journal.py``).
2. **Concurrency-safe.**  The ring buffer takes one lock per event;
   span nesting state is thread-local; query IDs are process-unique.
   Concurrent queries interleave in the ring but each event carries its
   ``query_id``, so per-query views are exact.
3. **Bounded.**  A ring buffer (``REPRO_JOURNAL_CAPACITY``, default
   65536 events) with an optional JSONL sink (``attach_sink`` /
   ``REPRO_JOURNAL_SINK``) for durable export.  Ring overflow drops the
   oldest events and counts them (``dropped``).

Spans emitted outside any query context are dropped — the journal is a
*query* journal; ambient noise belongs to ``tracer``/``metrics``.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional

JOURNAL_SCHEMA_VERSION = 1

_ATTR_TYPES = (str, int, float, bool, type(None), list, tuple, dict)


@dataclass(frozen=True)
class TraceContext:
    """The wire-able slice of journal state: enough for another thread (a
    shard worker, a speculative replica, a future remote node) to attach
    its spans under the originating query's tree."""

    query_id: str
    span_id: Optional[int] = None

    def to_dict(self) -> Dict[str, Any]:
        return {"query_id": self.query_id, "span_id": self.span_id}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "TraceContext":
        return TraceContext(query_id=d["query_id"],
                            span_id=d.get("span_id"))


class _NoopSpan:
    """Shared do-nothing span for the disabled / no-context paths."""

    __slots__ = ()
    query_id = None
    span_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self


_NOOP = _NoopSpan()


class JournalSpan:
    """A live span: context manager that commits one event on exit."""

    __slots__ = ("_journal", "name", "category", "query_id", "span_id",
                 "parent_id", "attrs", "start", "_tid")

    def __init__(self, journal: "QueryJournal", name: str, category: str,
                 query_id: str, span_id: int, parent_id: Optional[int],
                 attrs: Dict[str, Any]):
        self._journal = journal
        self.name = name
        self.category = category
        self.query_id = query_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.start = 0.0
        self._tid = 0

    def __enter__(self) -> "JournalSpan":
        self._journal._push(self)
        self._tid = threading.get_ident()
        self.start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        end = time.perf_counter()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._journal._pop(self)
        self._journal._commit({
            "kind": "span", "name": self.name, "cat": self.category,
            "query_id": self.query_id, "span_id": self.span_id,
            "parent_id": self.parent_id, "ts": self.start,
            "dur": end - self.start, "tid": self._tid,
            "attrs": self.attrs,
        })
        return False

    def set(self, **attrs) -> "JournalSpan":
        """Attach host-side attributes (never device values) to the span."""
        self.attrs.update(attrs)
        return self


class QueryJournal:
    """Thread-safe ring buffer of query-scoped span/instant events."""

    def __init__(self, capacity: Optional[int] = None,
                 enabled: Optional[bool] = None):
        if capacity is None:
            capacity = int(os.environ.get("REPRO_JOURNAL_CAPACITY", 65536))
        if enabled is None:
            enabled = os.environ.get("REPRO_JOURNAL_DISABLE", "0") != "1"
        self.capacity = capacity
        self.enabled = enabled
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._qseq = itertools.count(1)
        self._local = threading.local()
        # perf_counter origin so event timestamps are small positive floats
        # comparable across threads; wall anchor for JSONL consumers.
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()
        self._sink = None
        self._sink_lock = threading.Lock()
        sink = os.environ.get("REPRO_JOURNAL_SINK")
        if sink:
            self.attach_sink(sink)

    # -- enable / sink -----------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def attach_sink(self, path: str) -> None:
        """Mirror every committed event to ``path`` as one JSON line
        (schema_version stamped per line so files are self-describing)."""
        with self._sink_lock:
            if self._sink is not None:
                self._sink.close()
            self._sink = open(path, "a", encoding="utf-8")

    def detach_sink(self) -> None:
        with self._sink_lock:
            if self._sink is not None:
                self._sink.close()
                self._sink = None

    # -- context plumbing --------------------------------------------------

    def _stack(self) -> List:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, span: JournalSpan) -> None:
        self._stack().append(span)

    def _pop(self, span: JournalSpan) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        elif span in st:          # tolerate out-of-order exits
            st.remove(span)

    def current_context(self) -> Optional[TraceContext]:
        """The ambient (query_id, span_id) on this thread, or None."""
        st = getattr(self._local, "stack", None)
        if not st:
            return None
        top = st[-1]
        return TraceContext(query_id=top.query_id, span_id=top.span_id)

    @contextmanager
    def activate(self, ctx: Optional[TraceContext]):
        """Adopt a remote/parent ``TraceContext`` on this thread: spans
        opened inside attach under ``ctx.span_id`` in ``ctx.query_id``'s
        tree.  This is the propagation primitive the distributed runner
        uses to carry the coordinator's context onto fragment worker
        threads and speculative replicas."""
        if ctx is None or not self.enabled:
            yield
            return
        anchor = JournalSpan(self, "<ctx>", "ctx", ctx.query_id,
                             ctx.span_id if ctx.span_id is not None else 0,
                             None, {})
        # The anchor is bookkeeping only: it parents children but is never
        # committed as an event (the real span lives on the origin thread).
        self._push(anchor)
        try:
            yield
        finally:
            self._pop(anchor)

    # -- emission ----------------------------------------------------------

    def new_query_id(self, prefix: str = "q") -> str:
        return f"{prefix}{os.getpid()}-{next(self._qseq)}"

    def query_span(self, name: str, query_id: Optional[str] = None,
                   **attrs):
        """Front-door span.  If a journal context is already active on
        this thread (nested engine call, shard run under an activated
        fragment context) this is an ordinary child span; otherwise it
        roots a fresh query tree with a newly minted query ID."""
        if not self.enabled:
            return _NOOP
        cur = self.current_context()
        if cur is not None:
            return JournalSpan(self, name, attrs.pop("category", "engine"),
                               cur.query_id, next(self._ids), cur.span_id,
                               self._clean(attrs))
        qid = query_id or self.new_query_id()
        return JournalSpan(self, name, "query", qid, next(self._ids), None,
                           self._clean(attrs))

    def span(self, name: str, category: str = "other", **attrs):
        """Child span under the ambient context; dropped when no query is
        active on this thread (the journal records queries, not noise)."""
        if not self.enabled:
            return _NOOP
        cur = self.current_context()
        if cur is None:
            return _NOOP
        return JournalSpan(self, name, category, cur.query_id,
                           next(self._ids), cur.span_id, self._clean(attrs))

    def event(self, name: str, category: str = "other", **attrs) -> None:
        """Zero-duration instant event under the ambient context."""
        if not self.enabled:
            return
        cur = self.current_context()
        if cur is None:
            return
        self._commit({
            "kind": "instant", "name": name, "cat": category,
            "query_id": cur.query_id, "span_id": next(self._ids),
            "parent_id": cur.span_id, "ts": time.perf_counter(),
            "dur": 0.0, "tid": threading.get_ident(),
            "attrs": self._clean(attrs),
        })

    @staticmethod
    def _clean(attrs: Dict[str, Any]) -> Dict[str, Any]:
        # Journal attributes must be host-plain (JSON-able, no device
        # arrays): coerce numpy scalars via item(), drop anything exotic.
        out = {}
        for k, v in attrs.items():
            if isinstance(v, _ATTR_TYPES):
                out[k] = v
            elif hasattr(v, "item") and not hasattr(v, "__len__"):
                try:
                    out[k] = v.item()
                except Exception:
                    out[k] = repr(v)
            else:
                out[k] = repr(v)
        return out

    def _commit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)
        sink = self._sink
        if sink is not None:
            line = json.dumps(
                {"schema_version": JOURNAL_SCHEMA_VERSION, **ev},
                default=str)
            with self._sink_lock:
                if self._sink is not None:
                    self._sink.write(line + "\n")
                    self._sink.flush()

    # -- reading -----------------------------------------------------------

    def events(self, query_id: Optional[str] = None) -> List[Dict[str, Any]]:
        """Point-in-time snapshot, optionally filtered to one query."""
        with self._lock:
            evs = list(self._events)
        if query_id is not None:
            evs = [e for e in evs if e["query_id"] == query_id]
        return evs

    def query_ids(self) -> List[str]:
        """Distinct query IDs currently in the ring, oldest first."""
        seen: Dict[str, None] = {}
        for e in self.events():
            seen.setdefault(e["query_id"], None)
        return list(seen)

    def summary(self, query_id: Optional[str] = None) -> Dict[str, Any]:
        """Event counts by category — the cheap health view benchmarks
        embed next to their timings."""
        evs = self.events(query_id)
        by_cat: Dict[str, int] = {}
        for e in evs:
            by_cat[e["cat"]] = by_cat.get(e["cat"], 0) + 1
        return {"events": len(evs), "dropped": self.dropped,
                "by_category": dict(sorted(by_cat.items()))}

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto / chrome://tracing loadable)
# ---------------------------------------------------------------------------


def _chrome_pid(ev: Dict[str, Any],
                by_id: Dict[int, Dict[str, Any]]) -> int:
    """Process lane: coordinator/engine events in pid 0, shard-s work in
    pid s+1 — mirrors the physical layout of a shard mesh.  Events with
    no shard attribute of their own inherit the nearest ancestor's (a
    shard engine's inner spans belong on that shard's track)."""
    hops = 0
    while ev is not None and hops < 64:
        shard = ev.get("attrs", {}).get("shard")
        if isinstance(shard, int):
            return shard + 1
        ev = by_id.get(ev.get("parent_id"))
        hops += 1
    return 0


def to_chrome(events: Iterable[Dict[str, Any]],
              epoch: float = 0.0) -> Dict[str, Any]:
    """Render journal events as a Chrome trace-event JSON dict.

    Spans become complete events (``ph: "X"``, µs timestamps), instants
    become ``ph: "i"``; process/thread lanes get metadata names so
    Perfetto shows "coordinator" / "shard N" tracks."""
    events = list(events)
    by_id = {e["span_id"]: e for e in events}
    trace: List[Dict[str, Any]] = []
    lanes: Dict[int, None] = {}
    tids: Dict[int, int] = {}
    for ev in events:
        pid = _chrome_pid(ev, by_id)
        lanes.setdefault(pid, None)
        tid = tids.setdefault(ev.get("tid", 0), len(tids) + 1)
        args = {"query_id": ev["query_id"], **ev.get("attrs", {})}
        base = {"name": ev["name"], "cat": ev["cat"],
                "ts": (ev["ts"] - epoch) * 1e6, "pid": pid, "tid": tid,
                "args": args}
        if ev["kind"] == "span":
            trace.append({**base, "ph": "X",
                          "dur": max(ev["dur"], 1e-7) * 1e6})
        else:
            trace.append({**base, "ph": "i", "s": "t"})
    for pid in sorted(lanes):
        trace.append({"ph": "M", "name": "process_name", "pid": pid,
                      "tid": 0, "args": {
                          "name": "coordinator" if pid == 0
                          else f"shard {pid - 1}"}})
    return {"traceEvents": trace, "displayTimeUnit": "ms",
            "otherData": {"schema_version": JOURNAL_SCHEMA_VERSION}}


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Read a JSONL sink file back into event dicts."""
    out = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# The process-wide journal every front door writes into.
JOURNAL = QueryJournal()
