"""Process-wide metrics registry: counters, gauges, histograms.

This is the single sink for the instrumentation that previously lived as
scattered ad-hoc dicts and attributes: the pipeline compiler's
signature-cache hits/misses and trace wall time, the kernel backend's
kernel-vs-fallback hit counts, ``instrument``'s host-transfer and
sync-barrier counts, the buffer manager's cold/boundary byte ledgers, the
hybrid router's fragment placements, and the distributed runner's phase
timers.  Those subsystems keep their cheap per-object counters (tests
assert on them per-engine) and *publish* into this registry, which is what
``QueryProfile`` snapshots per query and what a future serving layer will
scrape.

All three instrument types are thread-safe (one lock per instrument; the
registry itself locks only on instrument creation) and support ``float``
increments, so wall-clock seconds can accumulate in counters.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonically increasing value (int or float)."""

    def __init__(self, name: str, mirror: Optional["Counter"] = None):
        self.name = name
        self.mirror = mirror
        self._lock = threading.Lock()
        self._value: Number = 0

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._value += n
        if self.mirror is not None:
            self.mirror.inc(n)

    @property
    def value(self) -> Number:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins value."""

    def __init__(self, name: str, mirror: Optional["Gauge"] = None):
        self.name = name
        self.mirror = mirror
        self._lock = threading.Lock()
        self._value: Number = 0

    def set(self, v: Number) -> None:
        with self._lock:
            self._value = v
        if self.mirror is not None:
            self.mirror.set(v)

    @property
    def value(self) -> Number:
        with self._lock:
            return self._value


class Histogram:
    """Streaming summary (count/sum/min/max) — enough for latency telemetry
    without bucket-boundary bikeshedding; percentiles belong to the future
    serving layer's scraper."""

    def __init__(self, name: str, mirror: Optional["Histogram"] = None):
        self.name = name
        self.mirror = mirror
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: Number) -> None:
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
        if self.mirror is not None:
            self.mirror.observe(v)

    def summary(self) -> Dict[str, Number]:
        with self._lock:
            if not self.count:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0}
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max}


class MetricsRegistry:
    """Create-or-get instruments by dotted name (``compiler.cache_hits``).

    A registry may be **scoped**: constructed with a ``parent`` registry
    and a ``label`` prefix, every instrument mirrors its updates into the
    parent under ``<label>.<name>``.  The distributed runner gives each
    pooled shard engine its own registry labeled ``distributed.shard<i>``
    so shard metrics stop colliding in one flat namespace, while the
    process-global view survives as labeled series in ``METRICS`` that
    ``aggregate_labeled`` can roll back up."""

    def __init__(self, parent: Optional["MetricsRegistry"] = None,
                 label: Optional[str] = None):
        if (parent is None) != (label is None):
            raise ValueError("parent and label must be given together")
        self.parent = parent
        self.label = label
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _mirror_name(self, name: str) -> str:
        return f"{self.label}.{name}"

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                mirror = (self.parent.counter(self._mirror_name(name))
                          if self.parent is not None else None)
                c = self._counters[name] = Counter(name, mirror=mirror)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                mirror = (self.parent.gauge(self._mirror_name(name))
                          if self.parent is not None else None)
                g = self._gauges[name] = Gauge(name, mirror=mirror)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                mirror = (self.parent.histogram(self._mirror_name(name))
                          if self.parent is not None else None)
                h = self._histograms[name] = Histogram(name, mirror=mirror)
            return h

    def snapshot(self) -> Dict[str, Number]:
        """Flat point-in-time view: counters/gauges by name, histograms
        expanded to ``name.count`` / ``name.sum`` / ``name.min`` / ``name.max``."""
        with self._lock:
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._histograms.items())
        out: Dict[str, Number] = {}
        for name, c in counters:
            out[name] = c.value
        for name, g in gauges:
            out[name] = g.value
        for name, h in hists:
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out

    @staticmethod
    def delta(before: Dict[str, Number],
              after: Dict[str, Number]) -> Dict[str, Number]:
        """Per-interval view of two snapshots (new keys count from zero).
        Gauges come through as differences too — snapshot pairs are a
        counter-oriented tool; read gauges from ``snapshot`` directly."""
        return {k: v - before.get(k, 0) for k, v in after.items()}

    def reset_for_tests(self) -> None:
        """Drop every instrument (tests only — production metrics are
        append-only by design)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


def aggregate_labeled(snapshot: Dict[str, Number], family: str,
                      sep: str = ".") -> Dict[str, Number]:
    """Roll labeled series back up into one process-global view.

    Given a snapshot containing mirrored keys like
    ``distributed.shard0.compute_seconds`` / ``...shard1...``, an
    aggregation over family ``"distributed.shard"`` sums every
    ``<family><i>.<metric>`` into ``<metric>`` (histogram ``.min`` /
    ``.max`` take min/max instead of summing)."""
    import re

    pat = re.compile(rf"^{re.escape(family)}(\d+){re.escape(sep)}(.+)$")
    out: Dict[str, Number] = {}
    for key, v in snapshot.items():
        m = pat.match(key)
        if m is None:
            continue
        metric = m.group(2)
        if metric.endswith(".min"):
            out[metric] = min(out.get(metric, float("inf")), v)
        elif metric.endswith(".max"):
            out[metric] = max(out.get(metric, float("-inf")), v)
        else:
            out[metric] = out.get(metric, 0) + v
    return out


# The process-wide registry every subsystem publishes into.
METRICS = MetricsRegistry()
