"""Distributed-trace analysis: merge shard spans into one query tree.

The journal collects raw events from every thread — coordinator loop,
fragment workers, speculative replicas, per-shard engines.  This module
turns one query's events into the artifacts the tooling serves:

* ``span_tree``        — parent-linked tree (children time-ordered);
* ``render_timeline``  — indented text timeline with wall times;
* ``top_operators``    — aggregate wall time by span name;
* ``exchange_report``  — per-exchange bytes-per-shard and skew table;
* ``verify_tree``      — structural/temporal integrity checks used by
  ``scripts/trace_report.py`` to cross-check the journal against
  ``QueryProfile`` totals.

Skew metric (DESIGN.md §15): for an exchange whose per-shard byte
contributions are ``b``, ``skew_ratio = max(b) / mean(b)`` — 1.0 is a
perfectly balanced exchange, ``n_shards`` is one shard carrying
everything.  For shuffles the *received* (post-partition) distribution is
what stalls the mesh, so that is what the runner records.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple


def skew_ratio(bytes_per_shard: Iterable[float]) -> float:
    """max/mean of a per-shard byte distribution; 1.0 when empty/uniform."""
    vals = [float(b) for b in bytes_per_shard]
    if not vals:
        return 1.0
    mean = sum(vals) / len(vals)
    if mean <= 0:
        return 1.0
    return max(vals) / mean


class SpanNode:
    __slots__ = ("event", "children")

    def __init__(self, event: Dict[str, Any]):
        self.event = event
        self.children: List["SpanNode"] = []

    @property
    def name(self) -> str:
        return self.event["name"]

    @property
    def dur(self) -> float:
        return self.event["dur"]


def span_tree(events: List[Dict[str, Any]],
              query_id: Optional[str] = None) -> List[SpanNode]:
    """Merge one query's events into parent-linked root nodes.

    Spans commit on *exit*, so parents land in the ring after their
    children; linking is by ``parent_id``, not arrival order.  Events
    whose parent never committed (e.g. still-open spans at snapshot time,
    or ring-evicted parents) surface as extra roots rather than being
    dropped."""
    if query_id is not None:
        events = [e for e in events if e["query_id"] == query_id]
    nodes = {e["span_id"]: SpanNode(e) for e in events}
    roots: List[SpanNode] = []
    for e in events:
        node = nodes[e["span_id"]]
        parent = nodes.get(e.get("parent_id"))
        if parent is not None and parent is not node:
            parent.children.append(node)
        else:
            roots.append(node)
    for n in nodes.values():
        n.children.sort(key=lambda c: c.event["ts"])
    roots.sort(key=lambda c: c.event["ts"])
    return roots


def render_timeline(events: List[Dict[str, Any]],
                    query_id: Optional[str] = None,
                    epoch: float = 0.0) -> str:
    """Indented text timeline of one query's span tree."""
    lines: List[str] = []

    def walk(node: SpanNode, depth: int) -> None:
        e = node.event
        t0 = (e["ts"] - epoch) * 1e3
        attrs = e.get("attrs", {})
        extra = " ".join(
            f"{k}={attrs[k]}" for k in ("fragment", "shard", "attempt",
                                        "kind", "replica", "skew_ratio")
            if k in attrs)
        marker = "·" if e["kind"] == "instant" else "▸"
        lines.append(f"{'  ' * depth}{marker} {e['name']:<34} "
                     f"+{t0:9.3f}ms {e['dur'] * 1e3:9.3f}ms"
                     f"{('  ' + extra) if extra else ''}")
        for c in node.children:
            walk(c, depth + 1)

    for root in span_tree(events, query_id):
        walk(root, 0)
    return "\n".join(lines)


def top_operators(events: List[Dict[str, Any]],
                  query_id: Optional[str] = None,
                  n: int = 15) -> List[Dict[str, Any]]:
    """Aggregate span wall time by name (spans only, instants skipped)."""
    if query_id is not None:
        events = [e for e in events if e["query_id"] == query_id]
    agg: Dict[str, Dict[str, Any]] = {}
    for e in events:
        if e["kind"] != "span":
            continue
        row = agg.setdefault(e["name"], {"name": e["name"], "cat": e["cat"],
                                         "count": 0, "total_s": 0.0,
                                         "max_s": 0.0})
        row["count"] += 1
        row["total_s"] += e["dur"]
        row["max_s"] = max(row["max_s"], e["dur"])
    return sorted(agg.values(), key=lambda r: -r["total_s"])[:n]


def render_top_operators(rows: List[Dict[str, Any]]) -> str:
    lines = [f"{'span':<36} {'cat':<10} {'count':>5} {'total_ms':>10} "
             f"{'max_ms':>10}"]
    for r in rows:
        lines.append(f"{r['name']:<36} {r['cat']:<10} {r['count']:>5} "
                     f"{r['total_s'] * 1e3:>10.3f} {r['max_s'] * 1e3:>10.3f}")
    return "\n".join(lines)


def exchange_report(events: List[Dict[str, Any]],
                    query_id: Optional[str] = None) -> List[Dict[str, Any]]:
    """One row per collective exchange span: kind, key, per-shard bytes,
    skew ratio — the 'exchange volume and skew' view the terabyte-scale
    paper argues is the distributed story."""
    if query_id is not None:
        events = [e for e in events if e["query_id"] == query_id]
    rows = []
    for e in events:
        if e["kind"] != "span" or e["cat"] != "exchange":
            continue
        a = e.get("attrs", {})
        rows.append({
            "fragment": a.get("fragment", "?"),
            "kind": a.get("kind", "?"),
            "key": a.get("key"),
            "bytes_per_shard": a.get("bytes_per_shard", []),
            "skew_ratio": a.get("skew_ratio", 1.0),
            "wall_s": e["dur"],
        })
    rows.sort(key=lambda r: -sum(r["bytes_per_shard"] or [0]))
    return rows


def render_exchange_report(rows: List[Dict[str, Any]]) -> str:
    if not rows:
        return "(no exchanges)"
    lines = [f"{'fragment':<22} {'kind':<10} {'key':<16} {'bytes':>12} "
             f"{'skew':>6} {'wall_ms':>9}  per-shard bytes"]
    for r in rows:
        bps = r["bytes_per_shard"] or []
        lines.append(
            f"{r['fragment']:<22} {r['kind']:<10} "
            f"{str(r['key'] or '-'):<16} {int(sum(bps)):>12} "
            f"{r['skew_ratio']:>6.2f} {r['wall_s'] * 1e3:>9.3f}  "
            f"{[int(b) for b in bps]}")
    return "\n".join(lines)


def verify_tree(events: List[Dict[str, Any]], query_id: str,
                slack_s: float = 0.005) -> List[str]:
    """Structural + temporal integrity checks over one query's tree.

    Returns a list of violations (empty == healthy):
    * every event carries the query ID;
    * span IDs are unique;
    * linked children fall inside their parent's wall-clock window
      (within ``slack_s`` — span commit order means the parent's window
      is measured on a different thread for propagated contexts);
    * each root's direct children don't sum to more than the root's
      wall (plus slack) unless they overlap (parallel shard spans on one
      parent are expected and exempt).
    """
    evs = [e for e in events if e["query_id"] == query_id]
    errors: List[str] = []
    if not evs:
        return [f"no events for query {query_id}"]
    seen_ids = set()
    for e in evs:
        if e["span_id"] in seen_ids:
            errors.append(f"duplicate span_id {e['span_id']}")
        seen_ids.add(e["span_id"])
    by_id = {e["span_id"]: e for e in evs}
    for e in evs:
        pid = e.get("parent_id")
        if pid is None:
            continue
        p = by_id.get(pid)
        if p is None:
            continue  # parent evicted or uncommitted — tree handles it
        if p["query_id"] != e["query_id"]:
            errors.append(
                f"span {e['span_id']} parent crosses query boundary")
        if p["kind"] != "span":
            continue
        if e["cat"] == "attempt":
            # replica spans race each other past the fragment span's exit
            # by design (a losing primary or a speculative backup keeps
            # running after the winner commits) — the fragment→attempt
            # edge is structural only; edges *inside* each attempt are
            # still window-checked against the attempt span itself
            continue
        if e["ts"] < p["ts"] - slack_s or \
                e["ts"] + e["dur"] > p["ts"] + p["dur"] + slack_s:
            errors.append(
                f"span {e['name']}#{e['span_id']} "
                f"[{e['ts']:.6f},{e['ts'] + e['dur']:.6f}] outside parent "
                f"{p['name']}#{pid} [{p['ts']:.6f},{p['ts'] + p['dur']:.6f}]")
    return errors


def query_wall(events: List[Dict[str, Any]],
               query_id: str) -> Tuple[float, Optional[Dict[str, Any]]]:
    """(root span wall seconds, root event) for one query — the number
    ``trace_report`` cross-checks against QueryProfile.total_seconds."""
    roots = [e for e in events
             if e["query_id"] == query_id and e.get("parent_id") is None
             and e["kind"] == "span"]
    if not roots:
        return 0.0, None
    root = max(roots, key=lambda e: e["dur"])
    return root["dur"], root
