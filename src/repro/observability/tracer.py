"""Lightweight span tracer: nested wall-clock attribution, near-free when off.

A ``Span`` is a named timed interval with arbitrary attributes and an
optional parent — the minimal vocabulary needed to reconstruct "where did
this query's time go" as a tree.  Spans are entered as context managers;
nesting within one thread is tracked through a thread-local stack, and a
parent can be passed explicitly when a child span starts on a different
thread (the executor's worker threads do exactly that).

Cost model: when the tracer is disabled, ``span()`` returns a shared no-op
context manager — no allocation, no clock read, no lock — so instrumented
code paths can stay instrumented in production.  When enabled, finished
spans are appended to a lock-protected list; ``finished()`` returns them
oldest-first.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional


class Span:
    """One timed interval.  ``seconds`` is valid once the span has exited."""

    __slots__ = ("name", "category", "attrs", "parent", "start", "end",
                 "_tracer")

    def __init__(self, tracer: "SpanTracer", name: str, category: str,
                 parent: Optional["Span"], attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.category = category
        self.parent = parent
        self.attrs = attrs
        self.start = 0.0
        self.end = 0.0

    @property
    def seconds(self) -> float:
        return max(self.end - self.start, 0.0)

    def set(self, **attrs: Any) -> None:
        """Attach attributes mid-span (rows out, cache hit...)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.end = time.perf_counter()
        self._tracer._pop(self)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "category": self.category,
                "seconds": self.seconds,
                "parent": self.parent.name if self.parent else None,
                "attrs": dict(self.attrs)}


class _NoopSpan:
    """Shared disabled-path span: every operation is a no-op."""

    __slots__ = ()

    seconds = 0.0

    def set(self, **attrs: Any) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _NoopSpan()


class SpanTracer:
    """Thread-safe span collector; disabled by default."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._finished: List[Span] = []
        self._local = threading.local()

    # -- recording -----------------------------------------------------------
    def span(self, name: str, category: str = "other",
             parent: Optional[Span] = None, **attrs: Any):
        """Open a span as a context manager.

        When the tracer is disabled this returns a shared no-op object —
        the call costs one attribute read and one comparison.
        """
        if not self.enabled:
            return _NOOP
        if parent is None:
            parent = self.current()
        return Span(self, name, category, parent, attrs)

    def current(self) -> Optional[Span]:
        """The innermost open span on *this* thread (None outside spans)."""
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    def _push(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(span)

    def _pop(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()
        with self._lock:
            self._finished.append(span)

    # -- inspection ----------------------------------------------------------
    def finished(self) -> List[Span]:
        with self._lock:
            return list(self._finished)

    def reset(self) -> None:
        with self._lock:
            self._finished.clear()

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False


# Process-wide default tracer (disabled until a profiling entry point —
# analyze=True / EXPLAIN ANALYZE — turns it on for the duration of a query).
TRACER = SpanTracer()
