"""Query-telemetry subsystem: spans, metrics, per-operator profiles.

The paper's cost-efficiency claims (8.3x TPC-H) are *per-operator*
arguments; this package makes every regression and every win attributable
to a named operator, compile step, cache or transfer — the DuckDB
``EXPLAIN ANALYZE`` / ``PRAGMA enable_profiling='json'`` loop rebuilt for
the device-resident engine.

Four pieces (DESIGN.md §12, §15):

* ``journal`` + ``dist`` — the always-on, query-ID-keyed **event
  journal** (thread-safe ring buffer + JSONL sink) with trace-context
  propagation across threads and the shard mesh, Chrome trace-event
  export, and span-tree merge/skew analysis for distributed queries;

* ``tracer``  — nested context-manager **spans** (thread-safe, near-zero
  cost when disabled) for ad-hoc wall-clock attribution;
* ``metrics`` — a process-wide **registry** of counters/gauges/histograms
  that absorbs the scattered ad-hoc instrumentation (compiler cache
  hits/misses, kernel-vs-fallback hits, host-transfer counts, buffer
  byte ledgers, hybrid-fragment placement, distributed timers);
* ``profile`` — the **QueryProfile** record assembled per query under
  ``engine.sql(q, analyze=True)`` / ``EXPLAIN ANALYZE``: per-operator and
  per-fused-region wall time, rows in/out, compile-vs-execute split,
  cache/kernel/transfer stats, versioned JSON export and profile diffing.
"""
from .journal import (
    JOURNAL, JOURNAL_SCHEMA_VERSION, JournalSpan, QueryJournal, TraceContext,
    to_chrome,
)
from .metrics import METRICS, MetricsRegistry, aggregate_labeled
from .profile import (
    PROFILE_SCHEMA_VERSION, OperatorProfile, PipelineProfile, ProfileBuilder,
    QueryProfile, diff_profiles, validate_profile,
)
from .tracer import TRACER, Span, SpanTracer

__all__ = [
    "JOURNAL", "JOURNAL_SCHEMA_VERSION", "JournalSpan", "METRICS",
    "MetricsRegistry", "OperatorProfile", "PROFILE_SCHEMA_VERSION",
    "PipelineProfile", "ProfileBuilder", "QueryJournal", "QueryProfile",
    "Span", "SpanTracer", "TRACER", "TraceContext", "aggregate_labeled",
    "diff_profiles", "to_chrome", "validate_profile",
]
