"""QueryProfile: the per-query EXPLAIN ANALYZE record.

Assembled by the executor when a query runs with ``analyze=True`` (or in
the legacy pre-fusion ``profile=True`` mode): per-pipeline operator
entries with wall time and rows in/out, the compile-vs-execute split,
per-query deltas of the engine's cache/kernel/transfer counters, the plan
text, and (for hybrid ``accelerate`` runs) fragment placements.

The JSON export is **versioned and schema-stable**: ``to_json`` always
emits exactly the keys ``validate_profile`` checks, so profiles written by
benchmarks (BENCH_*.json), CI smoke artifacts and ad-hoc EXPLAIN ANALYZE
runs stay diffable across sessions — ``diff_profiles`` /
``scripts/profile_diff.py`` is the tool that names the operator that moved.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

PROFILE_SCHEMA_VERSION = 1

# every operator entry carries one of these categories (bench_breakdown and
# the schema validator key on the set)
OPERATOR_CATEGORIES = ("scan", "filter", "project", "join", "groupby",
                       "orderby", "fused", "other")

_TOP_KEYS = ("schema_version", "query", "engine", "total_seconds",
             "compile_seconds", "execute_seconds", "pipelines",
             "operator_totals", "metrics", "plan", "fragments")
_OP_KEYS = ("name", "category", "rows_in", "rows_out", "seconds", "attrs")
_PIPELINE_KEYS = ("pid", "source", "deps", "operators")


@dataclasses.dataclass
class OperatorProfile:
    """One executed operator (or fused region) inside a pipeline."""
    name: str
    category: str
    rows_in: int
    rows_out: int
    seconds: float
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "category": self.category,
                "rows_in": int(self.rows_in), "rows_out": int(self.rows_out),
                "seconds": float(self.seconds), "attrs": dict(self.attrs)}


@dataclasses.dataclass
class PipelineProfile:
    """One executed pipeline: source description, dependencies, operators."""
    pid: int
    source: str
    deps: List[int]
    operators: List[OperatorProfile] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {"pid": self.pid, "source": self.source,
                "deps": list(self.deps),
                "operators": [o.to_dict() for o in self.operators]}


@dataclasses.dataclass
class QueryProfile:
    query: Optional[str]
    engine: Dict[str, Any]
    total_seconds: float
    compile_seconds: float
    execute_seconds: float
    pipelines: List[PipelineProfile]
    operator_totals: Dict[str, float]
    metrics: Dict[str, float]
    plan: str
    fragments: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    # -- export --------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": PROFILE_SCHEMA_VERSION,
            "query": self.query,
            "engine": dict(self.engine),
            "total_seconds": float(self.total_seconds),
            "compile_seconds": float(self.compile_seconds),
            "execute_seconds": float(self.execute_seconds),
            "pipelines": [p.to_dict() for p in self.pipelines],
            "operator_totals": {k: float(v)
                                for k, v in sorted(self.operator_totals.items())},
            "metrics": {k: v for k, v in sorted(self.metrics.items())},
            "plan": self.plan,
            "fragments": list(self.fragments),
        }

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "QueryProfile":
        errors = validate_profile(d)
        if errors:
            raise ValueError("invalid profile: " + "; ".join(errors))
        return cls(
            query=d["query"], engine=d["engine"],
            total_seconds=d["total_seconds"],
            compile_seconds=d["compile_seconds"],
            execute_seconds=d["execute_seconds"],
            pipelines=[PipelineProfile(
                pid=p["pid"], source=p["source"], deps=list(p["deps"]),
                operators=[OperatorProfile(**o) for o in p["operators"]])
                for p in d["pipelines"]],
            operator_totals=dict(d["operator_totals"]),
            metrics=dict(d["metrics"]), plan=d["plan"],
            fragments=list(d["fragments"]))

    @classmethod
    def from_json(cls, s: str) -> "QueryProfile":
        return cls.from_dict(json.loads(s))

    # -- pretty printer ------------------------------------------------------
    def pretty(self) -> str:
        """Annotated EXPLAIN ANALYZE rendering: the optimized plan tree,
        then each executed pipeline with per-operator wall time, rows and
        region annotations (cache hit, probe mode, estimated FLOPs/bytes)."""
        ms = 1e3
        lines = [f"EXPLAIN ANALYZE  "
                 f"(total {self.total_seconds * ms:.2f} ms = "
                 f"compile {self.compile_seconds * ms:.2f} ms + "
                 f"execute {self.execute_seconds * ms:.2f} ms)"]
        if self.query:
            lines.append(f"query: {' '.join(self.query.split())[:120]}")
        if self.plan:
            lines.append("plan:")
            lines.extend("  " + ln for ln in self.plan.splitlines())
        for p in self.pipelines:
            dep = f" deps={p.deps}" if p.deps else ""
            lines.append(f"pipeline {p.pid} <- {p.source}{dep}")
            for op in p.operators:
                note = ""
                if op.attrs:
                    parts = []
                    for k in ("cache_hit", "mode", "est_flops", "est_bytes"):
                        if k in op.attrs:
                            v = op.attrs[k]
                            parts.append(f"{k}={v:.3g}" if isinstance(v, float)
                                         else f"{k}={v}")
                    if parts:
                        note = "  [" + " ".join(parts) + "]"
                lines.append(
                    f"  {op.name:<42} {op.seconds * ms:9.3f} ms  "
                    f"rows {op.rows_in:>9} -> {op.rows_out:>9}{note}")
        if self.fragments:
            lines.append("fragments:")
            for f in self.fragments:
                lines.append(f"  frag {f.get('fid')} [{f.get('placement')}] "
                             f"rels={f.get('rels')} "
                             f"{f.get('seconds', 0.0) * ms:.2f} ms")
        if self.operator_totals:
            tot = ", ".join(f"{k}={v * ms:.2f}ms"
                            for k, v in sorted(self.operator_totals.items(),
                                               key=lambda kv: -kv[1]))
            lines.append(f"operator totals: {tot}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# builder (filled in by the executor during an analyzed run)
# ---------------------------------------------------------------------------


class ProfileBuilder:
    """Mutable per-query collector; thread-safe (worker threads append)."""

    def __init__(self, query: Optional[str] = None,
                 engine: Optional[Dict[str, Any]] = None):
        self.query = query
        self.engine = dict(engine or {})
        self.plan_text = ""
        self.fragments: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._pipelines: List[PipelineProfile] = []

    def start_pipeline(self, source: str, deps: List[int]) -> PipelineProfile:
        with self._lock:
            rec = PipelineProfile(len(self._pipelines), source, list(deps))
            self._pipelines.append(rec)
            return rec

    def add_operator(self, rec: PipelineProfile, name: str, category: str,
                     rows_in: int, rows_out: int, seconds: float,
                     **attrs: Any) -> OperatorProfile:
        op = OperatorProfile(name, category, int(rows_in), int(rows_out),
                             float(seconds), dict(attrs))
        with self._lock:
            rec.operators.append(op)
        return op

    def finalize(self, total_seconds: float, compile_seconds: float,
                 metrics: Dict[str, float]) -> QueryProfile:
        totals: Dict[str, float] = {}
        with self._lock:
            pipelines = list(self._pipelines)
        for p in pipelines:
            for op in p.operators:
                totals[op.category] = totals.get(op.category, 0.0) + op.seconds
        compile_seconds = min(max(compile_seconds, 0.0), total_seconds)
        return QueryProfile(
            query=self.query, engine=self.engine,
            total_seconds=float(total_seconds),
            compile_seconds=float(compile_seconds),
            execute_seconds=float(max(total_seconds - compile_seconds, 0.0)),
            pipelines=pipelines, operator_totals=totals, metrics=dict(metrics),
            plan=self.plan_text, fragments=list(self.fragments))


# ---------------------------------------------------------------------------
# schema validation (CI smoke + golden tests key on this)
# ---------------------------------------------------------------------------


def validate_profile(d: Any) -> List[str]:
    """Structural schema check → list of error strings (empty = valid).

    Checks key sets, types, category vocabulary, non-negative rows, and
    the timing invariants the acceptance contract names: every duration
    ≥ 0, compile + execute ≤ total, and per-operator times summing to
    ≤ total query wall time (pipelines are serialized under analyze, so
    operator wall clocks cannot overlap)."""
    errors: List[str] = []
    if not isinstance(d, dict):
        return [f"profile must be a dict, got {type(d).__name__}"]
    missing = [k for k in _TOP_KEYS if k not in d]
    extra = [k for k in d if k not in _TOP_KEYS]
    if missing:
        errors.append(f"missing top-level keys: {missing}")
    if extra:
        errors.append(f"unknown top-level keys: {extra}")
    if d.get("schema_version") != PROFILE_SCHEMA_VERSION:
        errors.append(f"schema_version {d.get('schema_version')!r} != "
                      f"{PROFILE_SCHEMA_VERSION}")
    if missing:
        return errors

    for key in ("total_seconds", "compile_seconds", "execute_seconds"):
        v = d[key]
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"{key} must be a non-negative number, got {v!r}")
    if not errors:
        if d["compile_seconds"] + d["execute_seconds"] > \
                d["total_seconds"] * 1.001 + 1e-9:
            errors.append("compile_seconds + execute_seconds exceeds "
                          "total_seconds")

    if not isinstance(d["engine"], dict):
        errors.append("engine must be a dict")
    if d["query"] is not None and not isinstance(d["query"], str):
        errors.append("query must be a string or null")
    if not isinstance(d["plan"], str):
        errors.append("plan must be a string")
    if not isinstance(d["fragments"], list):
        errors.append("fragments must be a list")
    if not isinstance(d["metrics"], dict):
        errors.append("metrics must be a dict")
    else:
        for k, v in d["metrics"].items():
            if not isinstance(v, (int, float)):
                errors.append(f"metric {k!r} must be numeric, got {v!r}")
    if not isinstance(d["operator_totals"], dict):
        errors.append("operator_totals must be a dict")
    else:
        for k, v in d["operator_totals"].items():
            if k not in OPERATOR_CATEGORIES:
                errors.append(f"unknown operator category {k!r}")
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"operator_totals[{k!r}] must be >= 0")

    op_sum = 0.0
    if not isinstance(d["pipelines"], list):
        errors.append("pipelines must be a list")
        return errors
    for p in d["pipelines"]:
        if not isinstance(p, dict) or sorted(p) != sorted(_PIPELINE_KEYS):
            errors.append(f"pipeline keys must be {_PIPELINE_KEYS}, "
                          f"got {sorted(p) if isinstance(p, dict) else p!r}")
            continue
        if not isinstance(p["pid"], int) or not isinstance(p["source"], str):
            errors.append(f"pipeline {p.get('pid')!r}: bad pid/source types")
        for op in p["operators"]:
            if not isinstance(op, dict) or sorted(op) != sorted(_OP_KEYS):
                errors.append(f"operator keys must be {_OP_KEYS}, got "
                              f"{sorted(op) if isinstance(op, dict) else op!r}")
                continue
            if op["category"] not in OPERATOR_CATEGORIES:
                errors.append(f"operator {op['name']!r}: unknown category "
                              f"{op['category']!r}")
            for key in ("rows_in", "rows_out"):
                if not isinstance(op[key], int) or op[key] < 0:
                    errors.append(f"operator {op['name']!r}: {key} must be a "
                                  f"non-negative int")
            if not isinstance(op["seconds"], (int, float)) or op["seconds"] < 0:
                errors.append(f"operator {op['name']!r}: seconds must be >= 0")
            else:
                op_sum += op["seconds"]
            if not isinstance(op["attrs"], dict):
                errors.append(f"operator {op['name']!r}: attrs must be a dict")
    if not errors and isinstance(d["total_seconds"], (int, float)):
        if op_sum > d["total_seconds"] * 1.001 + 1e-9:
            errors.append(f"per-operator seconds sum to {op_sum:.6f} > "
                          f"total_seconds {d['total_seconds']:.6f}")
    return errors


# ---------------------------------------------------------------------------
# profile diffing (scripts/profile_diff.py is the CLI wrapper)
# ---------------------------------------------------------------------------


def _operator_table(profile: Dict[str, Any]) -> Dict[str, float]:
    """Flatten a profile to {qualified operator name: seconds} plus the
    category totals and the query total — the comparable units of a diff."""
    out: Dict[str, float] = {"total": float(profile["total_seconds"]),
                             "compile": float(profile["compile_seconds"])}
    for cat, s in profile.get("operator_totals", {}).items():
        out[f"category:{cat}"] = float(s)
    for p in profile.get("pipelines", []):
        for i, op in enumerate(p.get("operators", [])):
            out[f"p{p['pid']}/{i}:{op['name']}"] = float(op["seconds"])
    return out


def diff_profiles(old: Dict[str, Any], new: Dict[str, Any],
                  threshold: float = 1.5,
                  min_delta_s: float = 0.002) -> Tuple[List[str], List[str]]:
    """Compare two profile dicts → (regressions, report_lines).

    An entry regresses when it slowed by more than ``threshold``× AND by
    more than ``min_delta_s`` wall seconds (both gates, so noise on
    microsecond operators never pages anyone).  The report names every
    operator/phase that moved in either direction.
    """
    a, b = _operator_table(old), _operator_table(new)
    regressions: List[str] = []
    report: List[str] = []
    for key in sorted(set(a) | set(b)):
        sa, sb = a.get(key, 0.0), b.get(key, 0.0)
        delta = sb - sa
        if abs(delta) < min_delta_s:
            continue
        ratio = (sb / sa) if sa > 0 else float("inf")
        line = (f"{key}: {sa * 1e3:.2f} ms -> {sb * 1e3:.2f} ms "
                f"({'+' if delta >= 0 else ''}{delta * 1e3:.2f} ms, "
                f"{ratio:.2f}x)")
        if delta > 0 and ratio > threshold:
            regressions.append(f"REGRESSION {line}")
            report.append(f"REGRESSION {line}")
        else:
            report.append(("improved   " if delta < 0 else "moved      ")
                          + line)
    return regressions, report
