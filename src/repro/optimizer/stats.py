"""Cardinality / selectivity heuristics for the rule-based optimizer.

No histograms or NDV sketches — the same class of closed-form guesses
classical System-R-style optimizers fall back to when stats are missing.
They only need to be good enough to (a) pick hash-join build sides and
(b) order joins so selective dimension tables apply early, which is what the
paper's host-optimizer (DuckDB) contributes to Sirius plans.

String predicates get one real statistic for free: the dictionary.  When
the catalog carries column dictionaries (``Catalog.with_dictionaries`` —
the engine attaches them from its loaded tables), LIKE / IN / prefix /
equality selectivities are the predicate's measured *hit rate over the
dictionary* instead of the Selinger constants.  Codes are assumed uniform
(no per-code frequencies), and the constants remain the fallback whenever
no dictionary is available.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SetRel, SortRel, WindowRel,
)
from ..relational import strings
from ..relational.expressions import (
    Between, BinOp, Col, Expr, InList, Like, Lit, StartsWith, UnOp, walk_expr,
)

# default selectivity guesses (classic Selinger-style constants)
SEL_EQ = 0.05
SEL_RANGE = 0.3
SEL_BETWEEN = 0.25
SEL_LIKE = 0.1
SEL_IN_PER_VALUE = 0.05
SEL_DEFAULT = 0.5


def _dictionary_of(e: Expr, catalog) -> Optional[object]:
    """Dictionary of a bare-column operand, when the catalog knows it."""
    if catalog is None or not isinstance(e, Col):
        return None
    getter = getattr(catalog, "dictionary_for", None)
    return getter(e.name) if getter is not None else None


def selectivity(e: Expr, catalog=None) -> float:
    """Heuristic fraction of rows satisfying predicate ``e``.

    With a dictionary-carrying ``catalog``, string predicates return their
    dictionary hit rate; otherwise the classic constants apply.
    """
    if isinstance(e, BinOp):
        if e.op == "and":
            return selectivity(e.left, catalog) * selectivity(e.right, catalog)
        if e.op == "or":
            s1 = selectivity(e.left, catalog)
            s2 = selectivity(e.right, catalog)
            return min(1.0, s1 + s2 - s1 * s2)
        if e.op in ("==", "!="):
            sel = SEL_EQ
            if isinstance(e.right, Lit) and isinstance(e.right.value, str):
                d = _dictionary_of(e.left, catalog)
                if d is not None and len(d):
                    sel = strings.eq_selectivity(d, e.right.value)
            return sel if e.op == "==" else 1.0 - sel
        if e.op in ("<", "<=", ">", ">="):
            return SEL_RANGE
        return SEL_DEFAULT
    if isinstance(e, UnOp) and e.op == "not":
        return max(0.0, 1.0 - selectivity(e.operand, catalog))
    if isinstance(e, Between):
        return SEL_BETWEEN
    if isinstance(e, InList):
        values = list(e.values)
        d = _dictionary_of(e.operand, catalog)
        if d is not None and len(d) and all(isinstance(v, str) for v in values):
            s = strings.in_selectivity(d, values)
        else:
            s = min(1.0, SEL_IN_PER_VALUE * max(len(values), 1))
        return 1.0 - s if e.negate else s
    if isinstance(e, Like):
        d = _dictionary_of(e.operand, catalog)
        s = strings.like_selectivity(d, e.pattern) \
            if d is not None and len(d) else SEL_LIKE
        return 1.0 - s if e.negate else s
    if isinstance(e, StartsWith):
        d = _dictionary_of(e.operand, catalog)
        s = strings.prefix_selectivity(d, e.prefix) \
            if d is not None and len(d) else SEL_LIKE
        return 1.0 - s if e.negate else s
    if isinstance(e, Lit):
        if isinstance(e.value, bool):
            return 1.0 if e.value else 0.0
        return SEL_DEFAULT
    return SEL_DEFAULT


def contains_subquery(e: Expr) -> bool:
    return any(isinstance(n, ScalarSubquery) for n in walk_expr(e))


def rel_columns(rel: Rel, catalog) -> List[str]:
    """Output column names of a plan node (needs the catalog for bare
    ReadRels)."""
    if isinstance(rel, ReadRel):
        if rel.columns:
            return list(rel.columns)
        if catalog is not None and catalog.has_table(rel.table):
            return catalog.columns(rel.table)
        return []                     # unknown table: treat as opaque
    if isinstance(rel, (FilterRel, SortRel, FetchRel, ExchangeRel)):
        return rel_columns(rel.input, catalog)
    if isinstance(rel, ProjectRel):
        names = [n for n, _ in rel.exprs]
        if rel.keep_input:
            base = [c for c in rel_columns(rel.input, catalog)
                    if c not in names]
            return base + names
        return names
    if isinstance(rel, JoinRel):
        probe = rel_columns(rel.probe, catalog)
        if rel.how in ("semi", "anti"):
            return probe
        if rel.how == "mark":
            return probe + [rel.mark_name]
        build = [c for c in rel_columns(rel.build, catalog) if c not in probe]
        out = probe + build
        if rel.how == "left":
            out = out + ["__matched"]
        return out
    if isinstance(rel, AggregateRel):
        return list(rel.group_keys) + [a.name for a in rel.aggs]
    if isinstance(rel, WindowRel):
        return rel_columns(rel.input, catalog) + [rel.name]
    if isinstance(rel, SetRel):
        return rel_columns(rel.operands[0], catalog) if rel.operands else []
    raise TypeError(type(rel))


def estimate(rel: Rel, catalog) -> float:
    """Estimated output rows (also memoized onto ``rel.estimated_rows``)."""
    if isinstance(rel, ReadRel):
        base = catalog.row_estimate(rel.table) if catalog is not None else 1e3
        out = base * (selectivity(rel.filter, catalog)
                      if rel.filter is not None else 1.0)
    elif isinstance(rel, FilterRel):
        out = estimate(rel.input, catalog) * selectivity(rel.condition,
                                                       catalog)
    elif isinstance(rel, (ProjectRel, ExchangeRel)):
        out = estimate(rel.input, catalog)
    elif isinstance(rel, SortRel):
        out = estimate(rel.input, catalog)
        if rel.limit is not None:
            out = min(out, float(rel.limit))
    elif isinstance(rel, FetchRel):
        out = min(estimate(rel.input, catalog), float(rel.count))
    elif isinstance(rel, JoinRel):
        p = estimate(rel.probe, catalog)
        b = estimate(rel.build, catalog)
        if rel.how in ("semi",):
            out = p * 0.5
        elif rel.how == "anti":
            out = p * 0.5
        elif rel.how == "mark":
            out = p
        else:
            # FK-join heuristic: output ≈ the larger (fact) side, scaled by
            # how selective the smaller side already is relative to its base
            out = max(p, b)
            if rel.how == "left":
                out = max(out, p)
        if rel.post_filter is not None:
            out *= selectivity(rel.post_filter, catalog)
    elif isinstance(rel, AggregateRel):
        child = estimate(rel.input, catalog)
        out = 1.0 if not rel.group_keys else max(1.0, child * 0.1)
        if rel.having is not None:
            out *= selectivity(rel.having, catalog)
    elif isinstance(rel, WindowRel):
        out = estimate(rel.input, catalog)
    elif isinstance(rel, SetRel):
        out = sum(estimate(p, catalog) for p in rel.operands)
    else:
        out = 1e3
    rel.estimated_rows = float(out)
    return rel.estimated_rows


def annotate(rel: Rel, catalog) -> Rel:
    """Set ``estimated_rows`` on every node (including scalar-subquery
    sub-plans) so ``explain`` shows the optimizer's cardinality view."""
    estimate(rel, catalog)
    for node in _walk_all(rel):
        estimate(node, catalog)
    return rel


def _walk_all(rel: Rel):
    yield rel
    for child in rel.inputs():
        yield from _walk_all(child)
    for e in _rel_exprs(rel):
        for n in walk_expr(e):
            if isinstance(n, ScalarSubquery):
                yield from _walk_all(n.plan)


def _rel_exprs(rel: Rel) -> List[Expr]:
    import dataclasses

    out: List[Expr] = []
    for f in dataclasses.fields(rel):
        v = getattr(rel, f.name)
        if isinstance(v, Expr):
            out.append(v)
        elif isinstance(v, list):
            for item in v:
                if isinstance(item, Expr):
                    out.append(item)
                elif isinstance(item, tuple):
                    out.extend(x for x in item if isinstance(x, Expr))
                elif hasattr(item, "expr") and isinstance(
                        getattr(item, "expr", None), Expr):
                    out.append(item.expr)
    return out
