"""Rule-based plan optimizer (the DuckDB-side rewrites of the paper).

The SQL frontend lowers to a deliberately naive plan; these passes rewrite
it into the shape the hand-built TPC-H plans are already in — filters at the
scans, narrow reads, selective joins first, smaller hash-build sides —
before the engine ever sees it.  ``optimize`` is pure: the input plan is
never mutated, so naive/optimized comparisons (benchmarks/bench_optimizer)
stay valid.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from ..core.plan import Rel
from .rules import (
    choose_build_sides, fold_constants, order_conjuncts, prune_projections,
    pushdown_predicates, reorder_joins,
)
from .stats import annotate, estimate, rel_columns, selectivity

__all__ = [
    "DEFAULT_RULES", "annotate", "estimate", "optimize", "rel_columns",
    "selectivity",
]

# (name, pass) in application order
DEFAULT_RULES: List[Tuple[str, Callable[[Rel, object], Rel]]] = [
    ("fold_constants", fold_constants),
    ("pushdown_predicates", pushdown_predicates),
    ("prune_projections", prune_projections),
    ("reorder_joins", reorder_joins),
    ("choose_build_sides", choose_build_sides),
    ("order_conjuncts", order_conjuncts),
]


def optimize(plan: Rel, catalog=None, rules=None) -> Rel:
    """Apply the rule pipeline; annotate the result with row estimates."""
    if catalog is None:
        from ..sql.binder import DEFAULT_CATALOG
        catalog = DEFAULT_CATALOG
    for _name, rule in (DEFAULT_RULES if rules is None else rules):
        plan = rule(plan, catalog)
    return annotate(plan, catalog)
