"""Rule-based plan optimizer (the DuckDB-side rewrites of the paper).

The SQL frontend lowers to a deliberately naive plan; these passes rewrite
it into the shape the hand-built TPC-H plans are already in — filters at
the scans, narrow reads, selective joins first, smaller hash-build sides —
before the engine ever sees it.

``DEFAULT_RULES``, in application order:

  1. ``fold_constants``      — literal arithmetic/boolean folding.
  2. ``pushdown_predicates`` — FilterRel conjuncts sink through projections
     (rewriting through pure renames) and joins into ``ReadRel.filter``;
     conjuncts spanning both join sides become the join's ``post_filter``.
  3. ``prune_projections``   — required-column analysis top-down, landing
     in ``ReadRel.columns``.
  4. ``reorder_joins``       — greedy smallest-estimated-build-first
     ordering of left-deep inner/semi/anti chains under key-availability
     constraints.
  5. ``choose_build_sides``  — the smaller estimated side of an inner join
     becomes the hash-build side (the pipeline breaker, paper §3.2.2).
  6. ``order_conjuncts``     — most-selective-first AND ordering.

Cardinality model (``stats``): Selinger-style constants and FK-join
heuristics, upgraded with **dictionary-informed string selectivity** when
the catalog carries column dictionaries (``Catalog.with_dictionaries`` —
``SiriusEngine.sql`` attaches them automatically): LIKE / IN / prefix /
equality predicates are costed by their measured hit rate over the
dictionary, with the constants (``SEL_LIKE`` = 0.1, …) as fallback.

``optimize`` is pure — the input plan is never mutated — so naive/optimized
comparisons (``benchmarks/bench_optimizer.py``) stay valid.  Pass a custom
``rules`` list (same ``(name, fn)`` shape) to ablate individual passes.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from ..core.plan import Rel
from .rules import (
    choose_build_sides, fold_constants, order_conjuncts, prune_projections,
    pushdown_predicates, reorder_joins,
)
from .stats import annotate, estimate, rel_columns, selectivity

__all__ = [
    "DEFAULT_RULES", "annotate", "estimate", "optimize", "rel_columns",
    "selectivity",
]

# (name, pass) in application order; every pass is Rel × catalog → Rel
DEFAULT_RULES: List[Tuple[str, Callable[[Rel, object], Rel]]] = [
    ("fold_constants", fold_constants),
    ("pushdown_predicates", pushdown_predicates),
    ("prune_projections", prune_projections),
    ("reorder_joins", reorder_joins),
    ("choose_build_sides", choose_build_sides),
    ("order_conjuncts", order_conjuncts),
]


def optimize(plan: Rel, catalog=None, rules=None) -> Rel:
    """Apply the rule pipeline; annotate the result with row estimates.

    Args:
        plan: root of the (naive) plan IR — never mutated.
        catalog: schemas / row estimates / optional dictionaries driving
            the cost heuristics (default: the TPC-H catalog).
        rules: override ``DEFAULT_RULES`` — a list of ``(name, fn)`` pairs
            applied in order; use to ablate or extend passes.

    Returns:
        A rewritten plan with ``estimated_rows`` stamped on every node
        (shown by ``explain``).
    """
    if catalog is None:
        from ..sql.binder import DEFAULT_CATALOG
        catalog = DEFAULT_CATALOG
    for _name, rule in (DEFAULT_RULES if rules is None else rules):
        plan = rule(plan, catalog)
    return annotate(plan, catalog)
