"""Rule-based plan rewrites.

The passes reproduce the standard rewrites DuckDB performs before emitting a
Substrait plan to Sirius (the paper's host-optimizer contribution):

  * ``fold_constants``        — literal arithmetic/boolean folding
  * ``pushdown_predicates``   — FilterRel conjuncts sink through projections
    and joins into ``ReadRel.filter`` (scan-level predicate pushdown);
    conjuncts spanning both join sides become the join's ``post_filter``
  * ``prune_projections``     — required-column analysis top-down, landing in
    ``ReadRel.columns`` (scan-level projection pushdown)
  * ``reorder_joins``         — greedy smallest-intermediate-first ordering
    of left-deep inner/semi/anti chains, under key-availability constraints
  * ``choose_build_sides``    — the smaller estimated side of an inner join
    becomes the hash-build side (the pipeline breaker, paper §3.2.2)
  * ``order_conjuncts``       — most-selective-first AND ordering

Every pass is a pure function Rel → Rel (nodes are rebuilt, never mutated),
so the naive plan stays valid for rules-off comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SortRel,
)
from ..relational.expressions import (
    BinOp, Col, Expr, Lit, UnOp, and_all as _and_all,
    split_conjuncts as _conjuncts, transform_expr,
)
from .stats import contains_subquery, estimate, rel_columns, selectivity


def _replace_children(rel: Rel, **kw) -> Rel:
    return dataclasses.replace(rel, **kw)


def _map_children(rel: Rel, fn) -> Rel:
    """Rebuild ``rel`` with ``fn`` applied to every child Rel (and to plans
    inside ScalarSubquery expressions)."""
    changes = {}
    for f in dataclasses.fields(rel):
        v = getattr(rel, f.name)
        if isinstance(v, Rel):
            nv = fn(v)
            if nv is not v:
                changes[f.name] = nv
        elif isinstance(v, Expr):
            nv = _map_subplans(v, fn)
            if nv is not v:
                changes[f.name] = nv
        elif isinstance(v, list) and v:
            new_items, dirty = [], False
            for item in v:
                if isinstance(item, tuple) and len(item) == 2 \
                        and isinstance(item[1], Expr):
                    ne = _map_subplans(item[1], fn)
                    dirty |= ne is not item[1]
                    new_items.append((item[0], ne))
                elif hasattr(item, "expr") and isinstance(
                        getattr(item, "expr", None), Expr):
                    ne = _map_subplans(item.expr, fn)
                    if ne is not item.expr:
                        item = dataclasses.replace(item, expr=ne)
                        dirty = True
                    new_items.append(item)
                else:
                    new_items.append(item)
            if dirty:
                changes[f.name] = new_items
    return dataclasses.replace(rel, **changes) if changes else rel


def _map_subplans(e: Expr, fn) -> Expr:
    def visit(node: Expr) -> Expr:
        if isinstance(node, ScalarSubquery):
            np_ = fn(node.plan)
            if np_ is not node.plan:
                return ScalarSubquery(np_, node.column)
        return node
    return transform_expr(e, visit)


# ---------------------------------------------------------------------------
# constant folding
# ---------------------------------------------------------------------------

_FOLD_ARITH = {"+": lambda a, b: a + b, "-": lambda a, b: a - b,
               "*": lambda a, b: a * b, "/": lambda a, b: a / b}
_FOLD_CMP = {"==": lambda a, b: a == b, "!=": lambda a, b: a != b,
             "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
             ">": lambda a, b: a > b, ">=": lambda a, b: a >= b}


def _is_plain_num(e: Expr) -> bool:
    return (isinstance(e, Lit) and e.kind is None
            and isinstance(e.value, (int, float))
            and not isinstance(e.value, bool))


def _fold_expr(e: Expr) -> Expr:
    def visit(node: Expr) -> Expr:
        if isinstance(node, BinOp):
            l, r = node.left, node.right
            if node.op in _FOLD_ARITH and _is_plain_num(l) and _is_plain_num(r):
                if node.op == "/" and r.value == 0:
                    return node
                return Lit(_FOLD_ARITH[node.op](l.value, r.value))
            if node.op in _FOLD_CMP and _is_plain_num(l) and _is_plain_num(r):
                return Lit(bool(_FOLD_CMP[node.op](l.value, r.value)))
            if node.op in ("and", "or"):
                for a, b in ((l, r), (r, l)):
                    if isinstance(a, Lit) and isinstance(a.value, bool):
                        if node.op == "and":
                            return b if a.value else Lit(False)
                        return Lit(True) if a.value else b
        if isinstance(node, UnOp):
            v = node.operand
            if node.op == "-" and _is_plain_num(v):
                return Lit(-v.value)
            if node.op == "not" and isinstance(v, Lit) \
                    and isinstance(v.value, bool):
                return Lit(not v.value)
            if node.op == "not" and isinstance(v, UnOp) and v.op == "not":
                return v.operand
        return node
    return transform_expr(e, visit)


def fold_constants(rel: Rel, catalog=None) -> Rel:
    rel = _map_children(rel, lambda c: fold_constants(c, catalog))
    changes = {}
    for f in dataclasses.fields(rel):
        v = getattr(rel, f.name)
        if isinstance(v, Expr):
            nv = _fold_expr(v)
            if nv is not v:
                changes[f.name] = nv
        elif isinstance(v, list) and v:
            new_items, dirty = [], False
            for item in v:
                if isinstance(item, tuple) and len(item) == 2 \
                        and isinstance(item[1], Expr):
                    ne = _fold_expr(item[1])
                    dirty |= ne is not item[1]
                    new_items.append((item[0], ne))
                elif hasattr(item, "expr") and isinstance(
                        getattr(item, "expr", None), Expr):
                    ne = _fold_expr(item.expr)
                    if ne is not item.expr:
                        item = dataclasses.replace(item, expr=ne)
                        dirty = True
                    new_items.append(item)
                else:
                    new_items.append(item)
            if dirty:
                changes[f.name] = new_items
    return dataclasses.replace(rel, **changes) if changes else rel


# ---------------------------------------------------------------------------
# predicate pushdown
# ---------------------------------------------------------------------------


def pushdown_predicates(rel: Rel, catalog) -> Rel:
    return _push(rel, [], catalog)


def _push(rel: Rel, preds: List[Expr], catalog) -> Rel:
    """Return a plan equivalent to Filter(rel, AND(preds))."""
    rel = _map_children(rel, lambda c: _push(c, [], catalog)) \
        if not isinstance(rel, (FilterRel, ReadRel, ProjectRel, JoinRel,
                                SortRel, ExchangeRel)) else rel

    if isinstance(rel, FilterRel):
        return _push(rel.input, preds + _conjuncts(rel.condition), catalog)

    if isinstance(rel, ReadRel):
        into_scan = [p for p in preds if not contains_subquery(p)]
        keep = [p for p in preds if contains_subquery(p)]
        if into_scan:
            existing = _conjuncts(rel.filter)
            rel = _replace_children(rel, filter=_and_all(existing + into_scan))
        return _wrap_filter(rel, keep, catalog)

    if isinstance(rel, ProjectRel):
        passthrough = _passthrough_cols(rel, catalog)
        # pure renames (out_name -> Col(src)) are invertible: predicates on
        # the renamed output can be rewritten to the source name and pushed
        # through — this is what carries filters into aliased self-join and
        # derived-table scans, whose every column sits under a rename
        rename = {n: e.name for n, e in rel.exprs if isinstance(e, Col)}
        down, keep = [], []
        for p in preds:
            cols = set(p.columns())
            if not cols:
                keep.append(p)
            elif cols <= passthrough:
                down.append(p)
            elif cols <= (passthrough | set(rename)):
                down.append(transform_expr(
                    p, lambda n: Col(rename[n.name])
                    if isinstance(n, Col) and n.name in rename else n))
            else:
                keep.append(p)
        new_input = _push(rel.input, down, catalog)
        rel = _replace_children(rel, input=new_input)
        return _wrap_filter(rel, keep, catalog)

    if isinstance(rel, (SortRel, ExchangeRel)):
        limited = isinstance(rel, SortRel) and rel.limit is not None
        if limited:
            new_input = _push(rel.input, [], catalog)
            rel = _replace_children(rel, input=new_input)
            return _wrap_filter(rel, preds, catalog)
        new_input = _push(rel.input, preds, catalog)
        return _replace_children(rel, input=new_input)

    if isinstance(rel, JoinRel):
        probe_cols = set(rel_columns(rel.probe, catalog))
        build_cols = set(rel_columns(rel.build, catalog))
        probe_preds: List[Expr] = []
        build_preds: List[Expr] = []
        post: List[Expr] = []
        keep: List[Expr] = []
        build_ok = rel.how in ("inner", "semi", "anti")
        for p in preds:
            cols = set(p.columns())
            if cols and cols <= probe_cols:
                probe_preds.append(p)
            elif build_ok and cols and cols <= build_cols:
                build_preds.append(p)
            elif cols and cols <= (probe_cols | build_cols) \
                    and rel.how == "inner" and not contains_subquery(p):
                post.append(p)
            else:
                keep.append(p)
        new_probe = _push(rel.probe, probe_preds, catalog)
        new_build = _push(rel.build, build_preds, catalog)
        post_filter = rel.post_filter
        if post:
            post_filter = _and_all(_conjuncts(post_filter) + post)
        rel = _replace_children(rel, probe=new_probe, build=new_build,
                                post_filter=post_filter)
        return _wrap_filter(rel, keep, catalog)

    # breakers (Aggregate, Fetch) and anything else: optimize children,
    # keep the predicates above
    return _wrap_filter(rel, preds, catalog)


def _wrap_filter(rel: Rel, preds: List[Expr], catalog=None) -> Rel:
    # predicates that stay behind may embed scalar-subquery plans: those
    # sub-plans still deserve their own pushdown pass
    preds = [_map_subplans(p, lambda sp: _push(sp, [], catalog))
             for p in preds]
    cond = _and_all(preds)
    return rel if cond is None else FilterRel(rel, cond)


def _passthrough_cols(rel: ProjectRel, catalog) -> set:
    """Columns readable below this projection under the same name."""
    defined = {n for n, _ in rel.exprs}
    out = set()
    for n, e in rel.exprs:
        if isinstance(e, Col) and e.name == n:
            out.add(n)
    if rel.keep_input:
        out |= {c for c in rel_columns(rel.input, catalog)
                if c not in defined}
    return out


# ---------------------------------------------------------------------------
# projection pruning
# ---------------------------------------------------------------------------


def prune_projections(rel: Rel, catalog) -> Rel:
    return _prune(rel, None, catalog)


def _req(required, *extra) -> Optional[set]:
    if required is None:
        return None
    out = set(required)
    for cols in extra:
        out |= set(cols)
    return out


def _prune(rel: Rel, required: Optional[set], catalog) -> Rel:
    """Rebuild ``rel`` so it only produces ``required`` columns (None = all).
    Sub-plans inside scalar subqueries are pruned independently."""
    if isinstance(rel, ReadRel):
        if required is not None and catalog is not None \
                and catalog.has_table(rel.table):
            schema = catalog.columns(rel.table)
            cols = [c for c in schema if c in required]
            return _replace_children(rel, columns=cols)
        return rel

    if isinstance(rel, FilterRel):
        child_req = _req(required, rel.condition.columns()) \
            if required is not None else None
        cond = _prune_expr_subplans(rel.condition, catalog)
        return FilterRel(_prune(rel.input, child_req, catalog), cond)

    if isinstance(rel, ProjectRel):
        exprs = [(n, _prune_expr_subplans(e, catalog)) for n, e in rel.exprs]
        if required is not None and not rel.keep_input:
            exprs = [(n, e) for n, e in exprs if n in required] or exprs[:1]
        used: List[str] = []
        for _, e in exprs:
            used.extend(e.columns())
        if rel.keep_input:
            child_req = _req(required, used) if required is not None else None
        else:
            child_req = set(used)
        return ProjectRel(_prune(rel.input, child_req, catalog), exprs,
                          rel.keep_input)

    if isinstance(rel, JoinRel):
        probe_cols = set(rel_columns(rel.probe, catalog))
        build_cols = set(rel_columns(rel.build, catalog))
        post_cols = set(rel.post_filter.columns()) if rel.post_filter \
            is not None else set()
        if required is None:
            probe_req = None
            build_req = None if rel.how in ("inner", "left") else \
                set(rel.build_keys) | (post_cols & build_cols)
        else:
            want = set(required) | post_cols
            probe_req = (want & probe_cols) | set(rel.probe_keys)
            build_req = (want & build_cols) | set(rel.build_keys)
            if rel.how in ("semi", "anti"):
                build_req = set(rel.build_keys) | (post_cols & build_cols)
        post = _prune_expr_subplans(rel.post_filter, catalog) \
            if rel.post_filter is not None else None
        return dataclasses.replace(
            rel,
            probe=_prune(rel.probe, probe_req, catalog),
            build=_prune(rel.build, build_req, catalog),
            post_filter=post)

    if isinstance(rel, AggregateRel):
        # the aggregate defines its input needs exactly, independent of what
        # the parent wants
        child_req: set = set(rel.group_keys)
        aggs = []
        for a in rel.aggs:
            if a.expr is not None:
                child_req |= set(a.expr.columns())
                aggs.append(dataclasses.replace(
                    a, expr=_prune_expr_subplans(a.expr, catalog)))
            else:
                aggs.append(a)
        having = _prune_expr_subplans(rel.having, catalog) \
            if rel.having is not None else None
        return AggregateRel(_prune(rel.input, child_req, catalog),
                            list(rel.group_keys), aggs, having)

    if isinstance(rel, SortRel):
        child_req = _req(required, [k.name for k in rel.keys]) \
            if required is not None else None
        return dataclasses.replace(
            rel, input=_prune(rel.input, child_req, catalog))

    if isinstance(rel, FetchRel):
        return dataclasses.replace(
            rel, input=_prune(rel.input, required, catalog))

    if isinstance(rel, ExchangeRel):
        child_req = _req(required, rel.keys) if required is not None else None
        return dataclasses.replace(
            rel, input=_prune(rel.input, child_req, catalog))

    return rel


def _prune_expr_subplans(e: Expr, catalog) -> Expr:
    def visit(node: Expr) -> Expr:
        if isinstance(node, ScalarSubquery):
            return ScalarSubquery(_prune(node.plan, None, catalog),
                                  node.column)
        return node
    return transform_expr(e, visit)


# ---------------------------------------------------------------------------
# join reordering + build-side selection
# ---------------------------------------------------------------------------

_REORDERABLE = ("inner", "semi", "anti")


def reorder_joins(rel: Rel, catalog) -> Rel:
    rel = _map_children(rel, lambda c: reorder_joins(c, catalog))
    if not isinstance(rel, JoinRel) or rel.how not in _REORDERABLE:
        return rel
    # decompose the left-deep probe spine
    chain: List[JoinRel] = []
    node: Rel = rel
    while isinstance(node, JoinRel) and node.how in _REORDERABLE:
        chain.append(node)
        node = node.probe
    if len(chain) < 2:
        return rel
    base = node
    chain.reverse()                   # bottom-most join first
    base_cols = set(rel_columns(base, catalog))

    entries = []
    for j in chain:
        post_cols = set(j.post_filter.columns()) if j.post_filter is not None \
            else set()
        entries.append({
            "join": j,
            "build_cols": set(rel_columns(j.build, catalog)),
            "build_est": estimate(j.build, catalog),
            "post_cols": post_cols,
        })

    ordered = []
    avail = set(base_cols)
    pending = list(entries)
    while pending:
        # candidates whose probe keys (and post-filter probe-side columns)
        # are already available on the spine
        cands = []
        for ent in pending:
            j = ent["join"]
            need = set(j.probe_keys) | (ent["post_cols"] - ent["build_cols"])
            if need <= avail:
                cands.append(ent)
        if not cands:
            return rel                # give up: keep original order
        # greedy: smallest estimated build side first (semi/anti are
        # row-reducing, so their small builds naturally float up).
        # Identity-based removal: these dicts hold Rel/Expr whose == is
        # overloaded, so list.remove would mis-match.
        ent = min(cands, key=lambda e: e["build_est"])
        pending = [p for p in pending if p is not ent]
        ordered.append(ent)
        if ent["join"].how == "inner":
            avail |= ent["build_cols"]

    out: Rel = base
    for ent in ordered:
        j = ent["join"]
        out = dataclasses.replace(j, probe=out)
    return out


def choose_build_sides(rel: Rel, catalog) -> Rel:
    rel = _map_children(rel, lambda c: choose_build_sides(c, catalog))
    if isinstance(rel, JoinRel) and rel.how == "inner":
        p = estimate(rel.probe, catalog)
        b = estimate(rel.build, catalog)
        if b > p * 1.2:               # hysteresis: only swap when clearly won
            rel = dataclasses.replace(
                rel, probe=rel.build, build=rel.probe,
                probe_keys=list(rel.build_keys),
                build_keys=list(rel.probe_keys))
    return rel


# ---------------------------------------------------------------------------
# conjunct ordering (most selective first)
# ---------------------------------------------------------------------------


def order_conjuncts(rel: Rel, catalog=None) -> Rel:
    rel = _map_children(rel, lambda c: order_conjuncts(c, catalog))

    def reorder(e: Optional[Expr]) -> Optional[Expr]:
        cs = _conjuncts(e)
        if len(cs) < 2:
            return e
        cs.sort(key=lambda c: selectivity(c, catalog))
        return _and_all(cs)

    if isinstance(rel, ReadRel) and rel.filter is not None:
        return _replace_children(rel, filter=reorder(rel.filter))
    if isinstance(rel, FilterRel):
        return _replace_children(rel, condition=reorder(rel.condition))
    return rel
