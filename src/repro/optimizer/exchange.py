"""Exchange-placement optimizer pass + distributed fragment cutting.

The distributed lifecycle the paper describes for Doris+Sirius (and that
"Terabyte-Scale Analytics in the Blink of an Eye" / "Accelerating Presto
with GPUs" both share): the optimizer decides *at plan time* where rows
must move, inserts explicit exchange operators, and the engine executes the
plan as compiled fragments glued together by collectives.

This module is that plan-time half:

* :func:`place_exchanges` walks an optimized single-node plan tracking the
  **partitioning state** of every intermediate —

  - ``hash(k)``   rows hash-partitioned across shards on column ``k``
  - ``rr``        rows disjoint across shards, but on no useful key
  - ``rep``       every shard holds a full replica
  - ``coord``     rows only exist merged on the coordinator

  and inserts ``ExchangeRel`` boundaries (shuffle / broadcast / merge)
  where an operator's distribution requirement is not already met.  The
  build-side-selection rule uses the stats layer: a build side whose
  estimated replication cost ``est_build * (n_shards-1)`` is below the
  probe's estimated rows is broadcast; otherwise both sides are
  hash-partitioned onto a shared join key.  Group-bys either reuse an
  existing partitioning, or — when every aggregate decomposes — run as
  partial aggregation per shard, shuffle the (small) partials on a group
  key, and finalize after the exchange (``avg`` decomposes into sum/count,
  the case the paper's prototype lacked).  Order-dependent tails (sort,
  fetch, window over foreign partitionings, global aggregates) merge to the
  coordinator.

* :func:`cut_fragments` cuts the exchanged plan at every ``ExchangeRel``
  into dependency-ordered :class:`ExchangeFragment`\\ s — the same
  recursive boundary-scan rewrite the hybrid router uses, with each cut
  edge becoming a ``ReadRel`` on a ``__dist_frag<N>`` registry table.

Correctness rules encoded here (each one is load-bearing):

* a replicated probe over a hash-partitioned build is exact for
  inner/semi joins only; anti/left/mark joins would emit their
  non-matching probe rows once per shard, so those force the probe onto a
  disjoint partitioning first;
* a probe on ``rr`` must be re-shuffled even for inner joins (its rows are
  not where their build matches live);
* shuffling on a group key makes every group complete on one shard, so all
  aggregate functions — including non-decomposable ``count_distinct`` and
  ``having`` — evaluate exactly with no combine step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, SetRel, SortRel, WindowRel, walk_deep,
)
from ..relational.aggregate import AggSpec
from ..relational.expressions import BinOp, Col
from ..substrait.router import Fragment
from .stats import estimate

DIST_BOUNDARY_PREFIX = "__dist_frag"

HASH, RR, REP, COORD = "hash", "rr", "rep", "coord"

# aggregate functions with an exact partial/combine decomposition
_DECOMPOSABLE = {"sum", "count", "count_star", "min", "max", "avg"}


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """Distribution state of an intermediate result across the mesh."""

    kind: str                      # hash | rr | rep | coord
    key: Optional[str] = None      # partition column for kind == hash


@dataclasses.dataclass
class ExchangeFragment(Fragment):
    """A cut plan piece plus its *output* exchange.

    ``kind`` is how this fragment's rows leave it (``shuffle`` /
    ``broadcast`` / ``merge``; ``None`` for the root), ``run_once`` marks
    fragments whose inputs are fully replicated (executing them per shard
    would duplicate rows), and ``pt`` optionally names a committed build
    side whose keys may pre-filter this fragment's shuffle (predicate
    transfer)."""

    kind: Optional[str] = None
    keys: List[str] = dataclasses.field(default_factory=list)
    run_once: bool = False
    pt: Optional[Tuple[int, str, str]] = None   # (build fid, probe key, build key)

    @property
    def label(self) -> str:
        """Stable human-readable handle (``f<fid>_<kind>``) — the name the
        coordinator's dispatch loop, fault-injection plans, checkpoints
        and journal spans all agree on."""
        return f"f{self.fid}_{self.kind or 'final'}"


def boundary_name(fid: int) -> str:
    return f"{DIST_BOUNDARY_PREFIX}{fid}"


def is_dist_boundary(rel: Rel) -> bool:
    return isinstance(rel, ReadRel) and rel.table.startswith(DIST_BOUNDARY_PREFIX)


def _part_of(rel: Rel, default=Partitioning(RR)) -> Partitioning:
    return getattr(rel, "dist_part", default)


def _tag(rel: Rel, part: Partitioning) -> Rel:
    rel.dist_part = part
    return rel


def _shuffle(rel: Rel, key: str) -> Rel:
    ex = ExchangeRel(rel, "shuffle", [key])
    ex.dist_input_part = _part_of(rel)
    return _tag(ex, Partitioning(HASH, key))


def _broadcast(rel: Rel, keys: List[str]) -> Rel:
    ex = ExchangeRel(rel, "broadcast", list(keys))
    ex.dist_input_part = _part_of(rel)
    return _tag(ex, Partitioning(REP))


def _merge(rel: Rel) -> Rel:
    ex = ExchangeRel(rel, "merge")
    ex.dist_input_part = _part_of(rel)
    return _tag(ex, Partitioning(COORD))


def _project_part(rel: ProjectRel, p: Partitioning) -> Partitioning:
    """Track a hash partitioning through projection renames."""
    if p.kind != HASH:
        return p
    for n, e in rel.exprs:
        if n == p.key and isinstance(e, Col) and e.name == p.key:
            return p
    for n, e in rel.exprs:
        if isinstance(e, Col) and e.name == p.key:
            return Partitioning(HASH, n)
    names = [n for n, _ in rel.exprs]
    if rel.keep_input and p.key not in names:
        return p                        # key column passes through untouched
    if not rel.keep_input and p.key not in names:
        return Partitioning(RR)         # key dropped; rows still disjoint
    return Partitioning(RR)             # key name rebound to a new expression


def _decompose_aggs(aggs: List[AggSpec]):
    """partial + final AggSpecs (and avg fix-up projections) for a
    two-phase aggregation.  Returns None when not decomposable."""
    partial: List[AggSpec] = []
    final: List[AggSpec] = []
    avg_fixes: List[str] = []
    for a in aggs:
        if a.fn not in _DECOMPOSABLE:
            return None
        if a.fn == "avg":
            partial.append(AggSpec("sum", a.expr, a.name + "__psum"))
            partial.append(AggSpec("count", a.expr, a.name + "__pcnt"))
            final.append(AggSpec("sum", Col(a.name + "__psum"), a.name + "__psum"))
            final.append(AggSpec("sum", Col(a.name + "__pcnt"), a.name + "__pcnt"))
            avg_fixes.append(a.name)
        elif a.fn in ("count", "count_star"):
            partial.append(AggSpec(a.fn, a.expr, a.name))
            final.append(AggSpec("sum", Col(a.name), a.name))
        else:                           # sum / min / max combine with themselves
            partial.append(AggSpec(a.fn, a.expr, a.name))
            final.append(AggSpec(a.fn, Col(a.name), a.name))
    return partial, final, avg_fixes


def _finalize_agg(boundary: Rel, rel: AggregateRel, final, avg_fixes) -> Rel:
    """Combine step over exchanged partials, restoring the original
    output schema (group keys first, aggregates in declaration order)."""
    out: Rel = AggregateRel(boundary, list(rel.group_keys), final)
    if avg_fixes:
        exprs = [(k, Col(k)) for k in rel.group_keys]
        for a in rel.aggs:
            if a.name in avg_fixes:
                exprs.append((a.name, BinOp("/", Col(a.name + "__psum"),
                                            Col(a.name + "__pcnt"))))
            else:
                exprs.append((a.name, Col(a.name)))
        out = ProjectRel(out, exprs)
    if rel.having is not None:
        out = FilterRel(out, rel.having)
    return out


class ExchangePlacer:
    """One placement run: plan in, exchanged-and-tagged plan out."""

    def __init__(self, catalog, n_shards: int,
                 table_parts: Dict[str, Partitioning]):
        self.catalog = catalog
        self.n_shards = n_shards
        self.table_parts = table_parts

    def run(self, plan: Rel) -> Rel:
        placed = self.place(plan)
        if _part_of(placed).kind in (HASH, RR):
            placed = _merge(placed)
        return placed

    # -- per-node placement ------------------------------------------------

    def place(self, rel: Rel) -> Rel:
        fn = getattr(self, "_place_" + type(rel).__name__, None)
        if fn is not None:
            return fn(rel)
        # unknown rel: pin to the coordinator, merging any partitioned input
        changes = {}
        for f in dataclasses.fields(rel):
            v = getattr(rel, f.name)
            if isinstance(v, Rel):
                changes[f.name] = self._to_complete(self.place(v))
        out = dataclasses.replace(rel, **changes) if changes else rel
        return _tag(out, Partitioning(COORD))

    def _to_complete(self, rel: Rel) -> Rel:
        """Ensure every row of ``rel`` is visible to a single consumer
        (coordinator-complete or replicated)."""
        if _part_of(rel).kind in (REP, COORD):
            return rel
        return _merge(rel)

    def _place_ReadRel(self, rel: ReadRel) -> Rel:
        part = self.table_parts.get(rel.table, Partitioning(REP))
        return _tag(rel, part)

    def _place_FilterRel(self, rel: FilterRel) -> Rel:
        i = self.place(rel.input)
        return _tag(dataclasses.replace(rel, input=i), _part_of(i))

    def _place_ProjectRel(self, rel: ProjectRel) -> Rel:
        i = self.place(rel.input)
        out = dataclasses.replace(rel, input=i)
        return _tag(out, _project_part(rel, _part_of(i)))

    def _place_ExchangeRel(self, rel: ExchangeRel) -> Rel:
        # pre-existing exchanges (none in our plans) are transparent
        i = self.place(rel.input)
        return _tag(dataclasses.replace(rel, input=i), _part_of(i))

    def _place_JoinRel(self, rel: JoinRel) -> Rel:
        probe = self.place(rel.probe)
        build = self.place(rel.build)
        pp, bp = _part_of(probe), _part_of(build)

        if COORD in (pp.kind, bp.kind):
            out = dataclasses.replace(rel, probe=self._to_complete(probe),
                                      build=self._to_complete(build))
            return _tag(out, Partitioning(COORD))

        if bp.kind == REP:
            # build already everywhere: exact for every join kind
            out = dataclasses.replace(rel, probe=probe, build=build)
            return _tag(out, pp)

        est_p = estimate(probe, self.catalog)
        est_b = estimate(build, self.catalog)
        if est_b * max(self.n_shards - 1, 0) <= est_p:
            out = dataclasses.replace(
                rel, probe=probe,
                build=_broadcast(build, rel.build_keys))
            return _tag(out, pp)

        # hash path: co-partition both sides on one equi-key pair
        best, score = 0, -1
        for i, (pk, bk) in enumerate(zip(rel.probe_keys, rel.build_keys)):
            s = (pp == Partitioning(HASH, pk)) + (bp == Partitioning(HASH, bk))
            if s > score:
                best, score = i, s
        pk, bk = rel.probe_keys[best], rel.build_keys[best]

        if bp != Partitioning(HASH, bk):
            build = _shuffle(build, bk)
        if pp == Partitioning(HASH, pk):
            pass
        elif pp.kind == REP and rel.how in ("inner", "semi"):
            # replicated probe sees every build partition's matches exactly
            # once; wrong for anti/left/mark (misses would repeat per shard)
            pass
        else:
            probe = _shuffle(probe, pk)

        out = dataclasses.replace(rel, probe=probe, build=build)
        # either the probe ends hash(pk), or a replicated probe's matches
        # land wherever the build partition lives — hash(pk) both ways
        return _tag(out, Partitioning(HASH, pk))

    def _place_AggregateRel(self, rel: AggregateRel) -> Rel:
        i = self.place(rel.input)
        p = _part_of(i)
        if p.kind == COORD:
            return _tag(dataclasses.replace(rel, input=i), Partitioning(COORD))
        if p.kind == REP:
            return _tag(dataclasses.replace(rel, input=i), Partitioning(REP))

        if not rel.group_keys:
            # min/max partials from empty shards would contribute identity
            # values with no group row to hide behind — keep those global
            # aggregates on the coordinator
            dec = None if any(a.fn in ("min", "max") for a in rel.aggs) \
                else _decompose_aggs(rel.aggs)
            if dec is None:
                return _tag(dataclasses.replace(rel, input=self._to_complete(i)),
                            Partitioning(COORD))
            partial_specs, final_specs, avg_fixes = dec
            partial = _tag(AggregateRel(i, [], partial_specs),
                           Partitioning(RR))
            out = _finalize_agg(_merge(partial), rel, final_specs, avg_fixes)
            return _tag(out, Partitioning(COORD))

        if p.kind == HASH and p.key in rel.group_keys:
            # groups already complete per shard: every aggregate (incl.
            # count_distinct / having) evaluates exactly with no combine
            return _tag(dataclasses.replace(rel, input=i),
                        Partitioning(HASH, p.key))

        key = rel.group_keys[0]
        dec = _decompose_aggs(rel.aggs)
        if dec is None:
            # shuffle raw rows so each group lands whole on one shard
            return _tag(dataclasses.replace(rel, input=_shuffle(i, key)),
                        Partitioning(HASH, key))
        partial_specs, final_specs, avg_fixes = dec
        partial = _tag(AggregateRel(i, list(rel.group_keys), partial_specs), p)
        out = _finalize_agg(_shuffle(partial, key), rel, final_specs, avg_fixes)
        return _tag(out, Partitioning(HASH, key))

    def _ordered_tail(self, rel: Rel) -> Rel:
        """sort / fetch: global order — complete the input."""
        i = self.place(rel.input)
        out = dataclasses.replace(rel, input=self._to_complete(i))
        return _tag(out, Partitioning(COORD) if _part_of(i).kind != REP
                    else Partitioning(REP))

    _place_SortRel = _ordered_tail
    _place_FetchRel = _ordered_tail

    def _place_WindowRel(self, rel: WindowRel) -> Rel:
        i = self.place(rel.input)
        p = _part_of(i)
        if p.kind == HASH and p.key in rel.partition_keys:
            # window partitions are complete per shard
            return _tag(dataclasses.replace(rel, input=i), p)
        out = dataclasses.replace(rel, input=self._to_complete(i))
        return _tag(out, Partitioning(COORD) if p.kind != REP
                    else Partitioning(REP))

    def _place_SetRel(self, rel: SetRel) -> Rel:
        ops = [self.place(o) for o in rel.operands]
        parts = [_part_of(o) for o in ops]
        if all(p.kind == REP for p in parts):
            return _tag(dataclasses.replace(rel, operands=ops),
                        Partitioning(REP))
        if len(set(parts)) == 1 and parts[0].kind == HASH:
            return _tag(dataclasses.replace(rel, operands=ops), parts[0])
        ops = [self._to_complete(o) for o in ops]
        return _tag(dataclasses.replace(rel, operands=ops),
                    Partitioning(COORD))


def place_exchanges(plan: Rel, catalog, n_shards: int,
                    table_parts: Dict[str, Partitioning]) -> Rel:
    """Insert exchange boundaries; every returned node carries a
    ``dist_part`` tag and the root is coordinator-complete or replicated."""
    return ExchangePlacer(catalog, n_shards, table_parts).run(plan)


# ---------------------------------------------------------------------------
# fragment cutting
# ---------------------------------------------------------------------------


def cut_fragments(plan: Rel) -> List[ExchangeFragment]:
    """Cut a placed plan at every ``ExchangeRel`` into dependency-ordered
    fragments (root last) — the hybrid router's boundary-scan rewrite,
    with the exchange kind/keys recorded on the producing fragment."""
    fragments: List[ExchangeFragment] = []

    def make(root: Rel, kind: Optional[str], keys: List[str]) -> int:
        deps: List[int] = []

        def rewrite(node: Rel) -> Rel:
            if isinstance(node, ExchangeRel):
                fid = make(node.input, node.kind, node.keys)
                deps.append(fid)
                return ReadRel(boundary_name(fid))
            changes = {}
            field_names = [f.name for f in dataclasses.fields(node)]
            if isinstance(node, JoinRel):
                # build before probe: the committed build side can then
                # predicate-transfer into the probe's exchange
                field_names.remove("build")
                field_names.insert(0, "build")
            for fname in field_names:
                v = getattr(node, fname)
                if isinstance(v, Rel):
                    nv = rewrite(v)
                    if nv is not v:
                        changes[fname] = nv
                elif isinstance(v, list) and any(isinstance(x, Rel)
                                                 for x in v):
                    changes[fname] = [rewrite(x) if isinstance(x, Rel)
                                      else x for x in v]
            return dataclasses.replace(node, **changes) if changes else node

        new_root = rewrite(root)
        part = _part_of(root, default=Partitioning(COORD))
        is_root = kind is None
        placement = "coordinator" if is_root and part.kind in (COORD, REP) \
            else "shard"
        n_rels = sum(1 for r in walk_deep(new_root) if not is_dist_boundary(r))
        frag = ExchangeFragment(
            fid=len(fragments), plan=new_root, placement=placement,
            deps=deps, rel_count=n_rels, kind=kind, keys=list(keys),
            run_once=(part.kind == REP and not is_root))
        fragments.append(frag)
        return frag.fid

    make(plan, None, [])
    _mark_predicate_transfer(fragments)
    return fragments


def _mark_predicate_transfer(fragments: List[ExchangeFragment]) -> None:
    """Tag shuffle fragments that feed the probe of an inner/semi join
    whose build side is a registry table committed earlier: their rows may
    be pre-filtered by the build keys before the collective."""
    by_name = {boundary_name(f.fid): f for f in fragments}
    for consumer in fragments:
        for rel in walk_deep(consumer.plan):
            if not isinstance(rel, JoinRel) or rel.how not in ("inner", "semi"):
                continue
            if not (is_dist_boundary(rel.probe) and is_dist_boundary(rel.build)):
                continue
            pf = by_name.get(rel.probe.table)
            bf = by_name.get(rel.build.table)
            if pf is None or bf is None or bf.fid >= pf.fid:
                continue
            if pf.kind == "shuffle" and pf.pt is None:
                pf.pt = (bf.fid, rel.probe_keys[0], rel.build_keys[0])


def explain_placed(fragments: List[ExchangeFragment]) -> str:
    from ..core.plan import explain
    lines = []
    for f in fragments:
        head = f"fragment {f.fid}: out={f.kind or 'final'}"
        if f.keys:
            head += f" keys={f.keys}"
        head += f" placement={f.placement}"
        if f.run_once:
            head += " run_once"
        lines.append(head)
        lines.append(explain(f.plan, indent=1))
    return "\n".join(lines)
