"""TPC-H data generator (schema-faithful, FK-consistent, spec-like distributions).

A vectorized numpy re-implementation of dbgen sufficient for all 22 queries:
correct schemas, consistent foreign keys (including the 4-suppliers-per-part
partsupp structure and the "only 2/3 of customers have orders" rule that Q13 /
Q22 depend on), spec word lists for p_name/p_type/p_brand/containers/modes,
date arithmetic relations (ship/commit/receipt), and comment streams that
contain the exact patterns probed by Q13/Q16.

Output is the **host database format**: dict[table] -> dict[col] -> np.ndarray
(strings as unicode arrays, dates as datetime64[D]).  The buffer manager
deep-copies this into the device cache (the paper's cold run).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

HostDB = Dict[str, Dict[str, np.ndarray]]

REGIONS = np.array(["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"])
# 25 nations with their spec region keys
NATIONS = [
    ("ALGERIA", 0), ("ARGENTINA", 1), ("BRAZIL", 1), ("CANADA", 1),
    ("EGYPT", 4), ("ETHIOPIA", 0), ("FRANCE", 3), ("GERMANY", 3),
    ("INDIA", 2), ("INDONESIA", 2), ("IRAN", 4), ("IRAQ", 4),
    ("JAPAN", 2), ("JORDAN", 4), ("KENYA", 0), ("MOROCCO", 0),
    ("MOZAMBIQUE", 0), ("PERU", 1), ("CHINA", 2), ("ROMANIA", 3),
    ("SAUDI ARABIA", 4), ("VIETNAM", 2), ("RUSSIA", 3),
    ("UNITED KINGDOM", 3), ("UNITED STATES", 1),
]

SEGMENTS = np.array(["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"])
PRIORITIES = np.array(["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"])
SHIPMODES = np.array(["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"])
INSTRUCTS = np.array(["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"])
TYPE_S1 = np.array(["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"])
TYPE_S2 = np.array(["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"])
TYPE_S3 = np.array(["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"])
CONT_S1 = np.array(["SM", "LG", "MED", "JUMBO", "WRAP"])
CONT_S2 = np.array(["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"])
# P_NAME word list (subset of the spec's 92 words; includes the query probes)
P_WORDS = np.array([
    "almond", "antique", "aquamarine", "azure", "beige", "bisque", "black",
    "blanched", "blue", "blush", "brown", "burlywood", "burnished", "chartreuse",
    "chiffon", "chocolate", "coral", "cornflower", "cornsilk", "cream", "cyan",
    "dark", "deep", "dim", "dodger", "drab", "firebrick", "floral", "forest",
    "frosted", "gainsboro", "ghost", "goldenrod", "green", "grey", "honeydew",
    "hot", "hunter", "indian", "ivory", "khaki", "lace", "lavender", "lawn",
    "lemon", "light", "lime", "linen", "magenta", "maroon", "medium", "metallic",
    "midnight", "mint", "misty", "moccasin", "navajo", "navy", "olive", "orange",
    "orchid", "pale", "papaya", "peach", "peru", "pink", "plum", "powder",
    "puff", "purple", "red", "rose", "rosy", "royal", "saddle", "salmon",
    "sandy", "seashell", "sienna", "sky", "slate", "smoke", "snow", "spring",
    "steel", "tan", "thistle", "tomato", "turquoise", "violet", "wheat", "white",
])
COMMENT_WORDS = np.array([
    "carefully", "quickly", "furiously", "slyly", "blithely", "deposits",
    "accounts", "packages", "requests", "instructions", "foxes", "pinto",
    "beans", "theodolites", "dependencies", "platelets", "ideas", "special",
    "regular", "express", "bold", "final", "pending", "ironic", "even",
    "silent", "unusual", "Customer", "Complaints", "sleep", "haggle", "nag",
    "wake", "cajole", "detect", "integrate", "engage", "above", "against",
])

START = np.datetime64("1992-01-01", "D")
END = np.datetime64("1998-08-02", "D")
CURRENTDATE = np.datetime64("1995-06-17", "D")

# ---------------------------------------------------------------------------
# Catalog metadata: the schema the SQL binder resolves against and the base
# cardinalities (rows at SF1) the optimizer's cost heuristics start from.
# Kinds mirror relational.table: numeric | string | date.
# ---------------------------------------------------------------------------

TPCH_SCHEMA = {
    "region": {
        "r_regionkey": "numeric", "r_name": "string", "r_comment": "string",
    },
    "nation": {
        "n_nationkey": "numeric", "n_name": "string",
        "n_regionkey": "numeric", "n_comment": "string",
    },
    "supplier": {
        "s_suppkey": "numeric", "s_name": "string", "s_address": "string",
        "s_nationkey": "numeric", "s_phone": "string", "s_acctbal": "numeric",
        "s_comment": "string",
    },
    "part": {
        "p_partkey": "numeric", "p_name": "string", "p_mfgr": "string",
        "p_brand": "string", "p_type": "string", "p_size": "numeric",
        "p_container": "string", "p_retailprice": "numeric",
        "p_comment": "string",
    },
    "partsupp": {
        "ps_partkey": "numeric", "ps_suppkey": "numeric",
        "ps_availqty": "numeric", "ps_supplycost": "numeric",
        "ps_comment": "string",
    },
    "customer": {
        "c_custkey": "numeric", "c_name": "string", "c_address": "string",
        "c_nationkey": "numeric", "c_phone": "string", "c_acctbal": "numeric",
        "c_mktsegment": "string", "c_comment": "string",
    },
    "orders": {
        "o_orderkey": "numeric", "o_custkey": "numeric",
        "o_orderstatus": "string", "o_totalprice": "numeric",
        "o_orderdate": "date", "o_orderpriority": "string",
        "o_clerk": "string", "o_shippriority": "numeric",
        "o_comment": "string",
    },
    "lineitem": {
        "l_orderkey": "numeric", "l_partkey": "numeric",
        "l_suppkey": "numeric", "l_linenumber": "numeric",
        "l_quantity": "numeric", "l_extendedprice": "numeric",
        "l_discount": "numeric", "l_tax": "numeric",
        "l_returnflag": "string", "l_linestatus": "string",
        "l_shipdate": "date", "l_commitdate": "date",
        "l_receiptdate": "date", "l_shipinstruct": "string",
        "l_shipmode": "string", "l_comment": "string",
    },
}

TPCH_BASE_ROWS = {
    "region": 5, "nation": 25, "supplier": 10_000, "part": 200_000,
    "partsupp": 800_000, "customer": 150_000, "orders": 1_500_000,
    "lineitem": 6_000_000,
}


def _comments(rng: np.random.Generator, n: int, words: int = 4) -> np.ndarray:
    idx = rng.integers(0, len(COMMENT_WORDS), size=(n, words))
    parts = COMMENT_WORDS[idx]
    out = parts[:, 0]
    for j in range(1, words):
        out = np.char.add(np.char.add(out, " "), parts[:, j])
    return out


def _phones(rng: np.random.Generator, nkeys: np.ndarray) -> np.ndarray:
    cc = np.char.zfill((nkeys + 10).astype(str), 2)
    def seg(lo, hi, width):
        return np.char.zfill(rng.integers(lo, hi, size=len(nkeys)).astype(str), width)
    return np.char.add(np.char.add(np.char.add(np.char.add(np.char.add(
        np.char.add(cc, "-"), seg(100, 999, 3)), "-"), seg(100, 999, 3)), "-"),
        seg(1000, 9999, 4))


def generate(scale_factor: float = 0.01, seed: int = 19920101) -> HostDB:
    rng = np.random.default_rng(seed)
    sf = scale_factor
    n_supp = max(int(10_000 * sf), 20)
    n_part = max(int(200_000 * sf), 50)
    n_cust = max(int(150_000 * sf), 30)
    n_ord = max(int(1_500_000 * sf), 150)

    db: HostDB = {}

    # region / nation --------------------------------------------------------
    db["region"] = {
        "r_regionkey": np.arange(5, dtype=np.int64),
        "r_name": REGIONS.copy(),
        "r_comment": _comments(rng, 5),
    }
    n_names = np.array([n for n, _ in NATIONS])
    n_rk = np.array([r for _, r in NATIONS], dtype=np.int64)
    db["nation"] = {
        "n_nationkey": np.arange(25, dtype=np.int64),
        "n_name": n_names,
        "n_regionkey": n_rk,
        "n_comment": _comments(rng, 25),
    }

    # supplier ----------------------------------------------------------------
    sk = np.arange(1, n_supp + 1, dtype=np.int64)
    s_nk = rng.integers(0, 25, n_supp)
    # 5 per 10k suppliers get the Customer Complaints comment (spec-like rarity,
    # scaled so small SFs still exercise Q16's anti join)
    s_comment = _comments(rng, n_supp)
    n_complaints = max(n_supp // 200, 2)
    idx = rng.choice(n_supp, n_complaints, replace=False)
    s_comment[idx] = np.char.add(
        np.char.add("take Customer ", _comments(rng, n_complaints, 1)),
        " Complaints against")
    db["supplier"] = {
        "s_suppkey": sk,
        "s_name": np.char.add("Supplier#", np.char.zfill(sk.astype(str), 9)),
        "s_address": _comments(rng, n_supp, 2),
        "s_nationkey": s_nk,
        "s_phone": _phones(rng, s_nk),
        "s_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_supp), 2),
        "s_comment": s_comment,
    }

    # part ---------------------------------------------------------------------
    pk = np.arange(1, n_part + 1, dtype=np.int64)
    w = P_WORDS[rng.integers(0, len(P_WORDS), size=(n_part, 5))]
    p_name = w[:, 0]
    for j in range(1, 5):
        p_name = np.char.add(np.char.add(p_name, " "), w[:, j])
    m = rng.integers(1, 6, n_part)
    nn = rng.integers(1, 6, n_part)
    p_type = np.char.add(np.char.add(np.char.add(
        TYPE_S1[rng.integers(0, 6, n_part)], " "),
        np.char.add(TYPE_S2[rng.integers(0, 5, n_part)], " ")),
        TYPE_S3[rng.integers(0, 5, n_part)])
    db["part"] = {
        "p_partkey": pk,
        "p_name": p_name,
        "p_mfgr": np.char.add("Manufacturer#", m.astype(str)),
        "p_brand": np.char.add(np.char.add("Brand#", m.astype(str)), nn.astype(str)),
        "p_type": p_type,
        "p_size": rng.integers(1, 51, n_part),
        "p_container": np.char.add(np.char.add(
            CONT_S1[rng.integers(0, 5, n_part)], " "),
            CONT_S2[rng.integers(0, 8, n_part)]),
        "p_retailprice": np.round(
            (90000 + (pk % 20001) / 10 + 100 * (pk % 1000)) / 100, 2),
        "p_comment": _comments(rng, n_part, 2),
    }

    # partsupp: exactly 4 distinct suppliers per part (spec formula) -----------
    i = np.repeat(np.arange(4), n_part)
    ps_pk = np.tile(pk, 4)
    ps_sk = ((ps_pk - 1 + i * (n_supp // 4 + (ps_pk - 1) // n_supp)) % n_supp) + 1
    order_ps = np.lexsort((ps_sk, ps_pk))
    ps_pk, ps_sk = ps_pk[order_ps], ps_sk[order_ps]
    n_ps = len(ps_pk)
    db["partsupp"] = {
        "ps_partkey": ps_pk,
        "ps_suppkey": ps_sk.astype(np.int64),
        "ps_availqty": rng.integers(1, 10_000, n_ps),
        "ps_supplycost": np.round(rng.uniform(1.0, 1000.0, n_ps), 2),
        "ps_comment": _comments(rng, n_ps, 3),
    }

    # customer -----------------------------------------------------------------
    ck = np.arange(1, n_cust + 1, dtype=np.int64)
    c_nk = rng.integers(0, 25, n_cust)
    db["customer"] = {
        "c_custkey": ck,
        "c_name": np.char.add("Customer#", np.char.zfill(ck.astype(str), 9)),
        "c_address": _comments(rng, n_cust, 2),
        "c_nationkey": c_nk,
        "c_phone": _phones(rng, c_nk),
        "c_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_cust), 2),
        "c_mktsegment": SEGMENTS[rng.integers(0, 5, n_cust)],
        "c_comment": _comments(rng, n_cust, 3),
    }

    # orders: only customers with custkey % 3 != 0 place orders (spec) ----------
    ok = np.arange(1, n_ord + 1, dtype=np.int64)
    eligible = ck[ck % 3 != 0]
    o_ck = rng.choice(eligible, n_ord)
    span = int((END - START).astype(int)) - 151
    o_date = START + rng.integers(0, span, n_ord).astype("timedelta64[D]")
    o_comment = _comments(rng, n_ord, 3)
    # inject '%special%requests%' pattern probed by Q13 (~1% of orders)
    n_special = max(n_ord // 100, 3)
    idx = rng.choice(n_ord, n_special, replace=False)
    o_comment[idx] = np.char.add(
        np.char.add("handle special ", _comments(rng, n_special, 1)),
        " requests carefully")

    # lineitem: 1..7 lines per order --------------------------------------------
    lines_per = rng.integers(1, 8, n_ord)
    n_li = int(lines_per.sum())
    l_ok = np.repeat(ok, lines_per)
    starts = np.zeros(n_ord, np.int64)
    np.cumsum(lines_per[:-1], out=starts[1:])
    l_ln = (np.arange(n_li) - np.repeat(starts, lines_per) + 1).astype(np.int64)
    l_pk = rng.integers(1, n_part + 1, n_li).astype(np.int64)
    which = rng.integers(0, 4, n_li)
    l_sk = ((l_pk - 1 + which * (n_supp // 4 + (l_pk - 1) // n_supp)) % n_supp) + 1
    qty = rng.integers(1, 51, n_li).astype(np.int64)
    retail = db["part"]["p_retailprice"][l_pk - 1]
    ext = np.round(qty * retail, 2)
    disc = np.round(rng.integers(0, 11, n_li) / 100.0, 2)
    tax = np.round(rng.integers(0, 9, n_li) / 100.0, 2)
    o_date_per_line = np.repeat(o_date, lines_per)
    shipd = o_date_per_line + rng.integers(1, 122, n_li).astype("timedelta64[D]")
    commitd = o_date_per_line + rng.integers(30, 91, n_li).astype("timedelta64[D]")
    receiptd = shipd + rng.integers(1, 31, n_li).astype("timedelta64[D]")
    returnflag = np.where(
        receiptd <= CURRENTDATE,
        np.where(rng.random(n_li) < 0.5, "R", "A"), "N").astype("U1")
    linestatus = np.where(shipd > CURRENTDATE, "O", "F").astype("U1")

    net = ext * (1 - disc) * (1 + tax)
    totalprice = np.zeros(n_ord)
    np.add.at(totalprice, np.repeat(np.arange(n_ord), lines_per), net)

    db["orders"] = {
        "o_orderkey": ok,
        "o_custkey": o_ck,
        "o_orderstatus": np.where(
            np.bincount(np.repeat(np.arange(n_ord), lines_per),
                        (linestatus == "F"), n_ord) == lines_per, "F",
            np.where(np.bincount(np.repeat(np.arange(n_ord), lines_per),
                                 (linestatus == "O"), n_ord) == lines_per,
                     "O", "P")).astype("U1"),
        "o_totalprice": np.round(totalprice, 2),
        "o_orderdate": o_date,
        "o_orderpriority": PRIORITIES[rng.integers(0, 5, n_ord)],
        "o_clerk": np.char.add("Clerk#", np.char.zfill(
            rng.integers(1, max(int(1000 * sf), 10) + 1, n_ord).astype(str), 9)),
        "o_shippriority": np.zeros(n_ord, np.int64),
        "o_comment": o_comment,
    }
    db["lineitem"] = {
        "l_orderkey": l_ok,
        "l_partkey": l_pk,
        "l_suppkey": l_sk.astype(np.int64),
        "l_linenumber": l_ln,
        "l_quantity": qty.astype(np.float64),
        "l_extendedprice": ext,
        "l_discount": disc,
        "l_tax": tax,
        "l_returnflag": returnflag,
        "l_linestatus": linestatus,
        "l_shipdate": shipd,
        "l_commitdate": commitd,
        "l_receiptdate": receiptd,
        "l_shipinstruct": INSTRUCTS[rng.integers(0, 4, n_li)],
        "l_shipmode": SHIPMODES[rng.integers(0, 7, n_li)],
        "l_comment": _comments(rng, n_li, 2),
    }
    return db


def load_into_engine(engine, db: HostDB) -> None:
    """Cold-run load: host format → device cache via the buffer manager."""
    from ..relational.table import Table

    for name, cols in db.items():
        engine.register(name, Table.from_pydict(cols), cols)
