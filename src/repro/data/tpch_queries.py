"""The 22 TPC-H queries as Substrait-like plan trees, plus SQL-text versions.

In the paper, DuckDB/Doris parse + optimize SQL and hand Sirius a Substrait
plan; these builders stand in for that optimizer output (decorrelated
subqueries, pushed-down filters, join orders chosen by the FK graph — the
same rewrites DuckDB performs before emitting Substrait).  ``SQL_QUERIES``
holds SQL text for the queries inside the frontend's subset; the frontend +
rule-based optimizer (repro.sql / repro.optimizer) must reproduce these
hand-built plans' results row-for-row — the builders are the oracle for the
frontend, and the numpy engine is the oracle for the builders.

Determinism note: where the spec's ORDER BY admits ties, we append
tie-breaking keys so the accelerator engine, the numpy fallback oracle and
the distributed engine agree row-for-row (documented deviation; affects
ordering only, never the result set).
"""
from __future__ import annotations

import numpy as np

from ..core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, Rel, ScalarSubquery, SortRel,
)
from ..relational.aggregate import AggSpec
from ..relational.expressions import (
    Between, Case, Col as C, DateLit as D, ExtractYear, InList, Like, Lit as L,
    Substr,
)
from ..relational.sort import SortKey as K


def _month_add(date: str, months: int) -> str:
    d = np.datetime64(date, "M") + np.timedelta64(months, "M")
    day = str(np.datetime64(date, "D"))[8:]
    return f"{d}-{day}"


def _sum(e, name):
    return AggSpec("sum", e, name)


def _rev():
    return C("l_extendedprice") * (L(1.0) - C("l_discount"))


# ---------------------------------------------------------------------------


def q1() -> Rel:
    scan = ReadRel("lineitem", filter=C("l_shipdate") <= D("1998-09-02"))
    agg = AggregateRel(scan, ["l_returnflag", "l_linestatus"], [
        _sum(C("l_quantity"), "sum_qty"),
        _sum(C("l_extendedprice"), "sum_base_price"),
        _sum(_rev(), "sum_disc_price"),
        _sum(_rev() * (L(1.0) + C("l_tax")), "sum_charge"),
        AggSpec("avg", C("l_quantity"), "avg_qty"),
        AggSpec("avg", C("l_extendedprice"), "avg_price"),
        AggSpec("avg", C("l_discount"), "avg_disc"),
        AggSpec("count_star", None, "count_order"),
    ])
    return SortRel(agg, [K("l_returnflag"), K("l_linestatus")])


def _europe_supplier_ps() -> Rel:
    region = ReadRel("region", ["r_regionkey"], filter=C("r_name") == L("EUROPE"))
    nation = JoinRel(ReadRel("nation", ["n_nationkey", "n_name", "n_regionkey"]),
                     region, ["n_regionkey"], ["r_regionkey"], "semi")
    supp = JoinRel(ReadRel("supplier"), nation,
                   ["s_nationkey"], ["n_nationkey"], "inner")
    return JoinRel(ReadRel("partsupp",
                           ["ps_partkey", "ps_suppkey", "ps_supplycost"]),
                   supp, ["ps_suppkey"], ["s_suppkey"], "inner")


def q2() -> Rel:
    mincost = AggregateRel(_europe_supplier_ps(), ["ps_partkey"],
                           [AggSpec("min", C("ps_supplycost"), "min_cost")])
    mincost = ProjectRel(mincost, [("mc_partkey", C("ps_partkey")),
                                   ("min_cost", C("min_cost"))])
    part = ReadRel("part", ["p_partkey", "p_mfgr", "p_size", "p_type"],
                   filter=(C("p_size") == L(15)) & Like(C("p_type"), "%BRASS"))
    j = JoinRel(_europe_supplier_ps(), part,
                ["ps_partkey"], ["p_partkey"], "inner")
    j = JoinRel(j, mincost, ["ps_partkey", "ps_supplycost"],
                ["mc_partkey", "min_cost"], "semi")
    proj = ProjectRel(j, [
        ("s_acctbal", C("s_acctbal")), ("s_name", C("s_name")),
        ("n_name", C("n_name")), ("p_partkey", C("ps_partkey")),
        ("p_mfgr", C("p_mfgr")), ("s_address", C("s_address")),
        ("s_phone", C("s_phone")), ("s_comment", C("s_comment"))])
    return SortRel(proj, [K("s_acctbal", False), K("n_name"), K("s_name"),
                          K("p_partkey")], limit=100)


def q3() -> Rel:
    cust = ReadRel("customer", ["c_custkey"],
                   filter=C("c_mktsegment") == L("BUILDING"))
    orders = JoinRel(
        ReadRel("orders", ["o_orderkey", "o_custkey", "o_orderdate",
                           "o_shippriority"],
                filter=C("o_orderdate") < D("1995-03-15")),
        cust, ["o_custkey"], ["c_custkey"], "semi")
    li = ReadRel("lineitem", ["l_orderkey", "l_extendedprice", "l_discount"],
                 filter=C("l_shipdate") > D("1995-03-15"))
    j = JoinRel(li, orders, ["l_orderkey"], ["o_orderkey"], "inner")
    agg = AggregateRel(j, ["l_orderkey", "o_orderdate", "o_shippriority"],
                       [_sum(_rev(), "revenue")])
    return SortRel(agg, [K("revenue", False), K("o_orderdate"),
                         K("l_orderkey")], limit=10)


def q4() -> Rel:
    li = ReadRel("lineitem", ["l_orderkey"],
                 filter=C("l_commitdate") < C("l_receiptdate"))
    orders = ReadRel("orders", ["o_orderkey", "o_orderpriority"],
                     filter=(C("o_orderdate") >= D("1993-07-01"))
                     & (C("o_orderdate") < D(_month_add("1993-07-01", 3))))
    j = JoinRel(orders, li, ["o_orderkey"], ["l_orderkey"], "semi")
    agg = AggregateRel(j, ["o_orderpriority"],
                       [AggSpec("count_star", None, "order_count")])
    return SortRel(agg, [K("o_orderpriority")])


def q5() -> Rel:
    region = ReadRel("region", ["r_regionkey"], filter=C("r_name") == L("ASIA"))
    nation = JoinRel(ReadRel("nation", ["n_nationkey", "n_name", "n_regionkey"]),
                     region, ["n_regionkey"], ["r_regionkey"], "semi")
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_nationkey"]), nation,
                   ["s_nationkey"], ["n_nationkey"], "inner")
    orders = JoinRel(
        ReadRel("orders", ["o_orderkey", "o_custkey"],
                filter=(C("o_orderdate") >= D("1994-01-01"))
                & (C("o_orderdate") < D("1995-01-01"))),
        ReadRel("customer", ["c_custkey", "c_nationkey"]),
        ["o_custkey"], ["c_custkey"], "inner")
    li = JoinRel(ReadRel("lineitem", ["l_orderkey", "l_suppkey",
                                      "l_extendedprice", "l_discount"]),
                 orders, ["l_orderkey"], ["o_orderkey"], "inner")
    j = JoinRel(li, supp, ["l_suppkey", "c_nationkey"],
                ["s_suppkey", "s_nationkey"], "inner")
    agg = AggregateRel(j, ["n_name"], [_sum(_rev(), "revenue")])
    return SortRel(agg, [K("revenue", False)])


def q6() -> Rel:
    li = ReadRel("lineitem", filter=(
        (C("l_shipdate") >= D("1994-01-01")) & (C("l_shipdate") < D("1995-01-01"))
        & Between(C("l_discount"), L(0.05), L(0.07)) & (C("l_quantity") < L(24.0))))
    return AggregateRel(li, [], [_sum(C("l_extendedprice") * C("l_discount"),
                                      "revenue")])


def q7() -> Rel:
    nations = InList(C("n_name"), ["FRANCE", "GERMANY"])
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_nationkey"]),
                   ProjectRel(ReadRel("nation", filter=nations),
                              [("n_nationkey", C("n_nationkey")),
                               ("supp_nation", C("n_name"))]),
                   ["s_nationkey"], ["n_nationkey"], "inner")
    cust = JoinRel(ReadRel("customer", ["c_custkey", "c_nationkey"]),
                   ProjectRel(ReadRel("nation", filter=nations),
                              [("n2_nationkey", C("n_nationkey")),
                               ("cust_nation", C("n_name"))]),
                   ["c_nationkey"], ["n2_nationkey"], "inner")
    orders = JoinRel(ReadRel("orders", ["o_orderkey", "o_custkey"]),
                     cust, ["o_custkey"], ["c_custkey"], "inner")
    li = ReadRel("lineitem",
                 ["l_orderkey", "l_suppkey", "l_extendedprice", "l_discount",
                  "l_shipdate"],
                 filter=Between(C("l_shipdate"), D("1995-01-01"), D("1996-12-31")))
    j = JoinRel(li, orders, ["l_orderkey"], ["o_orderkey"], "inner")
    j = JoinRel(j, supp, ["l_suppkey"], ["s_suppkey"], "inner",
                post_filter=(
                    ((C("supp_nation") == L("FRANCE"))
                     & (C("cust_nation") == L("GERMANY")))
                    | ((C("supp_nation") == L("GERMANY"))
                       & (C("cust_nation") == L("FRANCE")))))
    proj = ProjectRel(j, [("supp_nation", C("supp_nation")),
                          ("cust_nation", C("cust_nation")),
                          ("l_year", ExtractYear(C("l_shipdate"))),
                          ("volume", _rev())])
    agg = AggregateRel(proj, ["supp_nation", "cust_nation", "l_year"],
                       [_sum(C("volume"), "revenue")])
    return SortRel(agg, [K("supp_nation"), K("cust_nation"), K("l_year")])


def q8() -> Rel:
    part = ReadRel("part", ["p_partkey"],
                   filter=C("p_type") == L("ECONOMY ANODIZED STEEL"))
    li = JoinRel(ReadRel("lineitem", ["l_orderkey", "l_partkey", "l_suppkey",
                                      "l_extendedprice", "l_discount"]),
                 part, ["l_partkey"], ["p_partkey"], "semi")
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_nationkey"]),
                   ProjectRel(ReadRel("nation"),
                              [("sn_key", C("n_nationkey")),
                               ("n2_name", C("n_name"))]),
                   ["s_nationkey"], ["sn_key"], "inner")
    li = JoinRel(li, supp, ["l_suppkey"], ["s_suppkey"], "inner")
    orders = ReadRel("orders", ["o_orderkey", "o_custkey", "o_orderdate"],
                     filter=Between(C("o_orderdate"), D("1995-01-01"),
                                    D("1996-12-31")))
    j = JoinRel(li, orders, ["l_orderkey"], ["o_orderkey"], "inner")
    region = ReadRel("region", ["r_regionkey"], filter=C("r_name") == L("AMERICA"))
    nat1 = JoinRel(ReadRel("nation", ["n_nationkey", "n_regionkey"]), region,
                   ["n_regionkey"], ["r_regionkey"], "semi")
    cust = JoinRel(ReadRel("customer", ["c_custkey", "c_nationkey"]), nat1,
                   ["c_nationkey"], ["n_nationkey"], "semi")
    j = JoinRel(j, cust, ["o_custkey"], ["c_custkey"], "semi")
    proj = ProjectRel(j, [
        ("o_year", ExtractYear(C("o_orderdate"))),
        ("volume", _rev()),
        ("brazil_volume", Case([(C("n2_name") == L("BRAZIL"), _rev())], L(0.0)))])
    agg = AggregateRel(proj, ["o_year"], [
        _sum(C("brazil_volume"), "num"), _sum(C("volume"), "den")])
    share = ProjectRel(agg, [("o_year", C("o_year")),
                             ("mkt_share", C("num") / C("den"))])
    return SortRel(share, [K("o_year")])


def q9() -> Rel:
    part = ReadRel("part", ["p_partkey"], filter=Like(C("p_name"), "%green%"))
    li = JoinRel(ReadRel("lineitem", ["l_orderkey", "l_partkey", "l_suppkey",
                                      "l_quantity", "l_extendedprice",
                                      "l_discount"]),
                 part, ["l_partkey"], ["p_partkey"], "semi")
    li = JoinRel(li, ReadRel("partsupp",
                             ["ps_partkey", "ps_suppkey", "ps_supplycost"]),
                 ["l_partkey", "l_suppkey"], ["ps_partkey", "ps_suppkey"],
                 "inner")
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_nationkey"]),
                   ReadRel("nation", ["n_nationkey", "n_name"]),
                   ["s_nationkey"], ["n_nationkey"], "inner")
    li = JoinRel(li, supp, ["l_suppkey"], ["s_suppkey"], "inner")
    j = JoinRel(li, ReadRel("orders", ["o_orderkey", "o_orderdate"]),
                ["l_orderkey"], ["o_orderkey"], "inner")
    proj = ProjectRel(j, [
        ("nation", C("n_name")),
        ("o_year", ExtractYear(C("o_orderdate"))),
        ("amount", _rev() - C("ps_supplycost") * C("l_quantity"))])
    agg = AggregateRel(proj, ["nation", "o_year"],
                       [_sum(C("amount"), "sum_profit")])
    return SortRel(agg, [K("nation"), K("o_year", False)])


def q10() -> Rel:
    orders = ReadRel("orders", ["o_orderkey", "o_custkey"],
                     filter=(C("o_orderdate") >= D("1993-10-01"))
                     & (C("o_orderdate") < D(_month_add("1993-10-01", 3))))
    li = ReadRel("lineitem", ["l_orderkey", "l_extendedprice", "l_discount"],
                 filter=C("l_returnflag") == L("R"))
    j = JoinRel(li, orders, ["l_orderkey"], ["o_orderkey"], "inner")
    j = JoinRel(j, ReadRel("customer"), ["o_custkey"], ["c_custkey"], "inner")
    j = JoinRel(j, ReadRel("nation", ["n_nationkey", "n_name"]),
                ["c_nationkey"], ["n_nationkey"], "inner")
    agg = AggregateRel(j, ["c_custkey", "c_name", "c_acctbal", "c_phone",
                           "n_name", "c_address", "c_comment"],
                       [_sum(_rev(), "revenue")])
    return SortRel(agg, [K("revenue", False), K("c_custkey")], limit=20)


def _q11_value_by_part() -> Rel:
    nation = ReadRel("nation", ["n_nationkey"],
                     filter=C("n_name") == L("GERMANY"))
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_nationkey"]), nation,
                   ["s_nationkey"], ["n_nationkey"], "semi")
    ps = JoinRel(ReadRel("partsupp", ["ps_partkey", "ps_suppkey",
                                      "ps_supplycost", "ps_availqty"]),
                 supp, ["ps_suppkey"], ["s_suppkey"], "semi")
    return ps


def q11() -> Rel:
    value = C("ps_supplycost") * C("ps_availqty")
    total = ScalarSubquery(
        AggregateRel(_q11_value_by_part(), [], [_sum(value, "t")]), "t")
    agg = AggregateRel(_q11_value_by_part(), ["ps_partkey"],
                       [_sum(value, "value")],
                       having=C("value") > total * L(0.0001))
    return SortRel(agg, [K("value", False), K("ps_partkey")])


def q12() -> Rel:
    li = ReadRel("lineitem", ["l_orderkey", "l_shipmode"],
                 filter=(InList(C("l_shipmode"), ["MAIL", "SHIP"])
                         & (C("l_commitdate") < C("l_receiptdate"))
                         & (C("l_shipdate") < C("l_commitdate"))
                         & (C("l_receiptdate") >= D("1994-01-01"))
                         & (C("l_receiptdate") < D("1995-01-01"))))
    j = JoinRel(li, ReadRel("orders", ["o_orderkey", "o_orderpriority"]),
                ["l_orderkey"], ["o_orderkey"], "inner")
    high = InList(C("o_orderpriority"), ["1-URGENT", "2-HIGH"])
    agg = AggregateRel(j, ["l_shipmode"], [
        _sum(Case([(high, L(1))], L(0)), "high_line_count"),
        _sum(Case([(high, L(0))], L(1)), "low_line_count")])
    return SortRel(agg, [K("l_shipmode")])


def q13() -> Rel:
    orders = ReadRel("orders", ["o_orderkey", "o_custkey"],
                     filter=Like(C("o_comment"), "%special%requests%",
                                 negate=True))
    j = JoinRel(ReadRel("customer", ["c_custkey"]), orders,
                ["c_custkey"], ["o_custkey"], "left")
    per_cust = AggregateRel(j, ["c_custkey"], [
        _sum(Case([(C("__matched"), L(1))], L(0)), "c_count")])
    dist = AggregateRel(per_cust, ["c_count"],
                        [AggSpec("count_star", None, "custdist")])
    return SortRel(dist, [K("custdist", False), K("c_count", False)])


def q14() -> Rel:
    li = ReadRel("lineitem", ["l_partkey", "l_extendedprice", "l_discount"],
                 filter=(C("l_shipdate") >= D("1995-09-01"))
                 & (C("l_shipdate") < D(_month_add("1995-09-01", 1))))
    j = JoinRel(li, ReadRel("part", ["p_partkey", "p_type"]),
                ["l_partkey"], ["p_partkey"], "inner")
    agg = AggregateRel(j, [], [
        _sum(Case([(Like(C("p_type"), "PROMO%"), _rev())], L(0.0)), "promo"),
        _sum(_rev(), "total")])
    return ProjectRel(agg, [("promo_revenue",
                             L(100.0) * C("promo") / C("total"))])


def _q15_revenue() -> Rel:
    li = ReadRel("lineitem", ["l_suppkey", "l_extendedprice", "l_discount"],
                 filter=(C("l_shipdate") >= D("1996-01-01"))
                 & (C("l_shipdate") < D(_month_add("1996-01-01", 3))))
    return AggregateRel(li, ["l_suppkey"], [_sum(_rev(), "total_revenue")])


def q15() -> Rel:
    best = ScalarSubquery(AggregateRel(_q15_revenue(), [], [
        AggSpec("max", C("total_revenue"), "m")]), "m")
    j = JoinRel(ReadRel("supplier", ["s_suppkey", "s_name", "s_address",
                                     "s_phone"]),
                _q15_revenue(), ["s_suppkey"], ["l_suppkey"], "inner")
    f = FilterRel(j, C("total_revenue") >= best)
    proj = ProjectRel(f, [
        ("s_suppkey", C("s_suppkey")), ("s_name", C("s_name")),
        ("s_address", C("s_address")), ("s_phone", C("s_phone")),
        ("total_revenue", C("total_revenue"))])
    return SortRel(proj, [K("s_suppkey")])


def q16() -> Rel:
    part = ReadRel("part", ["p_partkey", "p_brand", "p_type", "p_size"],
                   filter=((C("p_brand") != L("Brand#45"))
                           & Like(C("p_type"), "MEDIUM POLISHED%", negate=True)
                           & InList(C("p_size"), [49, 14, 23, 45, 19, 3, 36, 9])))
    ps = JoinRel(ReadRel("partsupp", ["ps_partkey", "ps_suppkey"]), part,
                 ["ps_partkey"], ["p_partkey"], "inner")
    bad_supp = ReadRel("supplier", ["s_suppkey"],
                       filter=Like(C("s_comment"), "%Customer%Complaints%"))
    ps = JoinRel(ps, bad_supp, ["ps_suppkey"], ["s_suppkey"], "anti")
    agg = AggregateRel(ps, ["p_brand", "p_type", "p_size"],
                       [AggSpec("count_distinct", C("ps_suppkey"),
                                "supplier_cnt")])
    return SortRel(agg, [K("supplier_cnt", False), K("p_brand"), K("p_type"),
                         K("p_size")])


def q17() -> Rel:
    part = ReadRel("part", ["p_partkey"],
                   filter=(C("p_brand") == L("Brand#23"))
                   & (C("p_container") == L("MED BOX")))
    li = JoinRel(ReadRel("lineitem", ["l_partkey", "l_quantity",
                                      "l_extendedprice"]),
                 part, ["l_partkey"], ["p_partkey"], "semi")
    avg_qty = AggregateRel(ReadRel("lineitem", ["l_partkey", "l_quantity"]),
                           ["l_partkey"],
                           [AggSpec("avg", C("l_quantity"), "avg_qty")])
    avg_qty = ProjectRel(avg_qty, [("ap_partkey", C("l_partkey")),
                                   ("avg_qty", C("avg_qty"))])
    j = JoinRel(li, avg_qty, ["l_partkey"], ["ap_partkey"], "inner",
                post_filter=C("l_quantity") < L(0.2) * C("avg_qty"))
    agg = AggregateRel(j, [], [_sum(C("l_extendedprice"), "s")])
    return ProjectRel(agg, [("avg_yearly", C("s") / L(7.0))])


def q18() -> Rel:
    big = AggregateRel(ReadRel("lineitem", ["l_orderkey", "l_quantity"]),
                       ["l_orderkey"], [_sum(C("l_quantity"), "sq")],
                       having=C("sq") > L(300.0))
    big = ProjectRel(big, [("big_okey", C("l_orderkey"))])
    orders = JoinRel(ReadRel("orders", ["o_orderkey", "o_custkey",
                                        "o_orderdate", "o_totalprice"]),
                     big, ["o_orderkey"], ["big_okey"], "semi")
    j = JoinRel(orders, ReadRel("customer", ["c_custkey", "c_name"]),
                ["o_custkey"], ["c_custkey"], "inner")
    li = JoinRel(ReadRel("lineitem", ["l_orderkey", "l_quantity"]), j,
                 ["l_orderkey"], ["o_orderkey"], "inner")
    agg = AggregateRel(li, ["c_name", "c_custkey", "o_orderkey", "o_orderdate",
                            "o_totalprice"], [_sum(C("l_quantity"), "sum_qty")])
    return SortRel(agg, [K("o_totalprice", False), K("o_orderdate"),
                         K("o_orderkey")], limit=100)


def q19() -> Rel:
    li = ReadRel("lineitem", ["l_partkey", "l_quantity", "l_extendedprice",
                              "l_discount"],
                 filter=(InList(C("l_shipmode"), ["AIR", "AIR REG"])
                         & (C("l_shipinstruct") == L("DELIVER IN PERSON"))))
    part = ReadRel("part", ["p_partkey", "p_brand", "p_container", "p_size"])
    cond1 = ((C("p_brand") == L("Brand#12"))
             & InList(C("p_container"), ["SM CASE", "SM BOX", "SM PACK", "SM PKG"])
             & Between(C("l_quantity"), L(1.0), L(11.0))
             & Between(C("p_size"), L(1), L(5)))
    cond2 = ((C("p_brand") == L("Brand#23"))
             & InList(C("p_container"), ["MED BAG", "MED BOX", "MED PKG", "MED PACK"])
             & Between(C("l_quantity"), L(10.0), L(20.0))
             & Between(C("p_size"), L(1), L(10)))
    cond3 = ((C("p_brand") == L("Brand#34"))
             & InList(C("p_container"), ["LG CASE", "LG BOX", "LG PACK", "LG PKG"])
             & Between(C("l_quantity"), L(20.0), L(30.0))
             & Between(C("p_size"), L(1), L(15)))
    j = JoinRel(li, part, ["l_partkey"], ["p_partkey"], "inner",
                post_filter=cond1 | cond2 | cond3)
    return AggregateRel(j, [], [_sum(_rev(), "revenue")])


def q20() -> Rel:
    forest = ReadRel("part", ["p_partkey"], filter=Like(C("p_name"), "forest%"))
    shipped = AggregateRel(
        ReadRel("lineitem", ["l_partkey", "l_suppkey", "l_quantity"],
                filter=(C("l_shipdate") >= D("1994-01-01"))
                & (C("l_shipdate") < D("1995-01-01"))),
        ["l_partkey", "l_suppkey"], [_sum(C("l_quantity"), "sum_qty")])
    ps = JoinRel(ReadRel("partsupp", ["ps_partkey", "ps_suppkey",
                                      "ps_availqty"]),
                 forest, ["ps_partkey"], ["p_partkey"], "semi")
    ps = JoinRel(ps, shipped, ["ps_partkey", "ps_suppkey"],
                 ["l_partkey", "l_suppkey"], "inner",
                 post_filter=C("ps_availqty") > L(0.5) * C("sum_qty"))
    ps = ProjectRel(ps, [("avail_supp", C("ps_suppkey"))])
    nation = ReadRel("nation", ["n_nationkey"], filter=C("n_name") == L("CANADA"))
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_name", "s_address",
                                        "s_nationkey"]),
                   nation, ["s_nationkey"], ["n_nationkey"], "semi")
    supp = JoinRel(supp, ps, ["s_suppkey"], ["avail_supp"], "semi")
    return SortRel(ProjectRel(supp, [("s_name", C("s_name")),
                                     ("s_address", C("s_address"))]),
                   [K("s_name")])


def q21() -> Rel:
    late = ReadRel("lineitem", ["l_orderkey", "l_suppkey"],
                   filter=C("l_receiptdate") > C("l_commitdate"))
    n_all = AggregateRel(ReadRel("lineitem", ["l_orderkey", "l_suppkey"]),
                         ["l_orderkey"],
                         [AggSpec("count_distinct", C("l_suppkey"), "n_all")],
                         having=C("n_all") > L(1))
    n_all = ProjectRel(n_all, [("na_okey", C("l_orderkey"))])
    n_late = AggregateRel(late, ["l_orderkey"],
                          [AggSpec("count_distinct", C("l_suppkey"), "n_late")],
                          having=C("n_late") == L(1))
    n_late = ProjectRel(n_late, [("nl_okey", C("l_orderkey"))])
    nation = ReadRel("nation", ["n_nationkey"],
                     filter=C("n_name") == L("SAUDI ARABIA"))
    supp = JoinRel(ReadRel("supplier", ["s_suppkey", "s_name", "s_nationkey"]),
                   nation, ["s_nationkey"], ["n_nationkey"], "semi")
    orders_f = ReadRel("orders", ["o_orderkey"],
                       filter=C("o_orderstatus") == L("F"))
    j = JoinRel(late, supp, ["l_suppkey"], ["s_suppkey"], "inner")
    j = JoinRel(j, orders_f, ["l_orderkey"], ["o_orderkey"], "semi")
    j = JoinRel(j, n_all, ["l_orderkey"], ["na_okey"], "semi")
    j = JoinRel(j, n_late, ["l_orderkey"], ["nl_okey"], "semi")
    agg = AggregateRel(j, ["s_name"], [AggSpec("count_star", None, "numwait")])
    return SortRel(agg, [K("numwait", False), K("s_name")], limit=100)


def q22() -> Rel:
    codes = ["13", "31", "23", "29", "30", "18", "17"]
    in_codes = InList(Substr(C("c_phone"), 1, 2), codes)
    avg_bal = ScalarSubquery(
        AggregateRel(ReadRel("customer", ["c_acctbal", "c_phone"],
                             filter=(C("c_acctbal") > L(0.0)) & in_codes),
                     [], [AggSpec("avg", C("c_acctbal"), "a")]), "a")
    cust = ReadRel("customer", ["c_custkey", "c_phone", "c_acctbal"],
                   filter=in_codes)
    cust = FilterRel(cust, C("c_acctbal") > avg_bal)
    cust = JoinRel(cust, ReadRel("orders", ["o_custkey"]),
                   ["c_custkey"], ["o_custkey"], "anti")
    proj = ProjectRel(cust, [("cntrycode", Substr(C("c_phone"), 1, 2)),
                             ("c_acctbal", C("c_acctbal"))])
    agg = AggregateRel(proj, ["cntrycode"],
                       [AggSpec("count_star", None, "numcust"),
                        _sum(C("c_acctbal"), "totacctbal")])
    return SortRel(agg, [K("cntrycode")])


QUERIES = {i: fn for i, fn in enumerate(
    [q1, q2, q3, q4, q5, q6, q7, q8, q9, q10, q11, q12, q13, q14, q15, q16,
     q17, q18, q19, q20, q21, q22], start=1)}


# ---------------------------------------------------------------------------
# SQL-text versions (the paper's *actual* input format) — all 22 queries.
#
# These feed the SQL frontend (repro.sql) + rule-based optimizer
# (repro.optimizer) and are validated row-for-row against the hand-built
# plans above.  Textual deviations from the TPC-H spec, all semantics- or
# determinism-preserving:
#   * the tie-breaking ORDER BY keys the hand-built plans add (Q3/Q10/Q11/
#     Q18) appear in the text too, so row order is engine-independent;
#   * Q19 uses the standard factored form (shipmode/shipinstruct conjuncts
#     hoisted out of the OR) — equivalent, and it exercises join-level
#     residual (post_filter) placement;
#   * Q11's HAVING threshold multiplies inside the scalar subquery instead
#     of outside — same arithmetic;
#   * Q22 groups by the substring expression directly rather than through a
#     derived table (the expression-valued group key is the engine's native
#     shape);
#   * Q7/Q8/Q9 inline the spec's derived-table column list as select-item
#     aliases and Q15 inlines the spec's revenue *view* as a derived table —
#     same plans after lowering;
#   * Q21 replaces the spec's lineitem self-joins (exists l2 / not exists
#     l3) with the equivalent per-order distinct-supplier-count subqueries
#     the hand-built plan uses: >1 distinct suppliers overall and exactly 1
#     distinct late supplier — the rewrite DuckDB's flattening produces;
#   * Q15 compares total_revenue with = (spec) where the hand-built plan
#     uses >= against the max — identical row sets by definition of max.
# ---------------------------------------------------------------------------

SQL_QUERIES = {
    1: """
select l_returnflag, l_linestatus,
       sum(l_quantity) as sum_qty,
       sum(l_extendedprice) as sum_base_price,
       sum(l_extendedprice * (1 - l_discount)) as sum_disc_price,
       sum(l_extendedprice * (1 - l_discount) * (1 + l_tax)) as sum_charge,
       avg(l_quantity) as avg_qty,
       avg(l_extendedprice) as avg_price,
       avg(l_discount) as avg_disc,
       count(*) as count_order
from lineitem
where l_shipdate <= date '1998-09-02'
group by l_returnflag, l_linestatus
order by l_returnflag, l_linestatus
""",
    2: """
select s_acctbal, s_name, n_name, p_partkey, p_mfgr, s_address, s_phone,
       s_comment
from part, supplier, partsupp, nation, region
where p_partkey = ps_partkey
  and s_suppkey = ps_suppkey
  and p_size = 15
  and p_type like '%BRASS'
  and s_nationkey = n_nationkey
  and n_regionkey = r_regionkey
  and r_name = 'EUROPE'
  and ps_supplycost = (select min(ps_supplycost)
                       from partsupp, supplier, nation, region
                       where p_partkey = ps_partkey
                         and s_suppkey = ps_suppkey
                         and s_nationkey = n_nationkey
                         and n_regionkey = r_regionkey
                         and r_name = 'EUROPE')
order by s_acctbal desc, n_name, s_name, p_partkey
limit 100
""",
    3: """
select l_orderkey, sum(l_extendedprice * (1 - l_discount)) as revenue,
       o_orderdate, o_shippriority
from customer, orders, lineitem
where c_mktsegment = 'BUILDING'
  and c_custkey = o_custkey
  and l_orderkey = o_orderkey
  and o_orderdate < date '1995-03-15'
  and l_shipdate > date '1995-03-15'
group by l_orderkey, o_orderdate, o_shippriority
order by revenue desc, o_orderdate, l_orderkey
limit 10
""",
    4: """
select o_orderpriority, count(*) as order_count
from orders
where o_orderdate >= date '1993-07-01'
  and o_orderdate < date '1993-07-01' + interval '3' month
  and exists (select * from lineitem
              where l_orderkey = o_orderkey
                and l_commitdate < l_receiptdate)
group by o_orderpriority
order by o_orderpriority
""",
    5: """
select n_name, sum(l_extendedprice * (1 - l_discount)) as revenue
from customer, orders, lineitem, supplier, nation, region
where c_custkey = o_custkey
  and l_orderkey = o_orderkey
  and l_suppkey = s_suppkey
  and c_nationkey = s_nationkey
  and s_nationkey = n_nationkey
  and n_regionkey = r_regionkey
  and r_name = 'ASIA'
  and o_orderdate >= date '1994-01-01'
  and o_orderdate < date '1995-01-01'
group by n_name
order by revenue desc
""",
    6: """
select sum(l_extendedprice * l_discount) as revenue
from lineitem
where l_shipdate >= date '1994-01-01'
  and l_shipdate < date '1995-01-01'
  and l_discount between 0.05 and 0.07
  and l_quantity < 24
""",
    7: """
select supp_nation, cust_nation, l_year, sum(volume) as revenue
from (select n1.n_name as supp_nation, n2.n_name as cust_nation,
             extract(year from l_shipdate) as l_year,
             l_extendedprice * (1 - l_discount) as volume
      from supplier, lineitem, orders, customer, nation n1, nation n2
      where s_suppkey = l_suppkey
        and o_orderkey = l_orderkey
        and c_custkey = o_custkey
        and s_nationkey = n1.n_nationkey
        and c_nationkey = n2.n_nationkey
        and ((n1.n_name = 'FRANCE' and n2.n_name = 'GERMANY')
          or (n1.n_name = 'GERMANY' and n2.n_name = 'FRANCE'))
        and l_shipdate between date '1995-01-01' and date '1996-12-31')
     as shipping
group by supp_nation, cust_nation, l_year
order by supp_nation, cust_nation, l_year
""",
    8: """
select o_year,
       sum(case when nation = 'BRAZIL' then volume else 0 end)
       / sum(volume) as mkt_share
from (select extract(year from o_orderdate) as o_year,
             l_extendedprice * (1 - l_discount) as volume,
             n2.n_name as nation
      from part, supplier, lineitem, orders, customer, nation n1,
           nation n2, region
      where p_partkey = l_partkey
        and s_suppkey = l_suppkey
        and l_orderkey = o_orderkey
        and o_custkey = c_custkey
        and c_nationkey = n1.n_nationkey
        and n1.n_regionkey = r_regionkey
        and r_name = 'AMERICA'
        and s_nationkey = n2.n_nationkey
        and o_orderdate between date '1995-01-01' and date '1996-12-31'
        and p_type = 'ECONOMY ANODIZED STEEL') as all_nations
group by o_year
order by o_year
""",
    9: """
select nation, o_year, sum(amount) as sum_profit
from (select n_name as nation,
             extract(year from o_orderdate) as o_year,
             l_extendedprice * (1 - l_discount)
               - ps_supplycost * l_quantity as amount
      from part, supplier, lineitem, partsupp, orders, nation
      where s_suppkey = l_suppkey
        and ps_suppkey = l_suppkey
        and ps_partkey = l_partkey
        and p_partkey = l_partkey
        and o_orderkey = l_orderkey
        and s_nationkey = n_nationkey
        and p_name like '%green%') as profit
group by nation, o_year
order by nation, o_year desc
""",
    10: """
select c_custkey, c_name, sum(l_extendedprice * (1 - l_discount)) as revenue,
       c_acctbal, n_name, c_address, c_phone, c_comment
from customer, orders, lineitem, nation
where c_custkey = o_custkey
  and l_orderkey = o_orderkey
  and o_orderdate >= date '1993-10-01'
  and o_orderdate < date '1993-10-01' + interval '3' month
  and l_returnflag = 'R'
  and c_nationkey = n_nationkey
group by c_custkey, c_name, c_acctbal, c_phone, n_name, c_address, c_comment
order by revenue desc, c_custkey
limit 20
""",
    11: """
select ps_partkey, sum(ps_supplycost * ps_availqty) as value
from partsupp, supplier, nation
where ps_suppkey = s_suppkey
  and s_nationkey = n_nationkey
  and n_name = 'GERMANY'
group by ps_partkey
having sum(ps_supplycost * ps_availqty) >
       (select sum(ps_supplycost * ps_availqty) * 0.0001
        from partsupp, supplier, nation
        where ps_suppkey = s_suppkey
          and s_nationkey = n_nationkey
          and n_name = 'GERMANY')
order by value desc, ps_partkey
""",
    12: """
select l_shipmode,
       sum(case when o_orderpriority = '1-URGENT'
                  or o_orderpriority = '2-HIGH' then 1 else 0 end)
           as high_line_count,
       sum(case when o_orderpriority <> '1-URGENT'
                 and o_orderpriority <> '2-HIGH' then 1 else 0 end)
           as low_line_count
from orders, lineitem
where o_orderkey = l_orderkey
  and l_shipmode in ('MAIL', 'SHIP')
  and l_commitdate < l_receiptdate
  and l_shipdate < l_commitdate
  and l_receiptdate >= date '1994-01-01'
  and l_receiptdate < date '1995-01-01'
group by l_shipmode
order by l_shipmode
""",
    13: """
select c_count, count(*) as custdist
from (select c_custkey, count(o_orderkey) as c_count
      from customer left outer join orders
        on c_custkey = o_custkey
       and o_comment not like '%special%requests%'
      group by c_custkey) as c_orders
group by c_count
order by custdist desc, c_count desc
""",
    14: """
select 100.00 * sum(case when p_type like 'PROMO%'
                         then l_extendedprice * (1 - l_discount)
                         else 0 end)
       / sum(l_extendedprice * (1 - l_discount)) as promo_revenue
from lineitem, part
where l_partkey = p_partkey
  and l_shipdate >= date '1995-09-01'
  and l_shipdate < date '1995-10-01'
""",
    15: """
select s_suppkey, s_name, s_address, s_phone, total_revenue
from supplier,
     (select l_suppkey, sum(l_extendedprice * (1 - l_discount))
          as total_revenue
      from lineitem
      where l_shipdate >= date '1996-01-01'
        and l_shipdate < date '1996-04-01'
      group by l_suppkey) as revenue0
where s_suppkey = l_suppkey
  and total_revenue = (select max(total_revenue)
                       from (select l_suppkey,
                                    sum(l_extendedprice * (1 - l_discount))
                                        as total_revenue
                             from lineitem
                             where l_shipdate >= date '1996-01-01'
                               and l_shipdate < date '1996-04-01'
                             group by l_suppkey) as revenue1)
order by s_suppkey
""",
    16: """
select p_brand, p_type, p_size, count(distinct ps_suppkey) as supplier_cnt
from partsupp, part
where p_partkey = ps_partkey
  and p_brand <> 'Brand#45'
  and p_type not like 'MEDIUM POLISHED%'
  and p_size in (49, 14, 23, 45, 19, 3, 36, 9)
  and ps_suppkey not in (select s_suppkey from supplier
                         where s_comment like '%Customer%Complaints%')
group by p_brand, p_type, p_size
order by supplier_cnt desc, p_brand, p_type, p_size
""",
    17: """
select sum(l_extendedprice) / 7.0 as avg_yearly
from lineitem, part
where p_partkey = l_partkey
  and p_brand = 'Brand#23'
  and p_container = 'MED BOX'
  and l_quantity < (select 0.2 * avg(l_quantity)
                    from lineitem
                    where l_partkey = p_partkey)
""",
    18: """
select c_name, c_custkey, o_orderkey, o_orderdate, o_totalprice,
       sum(l_quantity) as sum_qty
from customer, orders, lineitem
where o_orderkey in (select l_orderkey from lineitem
                     group by l_orderkey
                     having sum(l_quantity) > 300)
  and c_custkey = o_custkey
  and o_orderkey = l_orderkey
group by c_name, c_custkey, o_orderkey, o_orderdate, o_totalprice
order by o_totalprice desc, o_orderdate, o_orderkey
limit 100
""",
    19: """
select sum(l_extendedprice * (1 - l_discount)) as revenue
from lineitem, part
where l_partkey = p_partkey
  and l_shipmode in ('AIR', 'AIR REG')
  and l_shipinstruct = 'DELIVER IN PERSON'
  and ((p_brand = 'Brand#12'
        and p_container in ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG')
        and l_quantity between 1 and 11
        and p_size between 1 and 5)
    or (p_brand = 'Brand#23'
        and p_container in ('MED BAG', 'MED BOX', 'MED PKG', 'MED PACK')
        and l_quantity between 10 and 20
        and p_size between 1 and 10)
    or (p_brand = 'Brand#34'
        and p_container in ('LG CASE', 'LG BOX', 'LG PACK', 'LG PKG')
        and l_quantity between 20 and 30
        and p_size between 1 and 15))
""",
    20: """
select s_name, s_address
from supplier, nation
where s_suppkey in (select ps_suppkey
                    from partsupp
                    where ps_partkey in (select p_partkey from part
                                         where p_name like 'forest%')
                      and ps_availqty > (select 0.5 * sum(l_quantity)
                                         from lineitem
                                         where l_partkey = ps_partkey
                                           and l_suppkey = ps_suppkey
                                           and l_shipdate >= date '1994-01-01'
                                           and l_shipdate < date '1995-01-01'))
  and s_nationkey = n_nationkey
  and n_name = 'CANADA'
order by s_name
""",
    21: """
select s_name, count(*) as numwait
from lineitem, supplier, nation
where s_suppkey = l_suppkey
  and l_receiptdate > l_commitdate
  and l_orderkey in (select o_orderkey from orders
                     where o_orderstatus = 'F')
  and l_orderkey in (select l_orderkey from lineitem
                     group by l_orderkey
                     having count(distinct l_suppkey) > 1)
  and l_orderkey in (select l_orderkey from lineitem
                     where l_receiptdate > l_commitdate
                     group by l_orderkey
                     having count(distinct l_suppkey) = 1)
  and s_nationkey = n_nationkey
  and n_name = 'SAUDI ARABIA'
group by s_name
order by numwait desc, s_name
limit 100
""",
    22: """
select substring(c_phone, 1, 2) as cntrycode,
       count(*) as numcust,
       sum(c_acctbal) as totacctbal
from customer
where substring(c_phone, 1, 2) in ('13', '31', '23', '29', '30', '18', '17')
  and c_acctbal > (select avg(c_acctbal) from customer
                   where c_acctbal > 0.00
                     and substring(c_phone, 1, 2)
                         in ('13', '31', '23', '29', '30', '18', '17'))
  and not exists (select * from orders where o_custkey = c_custkey)
group by substring(c_phone, 1, 2)
order by cntrycode
""",
}

# the queries on which the optimizer's predicate pushdown provably lands a
# filter in a ReadRel (Q18's only predicates are join keys + an IN subquery)
SQL_PUSHDOWN_QIDS = tuple(q for q in sorted(SQL_QUERIES) if q != 18)
