"""ClickBench workload: hits-table sample generator + query set.

The paper's headline is dual-benchmark — 8.3x cost efficiency on TPC-H and
**7.4x on ClickBench** — so the repro carries both.  ClickBench is a single
denormalized web-analytics table (``hits``, ~100M rows in the official
dataset) probed by scan-heavy queries: top-K group-bys, substring/LIKE URL
filters, and distinct-user counts.  That makes it the acceptance workload
for the device-resident string subsystem: most queries touch a
dictionary-encoded string column in the hot path.

This module generates a **schema-faithful sample**: a representative subset
of the official column list (names and types as in the ClickBench DDL,
lowercased because the SQL frontend lowercases identifiers) with
web-analytics-shaped distributions — zipfian URL/phrase/region popularity,
mostly-empty ``searchphrase``/``mobilephonemodel``, sparse 64-bit user ids,
a two-week event window.  Absolute numbers are synthetic; the *shapes* that
drive the engine (dictionary sizes ≪ row counts, heavy-hitter skew, empty-
string majorities) are faithful.

``CLICKBENCH_QUERIES`` holds SQL text for a representative selection of the
official 43 queries (official numbering; a few marked ``x``-suffixed are
repro additions exercising ``starts_with``/``substring``).  Deviation from
the official text, determinism-preserving: every ORDER BY gets explicit
tie-breaking keys so engine and oracle agree row-for-row.

Output is the host database format: dict[table] -> dict[col] -> np.ndarray.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

HostDB = Dict[str, Dict[str, np.ndarray]]

# official dataset cardinality (for optimizer cost estimates at full scale)
CLICKBENCH_BASE_ROWS = {"hits": 99_997_497}

# Column subset of the official hits DDL (lowercased).  Kinds mirror
# relational.table: numeric | string | date.  eventtime is epoch seconds
# (the engine has no timestamp kind; ClickHouse stores it as one anyway).
CLICKBENCH_SCHEMA = {
    "hits": {
        "watchid": "numeric", "javaenable": "numeric", "title": "string",
        "goodevent": "numeric", "eventtime": "numeric", "eventdate": "date",
        "counterid": "numeric", "clientip": "numeric", "regionid": "numeric",
        "userid": "numeric", "os": "numeric", "useragent": "numeric",
        "url": "string", "referer": "string", "isrefresh": "numeric",
        "resolutionwidth": "numeric", "resolutionheight": "numeric",
        "mobilephone": "numeric", "mobilephonemodel": "string",
        "searchphrase": "string", "searchengineid": "numeric",
        "advengineid": "numeric", "traficsourceid": "numeric",
        "dontcounthits": "numeric",
    },
}

_HOSTS = np.array([
    "yandex.ru", "google.com", "images.google.com", "translate.google.com",
    "mail.google.com", "news.google.com", "auto.ru", "avito.ru", "vk.com",
    "facebook.com", "wikipedia.org", "news.mail.ru", "rambler.ru",
    "smeshariki.ru", "korablitz.ru", "rutube.ru", "kinopoisk.ru",
    "livejournal.com", "odnoklassniki.ru", "booking.com",
])
_PATHS = np.array([
    "search", "news", "cars", "video", "images", "maps", "market", "forum",
    "blog", "chat", "weather", "sport", "music", "films", "games",
])
_BRANDS = np.array([
    "Google", "Yandex", "Bing", "Mail.Ru", "Avito", "Auto.ru", "Wikipedia",
    "RuTube", "Kinopoisk", "VK",
])
_WORDS = np.array([
    "cars", "weather", "news", "photo", "video", "hotel", "flights", "games",
    "music", "films", "phone", "notebook", "recipe", "holiday", "tickets",
    "football", "exchange", "rates", "series", "torrent", "review", "forum",
    "download", "online", "free", "cheap", "new", "best", "top", "sale",
])
_MODELS = np.array([
    "iPhone", "iPad", "Nokia Lumia", "Samsung Galaxy", "HTC One",
    "Sony Xperia", "LG Optimus", "Nexus",
])

_EPOCH = np.datetime64("1970-01-01", "D")
_WINDOW_START = np.datetime64("2013-07-01", "D")   # the official window
_WINDOW_DAYS = 15


def _zipf_weights(n: int, s: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


def _pool_pick(rng, pool: np.ndarray, n: int, s: float = 1.1) -> np.ndarray:
    return pool[rng.choice(len(pool), n, p=_zipf_weights(len(pool), s))]


def generate(n_rows: int = 100_000, seed: int = 20130701) -> HostDB:
    """Generate a hits-table sample (host database format)."""
    rng = np.random.default_rng(seed)
    n = int(n_rows)

    # -- URL pool: scheme://host/path?id=k, zipf-popular -------------------
    n_urls = min(5000, max(200, n // 20))
    k = np.arange(n_urls)
    hosts = _HOSTS[rng.integers(0, len(_HOSTS), n_urls)]
    paths = _PATHS[rng.integers(0, len(_PATHS), n_urls)]
    schemes = np.where(rng.random(n_urls) < 0.3, "https", "http")
    url_pool = np.char.add(np.char.add(np.char.add(np.char.add(np.char.add(
        np.char.add(schemes, "://"), hosts), "/"), paths), "?id="),
        k.astype(str))
    url = _pool_pick(rng, url_pool, n)

    # referer: 40% empty, else another zipf pick from the same pool
    referer = np.where(rng.random(n) < 0.4, "", _pool_pick(rng, url_pool, n))

    # -- titles: "<word> <word> — <brand>" ---------------------------------
    n_titles = min(1500, max(100, n // 50))
    t1 = _WORDS[rng.integers(0, len(_WORDS), n_titles)]
    t2 = _WORDS[rng.integers(0, len(_WORDS), n_titles)]
    tb = _BRANDS[rng.integers(0, len(_BRANDS), n_titles)]
    title_pool = np.char.add(np.char.add(np.char.add(
        np.char.add(t1, " "), t2), " - "), tb)
    title = _pool_pick(rng, title_pool, n)

    # -- search phrases: 70% empty, zipf over two-word combos --------------
    n_phrases = min(600, max(50, n // 100))
    p1 = _WORDS[rng.integers(0, len(_WORDS), n_phrases)]
    p2 = _WORDS[rng.integers(0, len(_WORDS), n_phrases)]
    phrase_pool = np.char.add(np.char.add(p1, " "), p2)
    searchphrase = np.where(rng.random(n) < 0.7, "",
                            _pool_pick(rng, phrase_pool, n))
    has_phrase = searchphrase != ""
    searchengineid = np.where(
        has_phrase, rng.choice([2, 3, 58, 70], n, p=[0.6, 0.25, 0.1, 0.05]),
        0).astype(np.int64)

    # -- mobile: 90% desktop (empty model) ---------------------------------
    mobilephonemodel = np.where(rng.random(n) < 0.9, "",
                                _pool_pick(rng, _MODELS, n, 1.0))
    mobilephone = np.where(mobilephonemodel == "", 0,
                           rng.integers(1, 90, n)).astype(np.int64)

    # -- users/regions/counters: heavy-hitter skew -------------------------
    n_users = max(100, n // 3)
    user_pool = rng.integers(1 << 40, 1 << 44, n_users, dtype=np.int64)
    userid = _pool_pick(rng, user_pool, n, 1.2)
    regionid = rng.choice(np.arange(1, 230, dtype=np.int64), n,
                          p=_zipf_weights(229, 1.3))
    counterid = rng.choice(np.arange(1, 120, dtype=np.int64), n,
                           p=_zipf_weights(119, 1.1))

    # -- time window -------------------------------------------------------
    day = rng.integers(0, _WINDOW_DAYS, n)
    eventdate = _WINDOW_START + day.astype("timedelta64[D]")
    day_start = (_WINDOW_START - _EPOCH).astype(np.int64) * 86400
    eventtime = (day_start + day * 86400
                 + rng.integers(0, 86400, n)).astype(np.int64)

    widths = np.array([0, 1024, 1280, 1366, 1440, 1536, 1600, 1920, 2560],
                      dtype=np.int64)
    resolutionwidth = rng.choice(
        widths, n, p=[0.08, 0.1, 0.18, 0.22, 0.1, 0.08, 0.1, 0.12, 0.02])
    resolutionheight = np.where(
        resolutionwidth == 0, 0, (resolutionwidth * 9) // 16).astype(np.int64)

    hits = {
        "watchid": rng.integers(1 << 40, 1 << 52, n, dtype=np.int64),
        "javaenable": (rng.random(n) < 0.85).astype(np.int64),
        "title": title,
        "goodevent": np.ones(n, np.int64),
        "eventtime": eventtime,
        "eventdate": eventdate,
        "counterid": counterid,
        "clientip": rng.integers(-(1 << 31), 1 << 31, n, dtype=np.int64),
        "regionid": regionid,
        "userid": userid,
        "os": rng.integers(0, 45, n, dtype=np.int64),
        "useragent": rng.integers(0, 83, n, dtype=np.int64),
        "url": url,
        "referer": referer,
        "isrefresh": (rng.random(n) < 0.07).astype(np.int64),
        "resolutionwidth": resolutionwidth,
        "resolutionheight": resolutionheight,
        "mobilephone": mobilephone,
        "mobilephonemodel": mobilephonemodel,
        "searchphrase": searchphrase,
        "searchengineid": searchengineid,
        "advengineid": np.where(rng.random(n) < 0.97, 0,
                                rng.integers(1, 20, n)).astype(np.int64),
        "traficsourceid": rng.integers(-1, 10, n, dtype=np.int64),
        "dontcounthits": (rng.random(n) < 0.05).astype(np.int64),
    }
    return {"hits": hits}


def clickbench_catalog(sample_rows: int = None):
    """Catalog for the hits schema (optimizer stats + binder resolution)."""
    from ..sql.binder import Catalog
    rows = {"hits": float(sample_rows if sample_rows is not None
                          else CLICKBENCH_BASE_ROWS["hits"])}
    return Catalog(CLICKBENCH_SCHEMA, rows)


def load_into_engine(engine, db: HostDB) -> None:
    """Cold-run load: host format → device cache via the buffer manager."""
    from ..relational.table import Table

    for name, cols in db.items():
        engine.register(name, Table.from_pydict(cols), cols)


# ---------------------------------------------------------------------------
# the query set (official ClickBench numbering; *x = repro addition).
# Textual deviation from the official suite: explicit ORDER BY tie-breakers
# appended wherever the official text admits ties, so the accelerator
# engine and the numpy oracle agree row-for-row.
# ---------------------------------------------------------------------------

CLICKBENCH_QUERIES = {
    "q0": "select count(*) as c from hits",
    "q1": "select count(*) as c from hits where AdvEngineID <> 0",
    "q2": """
select sum(AdvEngineID) as s, count(*) as c,
       avg(ResolutionWidth) as w
from hits
""",
    "q4": "select count(distinct UserID) as u from hits",
    "q5": "select count(distinct SearchPhrase) as p from hits",
    "q6": "select min(EventDate) as lo, max(EventDate) as hi from hits",
    "q8": """
select RegionID, count(distinct UserID) as u
from hits
group by RegionID
order by u desc, RegionID
limit 10
""",
    "q10": """
select MobilePhoneModel, count(distinct UserID) as u
from hits
where MobilePhoneModel <> ''
group by MobilePhoneModel
order by u desc, MobilePhoneModel
limit 10
""",
    "q12": """
select SearchPhrase, count(*) as c
from hits
where SearchPhrase <> ''
group by SearchPhrase
order by c desc, SearchPhrase
limit 10
""",
    "q14": """
select SearchEngineID, SearchPhrase, count(*) as c
from hits
where SearchPhrase <> ''
group by SearchEngineID, SearchPhrase
order by c desc, SearchEngineID, SearchPhrase
limit 10
""",
    "q20": "select count(*) as c from hits where URL like '%google%'",
    "q21": """
select SearchPhrase, min(URL) as u, count(*) as c
from hits
where URL like '%google%' and SearchPhrase <> ''
group by SearchPhrase
order by c desc, SearchPhrase
limit 10
""",
    "q22": """
select SearchPhrase, min(URL) as u, min(Title) as t, count(*) as c,
       count(distinct UserID) as uu
from hits
where Title like '%Google%'
  and URL not like '%.google.%'
  and SearchPhrase <> ''
group by SearchPhrase
order by c desc, SearchPhrase
limit 10
""",
    # repro additions: the two string operations ClickBench itself buries
    # inside expressions — prefix predicates and substring group keys
    "q43x": "select count(*) as c from hits "
            "where starts_with(URL, 'https://')",
    "q44x": """
select substring(URL, 1, 12) as prefix, count(*) as c
from hits
group by prefix
order by c desc, prefix
limit 10
""",
}

# queries whose hot path evaluates a string predicate / transform — the
# device-residency acceptance set for the string subsystem
CLICKBENCH_STRING_QIDS = ("q10", "q12", "q14", "q20", "q21", "q22", "q43x",
                          "q44x")
