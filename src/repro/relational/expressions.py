"""Expression IR + vectorized evaluator.

Expressions are evaluated column-at-a-time on device (jnp), the Sirius /
libcudf execution style.  String predicates (LIKE, substring, prefix) are
evaluated once against the host-side *dictionary* (small) and then become a
device gather by code — the scoped "CPU fallback path" of the paper applied to
dictionary preprocessing (DESIGN.md §2).

All operations are elementwise / shape-preserving, so the same evaluator is
used by both the eager path and the jit/static path (dictionaries fold into
constants at trace time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import strings
from .table import BOOL, DATE, NUMERIC, STRING, Column, Table, date_to_days


class Expr:
    """Base class for expression nodes."""

    # operator sugar ------------------------------------------------------
    def __add__(self, o): return BinOp("+", self, _wrap(o))
    def __radd__(self, o): return BinOp("+", _wrap(o), self)
    def __sub__(self, o): return BinOp("-", self, _wrap(o))
    def __rsub__(self, o): return BinOp("-", _wrap(o), self)
    def __mul__(self, o): return BinOp("*", self, _wrap(o))
    def __rmul__(self, o): return BinOp("*", _wrap(o), self)
    def __truediv__(self, o): return BinOp("/", self, _wrap(o))
    def __eq__(self, o): return BinOp("==", self, _wrap(o))  # type: ignore[override]
    def __ne__(self, o): return BinOp("!=", self, _wrap(o))  # type: ignore[override]
    def __lt__(self, o): return BinOp("<", self, _wrap(o))
    def __le__(self, o): return BinOp("<=", self, _wrap(o))
    def __gt__(self, o): return BinOp(">", self, _wrap(o))
    def __ge__(self, o): return BinOp(">=", self, _wrap(o))
    def __and__(self, o): return BinOp("and", self, _wrap(o))
    def __or__(self, o): return BinOp("or", self, _wrap(o))
    def __invert__(self): return UnOp("not", self)
    def __hash__(self):  # needed because __eq__ is overloaded
        return id(self)

    def equals(self, other) -> bool:
        """Structural equality — the safe idiom for comparing expressions.

        ``==`` is overloaded to *build* a BinOp node, so anything that calls
        it for truth — ``list.remove``, ``in``, ``.index`` — silently
        misbehaves on Expr lists (every element "equals" every other, since
        a BinOp is truthy).  Optimizer/executor code must use ``equals`` /
        ``same`` or identity (``is``) instead.
        """
        return expr_equal(self, other)

    # alias: reads better in membership helpers (any(x.same(e) for e in xs))
    same = equals

    def columns(self) -> List[str]:
        """Free column references (for projection pruning)."""
        out: List[str] = []
        _collect_columns(self, out)
        return out


def _wrap(v) -> "Expr":
    if isinstance(v, Expr):
        return v
    return Lit(v)


@dataclasses.dataclass(eq=False)
class Col(Expr):
    name: str


@dataclasses.dataclass(eq=False)
class Lit(Expr):
    value: Any
    kind: Optional[str] = None  # force interpretation, e.g. DATE

    def resolved_kind(self) -> str:
        if self.kind:
            return self.kind
        if isinstance(self.value, str):
            return STRING
        if isinstance(self.value, bool):
            return BOOL
        return NUMERIC


def DateLit(s: str) -> Lit:
    return Lit(date_to_days(s), DATE)


@dataclasses.dataclass(eq=False)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr


@dataclasses.dataclass(eq=False)
class UnOp(Expr):
    op: str
    operand: Expr


@dataclasses.dataclass(eq=False)
class Between(Expr):
    operand: Expr
    lo: Expr
    hi: Expr


@dataclasses.dataclass(eq=False)
class InList(Expr):
    operand: Expr
    values: Sequence[Any]
    negate: bool = False


@dataclasses.dataclass(eq=False)
class Like(Expr):
    """SQL LIKE: ``%`` any run, ``_`` any char, backslash escapes both."""
    operand: Expr
    pattern: str
    negate: bool = False


@dataclasses.dataclass(eq=False)
class StartsWith(Expr):
    """Prefix predicate: on a sorted dictionary this is a contiguous code
    range, so it lowers to two integer compares (no mask gather)."""
    operand: Expr
    prefix: str
    negate: bool = False


@dataclasses.dataclass(eq=False)
class Case(Expr):
    whens: Sequence[Tuple[Expr, Expr]]
    default: Expr


@dataclasses.dataclass(eq=False)
class ExtractYear(Expr):
    operand: Expr


@dataclasses.dataclass(eq=False)
class Substr(Expr):
    """SQL substring(col, start, length) — 1-based, host dictionary rewrite."""
    operand: Expr
    start: int
    length: int


@dataclasses.dataclass(eq=False)
class Cast(Expr):
    operand: Expr
    dtype: str  # "float64" | "float32" | "int64" | "int32"


def _collect_columns(e: Expr, out: List[str]) -> None:
    if isinstance(e, Col):
        out.append(e.name)
    elif isinstance(e, BinOp):
        _collect_columns(e.left, out); _collect_columns(e.right, out)
    elif isinstance(e, UnOp):
        _collect_columns(e.operand, out)
    elif isinstance(e, Between):
        for x in (e.operand, e.lo, e.hi):
            _collect_columns(x, out)
    elif isinstance(e, (InList, Like, StartsWith, ExtractYear, Substr, Cast)):
        _collect_columns(e.operand, out)
    elif isinstance(e, Case):
        for c, v in e.whens:
            _collect_columns(c, out); _collect_columns(v, out)
        _collect_columns(e.default, out)


# ---------------------------------------------------------------------------
# generic structural helpers (used by the SQL frontend and the optimizer)
# ---------------------------------------------------------------------------


def expr_children(e: Expr) -> List[Expr]:
    """Immediate Expr children, generic over the dataclass fields."""
    out: List[Expr] = []
    if not dataclasses.is_dataclass(e):
        return out
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if isinstance(v, Expr):
            out.append(v)
        elif isinstance(v, (list, tuple)):
            for item in v:
                if isinstance(item, Expr):
                    out.append(item)
                elif isinstance(item, (list, tuple)):
                    out.extend(x for x in item if isinstance(x, Expr))
    return out


def walk_expr(e: Expr):
    """Pre-order traversal over an expression tree (does not enter sub-plans)."""
    yield e
    for c in expr_children(e):
        yield from walk_expr(c)


def transform_expr(e: Expr, fn) -> Expr:
    """Bottom-up rebuild: apply ``fn`` to every node, children first."""
    if not dataclasses.is_dataclass(e):
        return fn(e)
    changes = {}
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if isinstance(v, Expr):
            nv = transform_expr(v, fn)
            if nv is not v:
                changes[f.name] = nv
        elif isinstance(v, (list, tuple)):
            new_items, dirty = [], False
            for item in v:
                if isinstance(item, Expr):
                    ni = transform_expr(item, fn)
                    dirty |= ni is not item
                    new_items.append(ni)
                elif isinstance(item, tuple):
                    ni = tuple(transform_expr(x, fn) if isinstance(x, Expr)
                               else x for x in item)
                    dirty |= any(a is not b for a, b in zip(ni, item))
                    new_items.append(ni)
                else:
                    new_items.append(item)
            if dirty:
                changes[f.name] = type(v)(new_items) if isinstance(v, tuple) \
                    else new_items
    if changes:
        e = dataclasses.replace(e, **changes)
    return fn(e)


def split_conjuncts(e: Optional[Expr]) -> List[Expr]:
    """Flatten an AND tree into its conjuncts (None → [])."""
    if e is None:
        return []
    if isinstance(e, BinOp) and e.op == "and":
        return split_conjuncts(e.left) + split_conjuncts(e.right)
    return [e]


def and_all(conjuncts: Sequence[Expr]) -> Optional[Expr]:
    """Rebuild an AND tree from conjuncts ([] → None)."""
    out: Optional[Expr] = None
    for c in conjuncts:
        out = c if out is None else BinOp("and", out, c)
    return out


def expr_equal(a, b, rel_eq=None) -> bool:
    """Structural equality (Expr.__eq__ is overloaded to build BinOp).

    ``rel_eq`` compares embedded non-Expr dataclasses (plan sub-trees inside
    ScalarSubquery); defaults to identity.
    """
    if a is b:
        return True
    if isinstance(a, Expr) or isinstance(b, Expr):
        if type(a) is not type(b):
            return False
        for f in dataclasses.fields(a):
            if not expr_equal(getattr(a, f.name), getattr(b, f.name), rel_eq):
                return False
        return True
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(
            expr_equal(x, y, rel_eq) for x, y in zip(a, b))
    if dataclasses.is_dataclass(a) or dataclasses.is_dataclass(b):
        if type(a) is not type(b):
            return False
        return rel_eq(a, b) if rel_eq is not None else a is b
    return a == b


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

_ARITH = {"+": jnp.add, "-": jnp.subtract, "*": jnp.multiply, "/": jnp.divide}
_CMP = {"==": jnp.equal, "!=": jnp.not_equal, "<": jnp.less,
        "<=": jnp.less_equal, ">": jnp.greater, ">=": jnp.greater_equal}


# single LIKE implementation (escape-aware); re-exported here because the
# fallback oracle and older call sites import it from this module
like_to_regex = strings.like_to_regex


def _string_lit_cmp(col: Column, op: str, lit: str) -> Column:
    """Compare a dict-encoded string column with a string literal.

    The dictionary is sorted, so codes are ranks: integer comparison against
    the literal's insertion point is exact lexicographic comparison.
    """
    d = col.dictionary
    left = int(np.searchsorted(d, lit, side="left"))
    present = left < len(d) and d[left] == lit
    codes = col.data
    if op == "==":
        return Column(codes == left if present else jnp.zeros_like(codes, bool), BOOL)
    if op == "!=":
        return Column(codes != left if present else jnp.ones_like(codes, bool), BOOL)
    if op == "<":
        return Column(codes < left, BOOL)
    if op == ">=":
        return Column(codes >= left, BOOL)
    if op == "<=":
        # <= lit  ⇔  < upper insertion point
        right = int(np.searchsorted(d, lit, side="right"))
        return Column(codes < right, BOOL)
    if op == ">":
        right = int(np.searchsorted(d, lit, side="right"))
        return Column(codes >= right, BOOL)
    raise ValueError(f"bad string comparison {op}")


def evaluate(expr: Expr, table: Table) -> Column:
    """Evaluate ``expr`` against ``table`` → Column (device array)."""
    if isinstance(expr, Col):
        return table[expr.name]

    if isinstance(expr, Lit):
        n = table.num_rows
        k = expr.resolved_kind()
        if k == STRING:
            raise ValueError("bare string literal column not supported; use comparisons")
        val = expr.value
        dt = jnp.float64 if isinstance(val, float) else None
        return Column(jnp.full((n,), val, dtype=dt), k)

    if isinstance(expr, BinOp):
        if expr.op in ("and", "or"):
            l = evaluate(expr.left, table).data
            r = evaluate(expr.right, table).data
            fn = jnp.logical_and if expr.op == "and" else jnp.logical_or
            return Column(fn(l, r), BOOL)

        # string vs literal comparisons take the dictionary path
        if expr.op in _CMP:
            le, re_ = expr.left, expr.right
            if isinstance(re_, Lit) and re_.resolved_kind() == STRING:
                lc = evaluate(le, table)
                if lc.kind == STRING:
                    return _string_lit_cmp(lc, expr.op, re_.value)
            if isinstance(le, Lit) and le.resolved_kind() == STRING:
                rc = evaluate(re_, table)
                if rc.kind == STRING:
                    return _string_lit_cmp(rc, _flip(expr.op), le.value)

        l = evaluate(expr.left, table)
        r = evaluate(expr.right, table)
        if l.kind == STRING and r.kind == STRING:
            # column-vs-column string compare: unify dictionaries first
            from .table import unify_string_keys
            l, r = unify_string_keys(l, r)
        if expr.op in _CMP:
            return Column(_CMP[expr.op](l.data, r.data), BOOL)
        if expr.op in _ARITH:
            ld, rd = l.data, r.data
            if expr.op == "/":
                ld = ld.astype(jnp.float64)
            out_kind = DATE if (l.kind == DATE or r.kind == DATE) and expr.op in ("+", "-") else NUMERIC
            if l.kind == DATE and r.kind == DATE:
                out_kind = NUMERIC  # date difference = days
            return Column(_ARITH[expr.op](ld, rd), out_kind)
        raise ValueError(f"unknown binop {expr.op}")

    if isinstance(expr, UnOp):
        v = evaluate(expr.operand, table)
        if expr.op == "not":
            return Column(jnp.logical_not(v.data), BOOL)
        if expr.op == "-":
            return Column(jnp.negative(v.data), v.kind)
        raise ValueError(f"unknown unop {expr.op}")

    if isinstance(expr, Between):
        v = evaluate(expr.operand, table)
        lo = evaluate(expr.lo, table) if not isinstance(expr.lo, Lit) else None
        # inline literal bounds to keep jit graphs small
        lo_d = lo.data if lo is not None else jnp.asarray(expr.lo.value)
        hi = evaluate(expr.hi, table) if not isinstance(expr.hi, Lit) else None
        hi_d = hi.data if hi is not None else jnp.asarray(expr.hi.value)
        return Column((v.data >= lo_d) & (v.data <= hi_d), BOOL)

    if isinstance(expr, InList):
        v = evaluate(expr.operand, table)
        if v.kind == STRING:
            # one-time host pass over the dictionary → cached device code mask
            hit = strings.in_list_mask(v.dictionary,
                                       [str(x) for x in expr.values])[v.data]
        else:
            hit = jnp.zeros(v.data.shape, bool)
            for val in expr.values:
                hit = hit | (v.data == val)
        if expr.negate:
            hit = jnp.logical_not(hit)
        return Column(hit, BOOL)

    if isinstance(expr, Like):
        v = evaluate(expr.operand, table)
        if v.kind != STRING:
            raise ValueError("LIKE on non-string column")
        kind, lit = strings.analyze_like(expr.pattern)
        if kind == "prefix":
            hit = _prefix_hit(v, lit)
        elif kind == "exact":
            code = strings.exact_code(v.dictionary, lit)
            hit = (v.data == code) if code is not None \
                else jnp.zeros(v.data.shape, bool)
        else:
            # general pattern: cached regex pass over the dictionary →
            # device code mask → per-row gather (fuses into jit regions)
            hit = strings.like_mask(v.dictionary, expr.pattern)[v.data]
        if expr.negate:
            hit = jnp.logical_not(hit)
        return Column(hit, BOOL)

    if isinstance(expr, StartsWith):
        v = evaluate(expr.operand, table)
        if v.kind != STRING:
            raise ValueError("starts_with on non-string column")
        hit = _prefix_hit(v, expr.prefix)
        if expr.negate:
            hit = jnp.logical_not(hit)
        return Column(hit, BOOL)

    if isinstance(expr, Case):
        default = evaluate(expr.default, table)
        out = default.data
        kind = default.kind
        for cond, val in reversed(list(expr.whens)):
            c = evaluate(cond, table).data
            vv = evaluate(val, table)
            out = jnp.where(c, vv.data, out)
            kind = vv.kind
        return Column(out, kind)

    if isinstance(expr, ExtractYear):
        v = evaluate(expr.operand, table)
        if v.kind != DATE:
            raise ValueError("extract(year) on non-date")
        return Column(_year_from_days(v.data), NUMERIC)

    if isinstance(expr, Substr):
        v = evaluate(expr.operand, table)
        if v.kind != STRING:
            raise ValueError("substr on non-string")
        # code→code dictionary transform: the derived dictionary object is
        # identity-stable per (dictionary, start, length), so downstream
        # plan-signature caches stay valid across repeated executions
        new_dict, remap = strings.substr_transform(
            v.dictionary, expr.start, expr.length)
        return Column(remap[v.data], STRING, new_dict)

    if isinstance(expr, Cast):
        v = evaluate(expr.operand, table)
        return Column(v.data.astype(jnp.dtype(expr.dtype)), NUMERIC)

    raise TypeError(f"cannot evaluate {type(expr)}")


def _prefix_hit(col: Column, prefix: str) -> jnp.ndarray:
    """Prefix predicate over a dictionary-encoded column: codes are ranks of
    a sorted dictionary, so the matching codes form [lo, hi)."""
    lo, hi = strings.prefix_range(col.dictionary, prefix)
    if lo >= hi:
        return jnp.zeros(col.data.shape, bool)
    return (col.data >= lo) & (col.data < hi)


def _flip(op: str) -> str:
    return {"==": "==", "!=": "!=", "<": ">", ">": "<", "<=": ">=", ">=": "<="}[op]


def _year_from_days(days):
    """Civil year from days since 1970-01-01 (Howard Hinnant's algorithm)."""
    z = days.astype(jnp.int32) + 719468
    era = jnp.where(z >= 0, z, z - 146096) // 146097
    doe = z - era * 146097
    yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = (5 * doy + 2) // 153
    m = jnp.where(mp < 10, mp + 3, mp - 9)
    return jnp.where(m <= 2, y + 1, y).astype(jnp.int32)
