"""Order-by / top-k.

Eager path: device lexsort on the encoded sort keys (order-preserving
dictionary codes make string sorts integer sorts, so the whole sort runs on
device without decoding).  Sort inputs in TPC-H are tiny (post-aggregation),
matching the paper's observation that order-by never dominates.

Static path: ``static_topk`` — mask-aware top-k on a single packed key for
compiled fragments.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .table import Column, Table


@dataclasses.dataclass
class SortKey:
    name: str
    ascending: bool = True


def sort_table(table: Table, keys: Sequence[SortKey], limit: int | None = None) -> Table:
    if table.num_rows == 0:
        return table
    arrays: List[jnp.ndarray] = []
    for k in keys:
        col = table[k.name]
        a = jnp.asarray(col.data)
        if a.dtype.kind == "b":
            a = a.astype(jnp.int8)
        if not k.ascending:
            if a.dtype.kind == "f":
                a = -a
            else:
                a = -(a.astype(jnp.int64))
        arrays.append(a)
    # lexsort: last key is primary
    order = jnp.lexsort(tuple(reversed(arrays)))
    if limit is not None:
        order = order[:limit]
    return table.take(order)


def static_topk(packed_key: jnp.ndarray, valid: jnp.ndarray, k: int):
    """Top-k smallest packed keys among valid rows → (indices, valid_out)."""
    big = jnp.iinfo(packed_key.dtype).max if packed_key.dtype.kind == "i" else jnp.inf
    masked = jnp.where(valid, packed_key, big)
    # top_k finds largest; negate for ascending order
    neg = -(masked.astype(jnp.float32)) if masked.dtype.kind == "f" else -masked
    _, idx = jax.lax.top_k(neg, k)
    taken_valid = jnp.take(valid, idx)
    return idx, taken_valid
