r"""Device-resident string & dictionary subsystem (DESIGN.md "Strings &
dictionaries").

Strings never exist on the accelerator: a string column is an int32 code
array on device plus a *sorted* host-side dictionary (codes are ranks, so
integer order on codes is lexicographic order on strings).  Every string
operation therefore decomposes into

  1. a **one-time host pass** over the (small) dictionary that produces a
     device-resident artifact — a boolean *code mask*, a contiguous *code
     range*, or a code→code *remap* array — and
  2. a pure ``jnp`` gather/compare over the per-row codes, which fuses into
     the compiled pipeline regions like any numeric predicate.

This module owns step 1 and memoizes it **by dictionary identity**, which
matters twice over:

  * the host pass (regex over the dictionary, substring slicing, merge +
    searchsorted) runs once per (dictionary, operation), not once per
    query execution;
  * derived dictionaries (substring transforms, merged join dictionaries)
    come back as the *same object* every time, so the pipeline compiler's
    signature cache — which keys on ``id(dictionary)`` — stays hot across
    repeated queries instead of retracing on every fresh ``np.unique``.

Cached dictionaries are pinned with strong references (they are small: the
whole point of dictionary encoding is |dict| << |rows|).  ``stats`` counts
host passes vs cache hits so tests can assert the one-time property.

Deliberate tradeoff: the cache is unbounded.  Eviction cannot preserve the
identity-stability contract (dropping a derived dictionary and rebuilding
it later yields a new object, invalidating every downstream id()-keyed
signature cache), so a long-lived engine serving unbounded *distinct*
patterns/IN-lists will grow this cache; artifacts are dictionary-sized, so
growth is O(distinct predicates × |dict|), not O(rows).  ``clear_cache()``
is the explicit reset for that regime — call it only at a query-cache
flush boundary, since compiled pipeline regions warmed against the old
dictionary identities will retrace afterwards.

LIKE pattern language: ``%`` any run, ``_`` any char, backslash escapes
(``\%``, ``\_``, ``\\``) match the literal character.  Patterns that reduce
to a pure prefix (``abc%``) or an exact literal skip the regex entirely:
on a sorted dictionary a prefix match is a contiguous code range, so the
per-row evaluation is two integer compares with no mask gather at all.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _device_const(host: np.ndarray) -> jnp.ndarray:
    """Upload a host artifact as a *concrete* device array.

    Cached artifacts outlive any single trace, and the first evaluation of
    a predicate may happen while a fused pipeline region is being traced —
    a bare ``jnp.asarray`` there would cache a tracer and leak it into
    later executions.  ``ensure_compile_time_eval`` escapes the trace, so
    the cache always holds a reusable concrete constant.
    """
    with jax.ensure_compile_time_eval():
        return jnp.asarray(host)

# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

# (id(dictionary), op, params) -> artifact; strong refs in _pins keep the
# dictionary objects alive so an id() can never be recycled onto another
# dictionary while its cache entries exist.
_cache: Dict[Tuple, object] = {}
_pins: Dict[int, np.ndarray] = {}

stats = {"host_passes": 0, "cache_hits": 0}


def _cached(dictionary, op: str, params: Tuple, compute):
    """Memoize ``compute()`` by dictionary identity.

    ``dictionary`` is one np.ndarray or a tuple of them (two-dictionary
    operations: merge, recode); every participating dictionary is pinned so
    no id() in the key can be recycled while the entry lives."""
    dicts = dictionary if isinstance(dictionary, tuple) else (dictionary,)
    key = (tuple(id(d) for d in dicts), op, params)
    hit = _cache.get(key)
    if hit is not None:
        stats["cache_hits"] += 1
        return hit
    stats["host_passes"] += 1
    for d in dicts:
        _pins[id(d)] = d
    out = _cache[key] = compute()
    return out


def clear_cache() -> None:
    """Drop all memoized artifacts (tests / memory pressure)."""
    _cache.clear()
    _pins.clear()


# ---------------------------------------------------------------------------
# LIKE pattern analysis
# ---------------------------------------------------------------------------


def like_to_regex(pattern: str) -> re.Pattern:
    """SQL LIKE pattern → anchored regex.  Backslash escapes the next char."""
    out = []
    i, n = 0, len(pattern)
    while i < n:
        ch = pattern[i]
        if ch == "\\" and i + 1 < n:
            out.append(re.escape(pattern[i + 1]))
            i += 2
            continue
        if ch == "%":
            out.append(".*")
        elif ch == "_":
            out.append(".")
        else:
            out.append(re.escape(ch))
        i += 1
    return re.compile("^" + "".join(out) + "$", re.DOTALL)


def analyze_like(pattern: str) -> Tuple[str, str]:
    """Classify a LIKE pattern → ("exact"|"prefix"|"general", literal).

    ``exact``: no unescaped wildcards — equivalent to ``= literal``.
    ``prefix``: ``literal%`` with no other wildcards — a contiguous code
    range on the sorted dictionary.  Everything else is ``general``.
    """
    lit: List[str] = []
    i, n = 0, len(pattern)
    while i < n:
        ch = pattern[i]
        if ch == "\\" and i + 1 < n:
            lit.append(pattern[i + 1])
            i += 2
            continue
        if ch == "%":
            if i == n - 1:
                return "prefix", "".join(lit)
            return "general", ""
        if ch == "_":
            return "general", ""
        lit.append(ch)
        i += 1
    return "exact", "".join(lit)


# ---------------------------------------------------------------------------
# code masks / ranges (predicate artifacts)
# ---------------------------------------------------------------------------


def like_host_mask(dictionary: np.ndarray, pattern: str) -> np.ndarray:
    """Host bool mask over the dictionary: entry matches the LIKE pattern."""
    def compute():
        rx = like_to_regex(pattern)
        return np.fromiter((rx.match(s) is not None for s in dictionary),
                           bool, len(dictionary))
    return _cached(dictionary, "like_host", (pattern,), compute)


def like_mask(dictionary: np.ndarray, pattern: str) -> jnp.ndarray:
    """Device bool mask over dictionary codes for a LIKE pattern."""
    return _cached(dictionary, "like_dev", (pattern,),
                   lambda: _device_const(like_host_mask(dictionary, pattern)))


def in_list_mask(dictionary: np.ndarray, values: Sequence[str]) -> jnp.ndarray:
    """Device bool mask over dictionary codes for an IN list."""
    vals = tuple(values)

    def compute():
        # no dtype cast: forcing the dictionary's fixed U-width would
        # truncate longer IN values into false-positive matches
        hit = np.isin(dictionary, np.asarray(list(vals)))
        return _device_const(hit)
    return _cached(dictionary, "in_list", (vals,), compute)


def prefix_range(dictionary: np.ndarray, prefix: str) -> Tuple[int, int]:
    """Code range [lo, hi) whose dictionary entries start with ``prefix``.

    The dictionary is sorted, so every string with a given prefix occupies a
    contiguous rank interval; the per-row predicate is two int compares.
    """
    def compute():
        lo = int(np.searchsorted(dictionary, prefix, side="left"))
        # prefix matches sort contiguously from lo; count them directly
        # (a `prefix + <max char>` upper probe would wrongly exclude
        # entries whose next character is U+10FFFF itself)
        tail = dictionary[lo:]
        if len(tail) == 0 or prefix == "":
            return (lo, len(dictionary))
        hi = lo + int(np.char.startswith(tail, prefix).sum())
        return (lo, hi)
    return _cached(dictionary, "prefix", (prefix,), compute)


def exact_code(dictionary: np.ndarray, literal: str) -> Optional[int]:
    """Code of ``literal`` in the dictionary, or None when absent."""
    def compute():
        pos = int(np.searchsorted(dictionary, literal, side="left"))
        ok = pos < len(dictionary) and dictionary[pos] == literal
        return (pos if ok else None,)
    return _cached(dictionary, "exact", (literal,), compute)[0]


# ---------------------------------------------------------------------------
# dictionary transforms (code → code)
# ---------------------------------------------------------------------------


def substr_transform(dictionary: np.ndarray, start: int,
                     length: int) -> Tuple[np.ndarray, jnp.ndarray]:
    """SQL substring as a dictionary transform → (derived dict, device remap).

    ``derived dict`` is the sorted unique set of ``s[start-1 : start-1+length]``
    over the input dictionary; ``remap`` maps old codes to derived codes on
    device, so ``substring(col)`` is one gather and the result is itself a
    first-class dictionary-encoded column.  Identity-stable: the same input
    dictionary always yields the *same* derived dictionary object, keeping
    plan-signature caches valid across queries.
    """
    def compute():
        subs = np.asarray(
            [s[start - 1: start - 1 + length] for s in dictionary])
        derived, remap = np.unique(subs, return_inverse=True)
        return (derived, _device_const(remap.astype(np.int32)))
    return _cached(dictionary, "substr", (start, length), compute)


def merged_dictionary(left: np.ndarray, right: np.ndarray) -> np.ndarray:
    """Sorted union of two dictionaries (identity-stable per input pair)."""
    if left is right:
        return left
    return _cached((left, right), "merge", (),
                   lambda: np.unique(np.concatenate([left, right])))


def recode_map(src: np.ndarray, target: np.ndarray) -> jnp.ndarray:
    """Device int32 map from ``src`` codes to ``target`` codes (-1 = absent)."""
    def compute():
        pos = np.searchsorted(target, src)
        pos = np.clip(pos, 0, max(len(target) - 1, 0))
        ok = (target[pos] == src) if len(target) else np.zeros(len(src), bool)
        return _device_const(np.where(ok, pos, -1).astype(np.int32))
    return _cached((src, target), "recode", (), compute)


# ---------------------------------------------------------------------------
# dictionary-informed selectivity (optimizer stats hooks)
# ---------------------------------------------------------------------------


def like_selectivity(dictionary: np.ndarray, pattern: str) -> float:
    """Fraction of dictionary entries matching the pattern (hit rate).

    Without per-code frequencies this treats codes as uniform — still far
    better than a constant for the common cases (rare comment probes, broad
    ``%a%`` patterns), and exact when the dictionary is value-balanced.
    """
    n = len(dictionary)
    if n == 0:
        return 0.0
    return float(like_host_mask(dictionary, pattern).sum()) / n


def in_selectivity(dictionary: np.ndarray, values: Sequence[str]) -> float:
    n = len(dictionary)
    if n == 0:
        return 0.0
    hits = sum(1 for v in values if exact_code(dictionary, str(v)) is not None)
    return hits / n


def prefix_selectivity(dictionary: np.ndarray, prefix: str) -> float:
    n = len(dictionary)
    if n == 0:
        return 0.0
    lo, hi = prefix_range(dictionary, prefix)
    return (hi - lo) / n


def eq_selectivity(dictionary: np.ndarray, literal: str) -> float:
    n = len(dictionary)
    if n == 0:
        return 0.0
    return (1.0 if exact_code(dictionary, literal) is not None else 0.0) / n
