"""Columnar Table abstraction — the Arrow-derived format of Sirius (§3.2.3).

Sirius keeps three columnar formats (internal / libcudf / host-DB) that are
zero-copy convertible because all derive from Apache Arrow.  Here the internal
format is a dict of device (jnp) arrays; the "host database" format is numpy.
Conversion device<->host is explicit (``Table.to_host`` / ``Table.to_device``)
and accounted by the buffer manager, mirroring the paper's cold-run deep copy.

TPU adaptation (see DESIGN.md §2):
  * strings are order-preserving dictionary encoded at load time: codes are the
    rank of the string in the sorted dictionary, so integer comparison on codes
    is exactly lexicographic comparison on strings;
  * dates are int32 days since 1970-01-01;
  * decimals are float64 (TPC-H tolerance 1e-2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

# The analytical engine needs exact int64 join keys and float64 accumulation
# (TPC-H money).  Enable x64 before any array is created.  LM-side modules are
# dtype-explicit (bf16/f32) and unaffected.
jax.config.update("jax_enable_x64", True)

Array = Union[np.ndarray, jnp.ndarray]

# Logical column kinds.
NUMERIC = "numeric"
STRING = "string"
DATE = "date"
BOOL = "bool"

_EPOCH = np.datetime64("1970-01-01", "D")


def date_to_days(s: str) -> int:
    """'1995-03-15' -> int32 days since epoch."""
    return int((np.datetime64(s, "D") - _EPOCH).astype(np.int64))


def days_to_date(d: int) -> str:
    return str(_EPOCH + np.timedelta64(int(d), "D"))


@dataclasses.dataclass
class Column:
    """A single column: device data + (for strings) a host-side dictionary.

    ``data``       device array (codes for strings, days for dates)
    ``kind``       NUMERIC | STRING | DATE | BOOL
    ``dictionary`` sorted np.ndarray of python strings (STRING only)
    """

    data: Array
    kind: str = NUMERIC
    dictionary: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.kind == STRING and self.dictionary is None:
            raise ValueError("string column requires a dictionary")

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_strings(values: Sequence[str]) -> "Column":
        arr = np.asarray(values, dtype=object)
        dictionary, codes = np.unique(arr.astype(str), return_inverse=True)
        return Column(jnp.asarray(codes.astype(np.int32)), STRING, dictionary)

    @staticmethod
    def from_dates(values: Sequence[str]) -> "Column":
        days = (np.asarray(values, dtype="datetime64[D]") - _EPOCH).astype(np.int32)
        return Column(jnp.asarray(days), DATE)

    @staticmethod
    def from_numpy(arr: np.ndarray) -> "Column":
        if arr.dtype.kind in ("U", "S", "O"):
            return Column.from_strings(arr)
        if arr.dtype.kind == "M":
            days = (arr.astype("datetime64[D]") - _EPOCH).astype(np.int32)
            return Column(jnp.asarray(days), DATE)
        if arr.dtype == np.bool_:
            return Column(jnp.asarray(arr), BOOL)
        if arr.dtype == np.float64:
            return Column(jnp.asarray(arr, dtype=jnp.float64), NUMERIC)
        return Column(jnp.asarray(arr), NUMERIC)

    # -- basics ------------------------------------------------------------
    def __len__(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.data.shape)) * self.data.dtype.itemsize

    def take(self, idx: Array) -> "Column":
        return Column(jnp.take(self.data, idx, axis=0), self.kind, self.dictionary)

    def to_host(self) -> np.ndarray:
        """Decode to the host-database representation (deep copy)."""
        host = np.asarray(self.data)
        if self.kind == STRING:
            return self.dictionary[host]
        if self.kind == DATE:
            return _EPOCH + host.astype("timedelta64[D]")
        return host

    def decode(self) -> np.ndarray:
        return self.to_host()

    # -- dictionary bridging (string join keys across tables) ---------------
    def recode_to(self, target_dictionary: np.ndarray) -> "Column":
        """Map this column's codes into another dictionary's code space.

        Codes not present in the target dictionary map to -1 (never matches).
        This is the host-side 'dictionary bridge' used when joining string
        columns encoded against different dictionaries (DESIGN.md §2).
        """
        if self.kind != STRING:
            raise ValueError("recode_to only applies to string columns")
        from . import strings
        mapping = strings.recode_map(self.dictionary, target_dictionary)
        return Column(mapping[self.data], STRING, target_dictionary)


class Table:
    """An ordered collection of equal-length Columns."""

    def __init__(self, columns: Dict[str, Column]):
        self.columns: Dict[str, Column] = dict(columns)
        lengths = {len(c) for c in self.columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged table: {lengths}")
        self._num_rows = lengths.pop() if lengths else 0

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_pydict(data: Dict[str, Union[np.ndarray, list]]) -> "Table":
        cols = {}
        for name, values in data.items():
            if isinstance(values, Column):
                cols[name] = values
            else:
                arr = np.asarray(values)
                cols[name] = Column.from_numpy(arr)
        return Table(cols)

    # -- accessors ----------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def column_names(self) -> List[str]:
        return list(self.columns.keys())

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    # -- relational primitives (shared by operators) -------------------------
    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def rename(self, mapping: Dict[str, str]) -> "Table":
        return Table({mapping.get(n, n): c for n, c in self.columns.items()})

    def with_column(self, name: str, col: Column) -> "Table":
        cols = dict(self.columns)
        cols[name] = col
        return Table(cols)

    def drop(self, names: Sequence[str]) -> "Table":
        return Table({n: c for n, c in self.columns.items() if n not in names})

    def take(self, idx: Array) -> "Table":
        return Table({n: c.take(idx) for n, c in self.columns.items()})

    def head(self, n: int) -> "Table":
        return self.take(jnp.arange(min(n, self.num_rows)))

    def filter_mask(self, mask: Array) -> "Table":
        """Eager compaction (the libcudf apply_boolean_mask analogue).

        Device-side, via the jit-compiled ``kernels.ops.compact``: the
        dynamic output size is the one scalar pull (recorded/replayed by
        the plan cache); selected indices and the gather stay on device."""
        from ..core.instrument import pull_scalar
        from ..kernels import ops as kops
        idx, count = kops.compact(jnp.asarray(mask))
        return self.take(idx[: pull_scalar(count)])

    @staticmethod
    def concat(tables: Sequence["Table"]) -> "Table":
        tables = [t for t in tables if t.num_rows >= 0]
        if not tables:
            return Table({})
        names = tables[0].column_names
        out = {}
        for n in names:
            kind = tables[0][n].kind
            if kind == STRING:
                from . import strings
                merged = tables[0][n].dictionary
                for t in tables[1:]:
                    merged = strings.merged_dictionary(merged, t[n].dictionary)
                parts = [t[n].recode_to(merged).data for t in tables]
                out[n] = Column(jnp.concatenate(parts), STRING, merged)
            else:
                out[n] = Column(
                    jnp.concatenate([t[n].data for t in tables]), kind,
                )
        return Table(out)

    # -- host conversion ------------------------------------------------------
    def to_host(self) -> Dict[str, np.ndarray]:
        return {n: c.to_host() for n, c in self.columns.items()}

    def to_pylist(self) -> List[dict]:
        host = self.to_host()
        return [
            {n: host[n][i] for n in self.column_names} for i in range(self.num_rows)
        ]

    def __repr__(self) -> str:
        cols = ", ".join(
            f"{n}:{c.kind}[{c.data.dtype}]" for n, c in self.columns.items()
        )
        return f"Table({self.num_rows} rows; {cols})"


def unify_string_keys(left: Column, right: Column):
    """Re-encode two string columns into one shared dictionary for joins.

    The merged dictionary and both recode maps come from the
    identity-memoized string subsystem (``relational.strings``), so the
    host-side merge/searchsorted passes run once per dictionary pair and the
    merged dictionary object is stable across executions."""
    if left.kind != STRING or right.kind != STRING:
        return left, right
    if left.dictionary is right.dictionary or (
        len(left.dictionary) == len(right.dictionary)
        and np.array_equal(left.dictionary, right.dictionary)
    ):
        return left, right
    from . import strings
    merged = strings.merged_dictionary(left.dictionary, right.dictionary)
    return left.recode_to(merged), right.recode_to(merged)
