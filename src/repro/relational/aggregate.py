"""Group-by aggregation.

Eager path: factorize group keys host-side (exact, any cardinality), then
device segment reductions — the hash-aggregate analogue.  The paper notes
libcudf falls back to *sort-based* group-by for string keys; our dictionary
codes keep strings on the hash path, which is one of the TPU-adaptation wins
recorded in DESIGN.md.

Static path: fixed ``num_groups`` scatter-add aggregation (jit / shard_map /
kernel oracle) — group ids must already be dense small ints.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .expressions import Expr, evaluate
from .table import BOOL, DATE, NUMERIC, STRING, Column, Table


@dataclasses.dataclass
class AggSpec:
    """One output aggregate: fn in sum|avg|count|count_star|min|max|count_distinct."""

    fn: str
    expr: Optional[Expr]  # None for count_star
    name: str


def factorize_groups(table: Table, keys: Sequence[str]) -> Tuple[np.ndarray, Table]:
    """→ (group_id per row, unique-key Table in group-id order)."""
    if not keys:
        return np.zeros(table.num_rows, np.int64), Table({})
    cols = [table[k] for k in keys]
    mats = [np.asarray(c.data) for c in cols]
    stacked = np.stack([m.astype(np.int64) if m.dtype.kind != "f" else m for m in mats])
    # lexsort-based exact factorization over arbitrary column count
    order = np.lexsort(stacked[::-1])
    sorted_cols = stacked[:, order]
    changed = np.zeros(sorted_cols.shape[1], bool)
    if sorted_cols.shape[1]:
        changed[0] = True
        for row in sorted_cols:
            changed[1:] |= row[1:] != row[:-1]
    gid_sorted = np.cumsum(changed) - 1
    gids = np.empty(table.num_rows, np.int64)
    gids[order] = gid_sorted
    rep_idx = order[changed]  # first row of each group, in group-id order
    uniq = Table({k: table[k].take(jnp.asarray(rep_idx)) for k in keys})
    return gids, uniq


def _segment(fn: str, data: jnp.ndarray, gids: jnp.ndarray, n: int) -> jnp.ndarray:
    if fn == "sum":
        return jax.ops.segment_sum(data, gids, n)
    if fn == "min":
        return jax.ops.segment_min(data, gids, n)
    if fn == "max":
        return jax.ops.segment_max(data, gids, n)
    raise ValueError(fn)


def group_aggregate(
    table: Table, keys: Sequence[str], aggs: Sequence[AggSpec]
) -> Table:
    """Eager hash aggregate."""
    gids_np, uniq = factorize_groups(table, keys)
    n_groups = int(gids_np.max()) + 1 if len(gids_np) else 0
    if table.num_rows == 0:
        # empty input: global aggregates still produce one row
        if keys:
            return Table({**uniq.columns, **{a.name: Column(jnp.zeros((0,))) for a in aggs}})
        n_groups = 1
        gids_np = np.zeros(0, np.int64)
    if not keys:
        n_groups = max(n_groups, 1)
    gids = jnp.asarray(gids_np)

    out: Dict[str, Column] = dict(uniq.columns)
    counts = jax.ops.segment_sum(jnp.ones(table.num_rows), gids, n_groups)
    for a in aggs:
        if a.fn == "count_star":
            out[a.name] = Column(counts.astype(jnp.int64), NUMERIC)
            continue
        col = evaluate(a.expr, table)
        if a.fn == "count":
            data = col.data.astype(jnp.int64)
            ones = jnp.ones(table.num_rows, jnp.int64)
            out[a.name] = Column(jax.ops.segment_sum(ones, gids, n_groups), NUMERIC)
        elif a.fn in ("sum", "min", "max"):
            data = col.data
            if a.fn == "sum" and data.dtype.kind == "b":
                data = data.astype(jnp.int64)
            if a.fn == "sum" and data.dtype == jnp.float32:
                data = data.astype(jnp.float64)
            res = _segment(a.fn, data, gids, n_groups)
            kind = col.kind if a.fn in ("min", "max") else NUMERIC
            out[a.name] = Column(res, kind, col.dictionary if kind == STRING else None)
        elif a.fn == "avg":
            data = col.data.astype(jnp.float64)
            s = jax.ops.segment_sum(data, gids, n_groups)
            out[a.name] = Column(s / jnp.maximum(counts, 1.0), NUMERIC)
        elif a.fn == "count_distinct":
            vals = np.asarray(col.data)
            pairs = np.stack([gids_np, vals.astype(np.int64)])
            uniq_pairs = np.unique(pairs, axis=1)
            cd = np.zeros(n_groups, np.int64)
            np.add.at(cd, uniq_pairs[0], 1)
            out[a.name] = Column(jnp.asarray(cd), NUMERIC)
        else:
            raise ValueError(f"unknown aggregate {a.fn}")
    return Table(out)


# ---------------------------------------------------------------------------
# static-shape aggregate (jit / shard_map / kernel oracle)
# ---------------------------------------------------------------------------


def static_group_aggregate(
    gids: jnp.ndarray,
    valid: jnp.ndarray,
    values: Dict[str, Tuple[str, jnp.ndarray]],
    num_groups: int,
):
    """Masked scatter aggregation with a static group count.

    ``values`` maps output name -> (fn, data array).  Rows with valid=False
    contribute identity elements.  Returns dict of (num_groups,) arrays plus
    ``__count`` (rows per group) and ``__present`` (group non-empty).
    """
    gids = jnp.where(valid, gids, num_groups)  # dump invalid rows past the end
    out = {}
    ones = valid.astype(jnp.float32)
    counts = jax.ops.segment_sum(ones, gids, num_groups + 1)[:-1]
    out["__count"] = counts
    out["__present"] = counts > 0
    for name, (fn, data) in values.items():
        if fn in ("sum", "avg", "count"):
            if fn == "count":
                data = jnp.ones_like(data, jnp.float32)
            contrib = jnp.where(valid, data.astype(jnp.float32), 0)
            s = jax.ops.segment_sum(contrib, gids, num_groups + 1)[:-1]
            out[name] = s / jnp.maximum(counts, 1) if fn == "avg" else s
        elif fn == "min":
            big = jnp.asarray(jnp.finfo(jnp.float32).max, data.dtype)
            contrib = jnp.where(valid, data, big)
            out[name] = jax.ops.segment_min(contrib, gids, num_groups + 1)[:-1]
        elif fn == "max":
            small = jnp.asarray(jnp.finfo(jnp.float32).min, data.dtype)
            contrib = jnp.where(valid, data, small)
            out[name] = jax.ops.segment_max(contrib, gids, num_groups + 1)[:-1]
        else:
            raise ValueError(fn)
    return out
