"""Group-by aggregation.

Eager path: factorize group keys on device (lexsort-based, exact for any
cardinality), then device segment reductions — the hash-aggregate analogue.
The paper notes libcudf falls back to *sort-based* group-by for string keys;
our dictionary codes keep strings on the hash path, which is one of the
TPU-adaptation wins recorded in DESIGN.md.  No column ever round-trips to
host; the only sync is the scalar group count.

Static path: fixed ``num_groups`` scatter-add aggregation (jit / shard_map /
kernel oracle) — group ids must already be dense small ints.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.instrument import pull_scalar
from .expressions import Expr, evaluate
from .table import BOOL, DATE, NUMERIC, STRING, Column, Table


@dataclasses.dataclass
class AggSpec:
    """One output aggregate: fn in sum|avg|count|count_star|min|max|count_distinct."""

    fn: str
    expr: Optional[Expr]  # None for count_star
    name: str


@jax.jit
def _factorize_core(arrs: Tuple[jnp.ndarray, ...]):
    """Lexsort-based exact factorization (compiled; cached per shape/arity)."""
    n = arrs[0].shape[0]
    order = jnp.lexsort(tuple(reversed(arrs)))
    changed = jnp.zeros(n, bool).at[0].set(True)
    for a in arrs:
        s = a[order]
        changed = changed.at[1:].set(changed[1:] | (s[1:] != s[:-1]))
    gid_sorted = jnp.cumsum(changed) - 1
    gids = jnp.zeros(n, jnp.int64).at[order].set(gid_sorted)
    # first row of each group in gid order; tail beyond the group count is
    # garbage and sliced off by the caller
    rep = order[jnp.nonzero(changed, size=n, fill_value=0)[0]]
    return gids, rep, changed.sum()


# dense-domain factorization: count over the key product space instead of
# sorting — the hash-aggregate analogue of libcudf's direct path.  XLA's
# generic sort is the slow op on every backend, so small-domain group-bys
# (flags, dictionary codes, dates, FK ranges) skip it entirely.  The domain
# is capped relative to the row count: the accumulator arrays are
# domain-sized, so a domain far beyond n costs more than the sort it avoids.
_DENSE_DOMAIN_LIMIT = 1 << 21


@jax.jit
def _key_bounds(arrs: Tuple[jnp.ndarray, ...]):
    return tuple((a.min(), a.max()) for a in arrs)


@functools.partial(jax.jit, static_argnames=("domain",))
def _dense_factorize(packed: jnp.ndarray, domain: int):
    n = packed.shape[0]
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), packed, domain)
    present = counts > 0
    mapping = jnp.cumsum(present.astype(jnp.int64)) - 1
    gids = mapping[packed]
    first = jax.ops.segment_min(jnp.arange(n), packed, domain)
    # representative row per present packed value, ascending (= lex) order
    rep = first[jnp.nonzero(present, size=domain, fill_value=0)[0]]
    return gids, rep, present.sum()


def _group_key_arrays(table: Table, keys: Sequence[str]):
    arrs = [jnp.asarray(table[k].data) for k in keys]
    return [a.astype(jnp.int64) if a.dtype.kind != "f" else a for a in arrs]


def _dense_pack(arrs, n: int):
    """Pack int key columns into one dense id → (packed, domain) or None.

    One scalar pull pair per key (the fused bounds reduce) decides
    eligibility; recorded/replayed by the plan cache."""
    if not all(a.dtype.kind != "f" for a in arrs):
        return None
    limit = min(_DENSE_DOMAIN_LIMIT, max(1024, 4 * n))
    bounds = _key_bounds(tuple(arrs))
    los = [pull_scalar(b[0]) for b in bounds]
    cards = [pull_scalar(b[1]) - lo + 1 for b, lo in zip(bounds, los)]
    domain = 1
    for card in cards:
        domain *= card
        if domain > limit:
            return None
    packed = arrs[0] - los[0]
    for a, lo, card in zip(arrs[1:], los[1:], cards[1:]):
        packed = packed * card + (a - lo)
    return packed, domain


def factorize_groups(table: Table, keys: Sequence[str]) -> Tuple[jnp.ndarray, Table]:
    """→ (group_id per row on device, unique-key Table in group-id order)."""
    n = table.num_rows
    if not keys:
        return jnp.zeros(n, jnp.int64), Table({})
    if n == 0:
        return jnp.zeros(0, jnp.int64), Table(
            {k: table[k].take(jnp.zeros((0,), jnp.int64)) for k in keys})
    arrs = _group_key_arrays(table, keys)

    dense = _dense_pack(arrs, n)
    if dense is not None:
        gids, rep, n_groups = _dense_factorize(*dense)
        rep_idx = rep[: pull_scalar(n_groups)]
        uniq = Table({k: table[k].take(rep_idx) for k in keys})
        return gids, uniq

    gids, rep, n_groups = _factorize_core(tuple(arrs))
    rep_idx = rep[: pull_scalar(n_groups)]  # the factorization's scalar pull
    uniq = Table({k: table[k].take(rep_idx) for k in keys})
    return gids, uniq


@functools.partial(jax.jit, static_argnames=("n_groups",))
def _count_distinct(gids: jnp.ndarray, vals: jnp.ndarray, n_groups: int):
    """Device-side: sort (gid, value) pairs, count run starts per group."""
    n = gids.shape[0]
    order = jnp.lexsort((vals, gids))
    g_s, v_s = gids[order], vals[order]
    first = jnp.ones(n, bool)
    if n > 1:
        first = first.at[1:].set((g_s[1:] != g_s[:-1]) | (v_s[1:] != v_s[:-1]))
    return jax.ops.segment_sum(first.astype(jnp.int64), g_s, n_groups)


def _segment(fn: str, data: jnp.ndarray, gids: jnp.ndarray, n: int) -> jnp.ndarray:
    if fn == "sum":
        return jax.ops.segment_sum(data, gids, n)
    if fn == "min":
        return jax.ops.segment_min(data, gids, n)
    if fn == "max":
        return jax.ops.segment_max(data, gids, n)
    raise ValueError(fn)


@functools.partial(jax.jit, static_argnames=("fns", "domain"))
def _dense_aggregate_core(packed, datas, fns: Tuple[str, ...], domain: int):
    """Factorization *and* every segment reduction in one compiled program.

    Reductions run straight over the packed dense key domain; present
    groups are compacted at the end, so the whole group-by costs a single
    host sync (the group count).  → (counts, outs, rep rows, n_groups),
    all domain-sized with the live groups ascending (= lexicographic) in
    the leading entries.
    """
    n = packed.shape[0]
    counts = jax.ops.segment_sum(jnp.ones((n,)), packed, domain)
    present = counts > 0
    sel = jnp.nonzero(present, size=domain, fill_value=0)[0]
    outs = []
    for fn, data in zip(fns, datas):
        if fn == "avg":
            s = jax.ops.segment_sum(data.astype(jnp.float64), packed, domain)
            res = s / jnp.maximum(counts, 1.0)
        else:
            res = _segment(fn, data, packed, domain)
        outs.append(res[sel])
    first = jax.ops.segment_min(jnp.arange(n), packed, domain)
    return counts[sel], tuple(outs), first[sel], present.sum()


@functools.partial(jax.jit, static_argnames=("fns", "n_groups"))
def _aggregate_core(gids, datas, fns: Tuple[str, ...], n_groups: int):
    """All segment reductions of one group-by in a single compiled program.

    ``datas`` are pre-cast value arrays (ones for counts); ``fns`` are the
    core reductions (sum/min/max/avg).  Cached per (fns, n_groups, shapes).
    """
    counts = jax.ops.segment_sum(jnp.ones(gids.shape[0]), gids, n_groups)
    outs = []
    for fn, data in zip(fns, datas):
        if fn == "avg":
            s = jax.ops.segment_sum(data.astype(jnp.float64), gids, n_groups)
            outs.append(s / jnp.maximum(counts, 1.0))
        else:
            outs.append(_segment(fn, data, gids, n_groups))
    return counts, tuple(outs)


def group_aggregate(
    table: Table, keys: Sequence[str], aggs: Sequence[AggSpec]
) -> Table:
    """Eager hash aggregate (fully device-resident)."""
    n = table.num_rows
    if n == 0 and keys:
        # empty input with keys: zero groups
        empty = jnp.zeros((0,), jnp.int64)
        return Table({**{k: table[k].take(empty) for k in keys},
                      **{a.name: Column(jnp.zeros((0,))) for a in aggs}})

    # eager prep: evaluate value expressions and normalize dtypes, then run
    # every segment reduction in one compiled program
    ones = jnp.ones(n, jnp.int64)
    fns: List[str] = []
    datas: List[jnp.ndarray] = []
    meta: List[Optional[Tuple[str, str, Optional[np.ndarray]]]] = []
    distincts: List[Tuple[str, jnp.ndarray]] = []
    for a in aggs:
        if a.fn == "count_star":
            fns.append("sum"); datas.append(ones)
            meta.append((a.name, NUMERIC, None))
            continue
        col = evaluate(a.expr, table)
        if a.fn == "count":
            fns.append("sum"); datas.append(ones)
            meta.append((a.name, NUMERIC, None))
        elif a.fn in ("sum", "min", "max"):
            data = col.data
            if a.fn == "sum" and data.dtype.kind == "b":
                data = data.astype(jnp.int64)
            if a.fn == "sum" and data.dtype == jnp.float32:
                data = data.astype(jnp.float64)
            fns.append(a.fn); datas.append(data)
            kind = col.kind if a.fn in ("min", "max") else NUMERIC
            meta.append((a.name, kind,
                         col.dictionary if kind == STRING else None))
        elif a.fn == "avg":
            fns.append("avg"); datas.append(col.data)
            meta.append((a.name, NUMERIC, None))
        elif a.fn == "count_distinct":
            distincts.append((a.name, col.data))
            meta.append(None)
        else:
            raise ValueError(f"unknown aggregate {a.fn}")

    arrs = _group_key_arrays(table, keys) if keys and n else None
    dense = _dense_pack(arrs, n) if arrs is not None and not distincts else None
    if dense is not None:
        # dense keys: factorization + reductions fused, a single host sync
        _, results, rep, ng = _dense_aggregate_core(
            dense[0], tuple(datas), tuple(fns), dense[1])
        k = pull_scalar(ng)
        rep_idx = rep[:k]
        uniq = Table({key: table[key].take(rep_idx) for key in keys})
        results = tuple(r[:k] for r in results)
        gids = None
        n_groups = k
    else:
        if arrs is not None:
            # key arrays (and the dense bounds check) already computed above
            gids, rep, ng = _factorize_core(tuple(arrs))
            n_groups = pull_scalar(ng)
            uniq = Table({key: table[key].take(rep[:n_groups])
                          for key in keys})
        else:
            gids = jnp.zeros(n, jnp.int64)
            uniq = Table({})
            n_groups = 1
        _, results = _aggregate_core(gids, tuple(datas), tuple(fns), n_groups)

    out: Dict[str, Column] = {}
    it = iter(results)
    for m in meta:
        if m is None:
            continue
        name, kind, dictionary = m
        out[name] = Column(next(it), kind, dictionary)
    for name, vals in distincts:
        out[name] = Column(_count_distinct(gids, vals, n_groups), NUMERIC)
    # preserve the requested output column order
    return Table({**uniq.columns, **{a.name: out[a.name] for a in aggs}})


# ---------------------------------------------------------------------------
# static-shape aggregate (jit / shard_map / kernel oracle)
# ---------------------------------------------------------------------------


def static_group_aggregate(
    gids: jnp.ndarray,
    valid: jnp.ndarray,
    values: Dict[str, Tuple[str, jnp.ndarray]],
    num_groups: int,
):
    """Masked scatter aggregation with a static group count.

    ``values`` maps output name -> (fn, data array).  Rows with valid=False
    contribute identity elements.  Returns dict of (num_groups,) arrays plus
    ``__count`` (rows per group) and ``__present`` (group non-empty).
    """
    gids = jnp.where(valid, gids, num_groups)  # dump invalid rows past the end
    out = {}
    ones = valid.astype(jnp.float32)
    counts = jax.ops.segment_sum(ones, gids, num_groups + 1)[:-1]
    out["__count"] = counts
    out["__present"] = counts > 0
    for name, (fn, data) in values.items():
        if fn in ("sum", "avg", "count"):
            if fn == "count":
                data = jnp.ones_like(data, jnp.float32)
            contrib = jnp.where(valid, data.astype(jnp.float32), 0)
            s = jax.ops.segment_sum(contrib, gids, num_groups + 1)[:-1]
            out[name] = s / jnp.maximum(counts, 1) if fn == "avg" else s
        elif fn == "min":
            big = jnp.asarray(jnp.finfo(jnp.float32).max, data.dtype)
            contrib = jnp.where(valid, data, big)
            out[name] = jax.ops.segment_min(contrib, gids, num_groups + 1)[:-1]
        elif fn == "max":
            small = jnp.asarray(jnp.finfo(jnp.float32).min, data.dtype)
            contrib = jnp.where(valid, data, small)
            out[name] = jax.ops.segment_max(contrib, gids, num_groups + 1)[:-1]
        else:
            raise ValueError(fn)
    return out
