"""Relational operator substrate (the libcudf analogue, in jnp)."""
from .table import Column, Table, date_to_days, days_to_date, unify_string_keys  # noqa: F401
from .expressions import (  # noqa: F401
    Between, BinOp, Case, Cast, Col, DateLit, Expr, ExtractYear, InList, Like,
    Lit, Substr, UnOp, evaluate, like_to_regex,
)
from .join import StaticHashTable, combine_keys, hash_join  # noqa: F401
from .aggregate import AggSpec, group_aggregate, static_group_aggregate  # noqa: F401
from .sort import SortKey, sort_table  # noqa: F401
