"""Join operators.

Two implementations, per DESIGN.md §2:

* ``hash_join`` — the **eager** path (dynamic output size, like libcudf's
  stream model, but device-resident end to end).  Internally sort-merge on
  factorized keys, exact for arbitrary multiplicity; the match counting and
  run expansion are jit-compiled two-stage (the dynamic output size is the
  single scalar sync between them).  Supports inner / left / semi / anti /
  mark, and doubles as the correctness oracle for the fused probe path.

* ``StaticHashTable`` — the **static-shape** path used inside jit /
  shard_map / Pallas: an atomics-free open-addressing table built with
  deterministic multi-round masked scatter (TPU has no CAS), probed with
  linear probing.  Build keys must be unique (PK side) — TPC-H joins are
  PK-FK; multi-match plans are rewritten to semi/anti/mark + aggregation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .table import BOOL, NUMERIC, STRING, Column, Table, unify_string_keys

# ---------------------------------------------------------------------------
# key factorization (multi-column keys -> single int64 key)
# ---------------------------------------------------------------------------


def _minmax(*arrays) -> Tuple[int, int]:
    """(min, max) over possibly-empty device arrays, as python ints.

    A scalar sync per key column — metadata only, never a column transfer."""
    lo, hi = 0, 0
    for a in arrays:
        if a.shape[0]:
            lo = min(lo, int(a.min()))
            hi = max(hi, int(a.max()))
    return lo, hi


def _as_int_keys(left: Column, right: Column) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bring a (probe, build) key column pair into a shared integer space."""
    if left.kind == STRING or right.kind == STRING:
        left, right = unify_string_keys(left, right)
    l = jnp.asarray(left.data)
    r = jnp.asarray(right.data)
    if l.dtype.kind == "f" or r.dtype.kind == "f":
        # factorize floats exactly via unique over the union (device-side)
        uni = jnp.unique(jnp.concatenate([l, r]))
        l = jnp.searchsorted(uni, l)
        r = jnp.searchsorted(uni, r)
    return l.astype(jnp.int64), r.astype(jnp.int64)


def combine_keys(
    probe_cols: Sequence[Column], build_cols: Sequence[Column]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack multi-column join keys into one int64 key per row (exact)."""
    assert len(probe_cols) == len(build_cols) and probe_cols
    pk, bk = _as_int_keys(probe_cols[0], build_cols[0])
    base_min, _ = _minmax(pk, bk)
    pk, bk = pk - base_min, bk - base_min
    for pc, bc in zip(probe_cols[1:], build_cols[1:]):
        p2, b2 = _as_int_keys(pc, bc)
        m, mx = _minmax(p2, b2)
        p2, b2 = p2 - m, b2 - m
        card = mx - m + 1
        _, hi = _minmax(pk, bk)
        if hi * card > 2**62:
            # re-factorize to dense ranks to avoid overflow
            uni = jnp.unique(jnp.concatenate([pk, bk]))
            pk = jnp.searchsorted(uni, pk)
            bk = jnp.searchsorted(uni, bk)
        pk = pk * card + p2
        bk = bk * card + b2
    return pk, bk


# ---------------------------------------------------------------------------
# eager join (dynamic shapes)
# ---------------------------------------------------------------------------


@jax.jit
def _join_match(pk: jnp.ndarray, bk: jnp.ndarray):
    """Sort-merge match counting (compiled): → (build order, lo, counts)."""
    order = jnp.argsort(bk, stable=True)
    bk_sorted = bk[order]
    lo = jnp.searchsorted(bk_sorted, pk, side="left")
    hi = jnp.searchsorted(bk_sorted, pk, side="right")
    return order, lo, hi - lo


@functools.partial(jax.jit, static_argnames=("total",))
def _join_expand(order, lo, counts, counts_out, total: int):
    """Expand match runs into gather indices (compiled, bucketed ``total``).

    ``total`` is padded to a bucket; ``jnp.repeat`` fills the tail with the
    last value and the caller slices to the true output size.
    """
    n = lo.shape[0]
    probe_idx = jnp.repeat(jnp.arange(n), counts_out,
                           total_repeat_length=total)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts_out.dtype), jnp.cumsum(counts_out[:-1])])
    intra = jnp.arange(total) - jnp.repeat(starts, counts_out,
                                           total_repeat_length=total)
    build_pos = lo[probe_idx] + intra
    matched = counts[probe_idx] > 0
    nb = order.shape[0]
    build_pos = jnp.where(matched, jnp.clip(build_pos, 0, max(nb - 1, 0)), 0)
    build_idx = order[build_pos]
    return probe_idx, build_idx, matched


def _empty_build_join(probe: Table, build: Table, how: str,
                      mark_name: str) -> Table:
    n = probe.num_rows
    if how == "mark":
        return probe.with_column(mark_name,
                                 Column(jnp.zeros((n,), bool), BOOL))
    if how == "anti":
        return probe
    if how == "left":
        out = dict(probe.columns)
        for name, col in build.columns.items():
            if name not in out:
                out[name] = Column(jnp.zeros((n,), col.data.dtype), col.kind,
                                   col.dictionary)
        out["__matched"] = Column(jnp.zeros((n,), bool), BOOL)
        return Table(out)
    # inner / semi: no matches
    empty = jnp.zeros((0,), jnp.int64)
    out = {name: col.take(empty) for name, col in probe.columns.items()}
    if how == "inner":
        for name, col in build.columns.items():
            if name not in out:
                out[name] = col.take(empty)
    return Table(out)


def hash_join(
    probe: Table,
    build: Table,
    probe_keys: Sequence[str],
    build_keys: Sequence[str],
    how: str = "inner",
    mark_name: str = "__mark",
) -> Table:
    """Join ``probe`` against ``build``.

    how = inner | left | semi | anti | mark.
    ``left`` adds a ``__matched`` BOOL column; build columns of unmatched rows
    are garbage (gathered at index 0) and must be guarded by ``__matched``.
    ``mark`` returns the probe table + BOOL ``mark_name`` column (EXISTS / IN).
    """
    if probe.num_rows == 0 or build.num_rows == 0:
        if probe.num_rows == 0 and how in ("inner", "left"):
            out = {n: c for n, c in probe.columns.items()}
            empty = jnp.zeros((0,), jnp.int64)
            for n, c in build.columns.items():
                if n not in out:
                    out[n] = c.take(empty)
            if how == "left":
                out["__matched"] = Column(jnp.zeros((0,), bool), BOOL)
            return Table(out)
        if build.num_rows == 0:
            return _empty_build_join(probe, build, how, mark_name)

    pk, bk = combine_keys([probe[k] for k in probe_keys], [build[k] for k in build_keys])
    order, lo, counts = _join_match(pk, bk)

    if how == "mark":
        return probe.with_column(mark_name, Column(counts > 0, BOOL))
    if how == "semi":
        sel, k = kops.compact(counts > 0)
        return probe.take(sel[: int(k)])
    if how == "anti":
        sel, k = kops.compact(counts == 0)
        return probe.take(sel[: int(k)])

    if how == "left":
        counts_out = jnp.maximum(counts, 1)
    elif how == "inner":
        counts_out = counts
    else:
        raise ValueError(f"unknown join type {how}")

    # dynamic output size: the single scalar sync of the eager join.  The
    # expansion runs compiled with the output padded to a bucket, so repeat
    # executions replay cached programs.
    total = int(counts_out.sum())
    t_pad = kops.bucket_size(total)
    probe_idx, build_idx, matched = _join_expand(order, lo, counts,
                                                 counts_out, t_pad)
    probe_idx = probe_idx[:total]
    build_idx = build_idx[:total]

    out = {}
    for name, col in probe.columns.items():
        out[name] = col.take(probe_idx)
    for name, col in build.columns.items():
        if name in out:  # key columns equal by definition; keep probe copy
            continue
        out[name] = col.take(build_idx)
    if how == "left":
        out["__matched"] = Column(matched[:total], BOOL)
    return Table(out)


# ---------------------------------------------------------------------------
# static-shape open-addressing hash table (jit / shard_map / kernel oracle)
# ---------------------------------------------------------------------------

_MIX = np.int64(-7046029254386353131)  # 0x9E3779B97F4A7C15 as signed int64
EMPTY = jnp.int32(-1)


def _hash(keys: jnp.ndarray, mask: int) -> jnp.ndarray:
    h = (keys.astype(jnp.int64) * _MIX)
    h = h ^ (h >> 29)
    return (h & mask).astype(jnp.int32)


def next_pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 4)


@dataclasses.dataclass
class StaticHashTable:
    """Open-addressing table over unique int keys; fully static shapes.

    slots_key[i]  = key stored in slot i (or -1)
    slots_row[i]  = build-side row index for that key (or -1)
    Built with deterministic multi-round masked scatter (no atomics):
    every unplaced key scatters its row id into its current candidate slot
    with ``.at[].max``; winners are the rows that read their own id back.
    """

    slots_key: jnp.ndarray
    slots_row: jnp.ndarray
    capacity: int
    max_probes: int
    all_placed: Optional[jnp.ndarray] = None  # bool scalar; debug/assert aid

    @staticmethod
    def build(keys: jnp.ndarray, valid: Optional[jnp.ndarray] = None,
              capacity: Optional[int] = None, max_probes: int = 32) -> "StaticHashTable":
        n = keys.shape[0]
        cap = capacity or next_pow2(2 * n)
        mask = cap - 1
        keys = keys.astype(jnp.int64)
        rows = jnp.arange(n, dtype=jnp.int32)
        if valid is None:
            valid = jnp.ones((n,), bool)

        slots_row = jnp.full((cap,), -1, jnp.int32)
        placed = ~valid  # invalid rows are "already placed" (i.e. skipped)
        h0 = _hash(keys, mask)

        def round_body(i, state):
            slots_row, placed = state
            cand = ((h0 + i) & mask).astype(jnp.int32)
            # Contenders scatter-max their row id into a scratch table; the
            # scratch is merged only into slots that are still empty, so
            # earlier winners are never displaced (atomics-free CAS analogue).
            attempt = jnp.where(placed, -1, rows)
            bids = jnp.full((cap,), -1, jnp.int32).at[cand].max(attempt)
            empty = slots_row == -1
            slots_row = jnp.where(empty & (bids >= 0), bids, slots_row)
            won = (~placed) & (slots_row[cand] == rows)
            placed = placed | won
            return slots_row, placed

        slots_row, placed = jax.lax.fori_loop(
            0, max_probes, round_body, (slots_row, placed))
        slots_key = jnp.where(
            slots_row >= 0, keys[jnp.clip(slots_row, 0, n - 1)], jnp.int64(-1))
        return StaticHashTable(slots_key, slots_row, cap, max_probes,
                               jnp.all(placed))

    def lookup(self, probe_keys: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """→ (build_row_idx int32 [-1 if none], found bool). Fully vectorized."""
        mask = self.capacity - 1
        keys = probe_keys.astype(jnp.int64)
        h0 = _hash(keys, mask)

        def body(i, state):
            found_row, done = state
            cand = ((h0 + i) & mask).astype(jnp.int32)
            k = self.slots_key[cand]
            r = self.slots_row[cand]
            hit = (~done) & (k == keys) & (r >= 0)
            miss_empty = (~done) & (r == -1)  # empty slot ⇒ key absent
            found_row = jnp.where(hit, r, found_row)
            done = done | hit | miss_empty
            return found_row, done

        found_row = jnp.full(keys.shape, -1, jnp.int32)
        done = jnp.zeros(keys.shape, bool)
        found_row, done = jax.lax.fori_loop(
            0, self.max_probes, body, (found_row, done))
        return found_row, found_row >= 0


def static_join_gather(
    probe_data: dict, build_data: dict, row_idx: jnp.ndarray, found: jnp.ndarray
):
    """Gather build columns alongside probe columns under a match mask."""
    safe = jnp.clip(row_idx, 0, None)
    out = dict(probe_data)
    for name, arr in build_data.items():
        if name not in out:
            out[name] = jnp.take(arr, safe, axis=0)
    return out, found
