"""Join operators.

Two implementations, per DESIGN.md §2:

* ``hash_join`` — the **eager** path (dynamic output size, like libcudf's
  stream model, but device-resident end to end).  Internally sort-merge on
  factorized keys, exact for arbitrary multiplicity; the match counting and
  run expansion are jit-compiled two-stage (the dynamic output size is the
  single scalar sync between them).  Supports inner / left / semi / anti /
  mark, and doubles as the correctness oracle for the fused probe path.

* ``StaticHashTable`` — the **static-shape** path used inside jit /
  shard_map / Pallas: an atomics-free open-addressing table built with
  deterministic multi-round masked scatter (TPU has no CAS), probed with
  linear probing.  Build keys must be unique (PK side) — TPC-H joins are
  PK-FK; multi-match plans are rewritten to semi/anti/mark + aggregation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.instrument import pull_scalar
from ..kernels import ops as kops
from .table import BOOL, NUMERIC, STRING, Column, Table, unify_string_keys

# ---------------------------------------------------------------------------
# key factorization (multi-column keys -> single int64 key)
# ---------------------------------------------------------------------------


def _minmax(*arrays) -> Tuple[int, int]:
    """(min, max) over possibly-empty device arrays, as python ints.

    A scalar pull per key column — metadata only, never a column transfer;
    recorded/replayed by the plan cache so warm runs skip the sync."""
    lo, hi = 0, 0
    for a in arrays:
        if a.shape[0]:
            lo = min(lo, pull_scalar(a.min()))
            hi = max(hi, pull_scalar(a.max()))
    return lo, hi


def _as_int_keys(left: Column, right: Column) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bring a (probe, build) key column pair into a shared integer space."""
    if left.kind == STRING or right.kind == STRING:
        left, right = unify_string_keys(left, right)
    l = jnp.asarray(left.data)
    r = jnp.asarray(right.data)
    if l.dtype.kind == "f" or r.dtype.kind == "f":
        # factorize floats exactly via unique over the union (device-side);
        # static ``size`` + top-of-range fill keeps the padded array sorted
        # (ranks unchanged) and the whole path jit-traceable for the plan
        # cache's compiled replay
        both = jnp.concatenate([l, r])
        uni = jnp.unique(both, size=both.shape[0], fill_value=jnp.inf)
        l = jnp.searchsorted(uni, l)
        r = jnp.searchsorted(uni, r)
    return l.astype(jnp.int64), r.astype(jnp.int64)


def combine_keys(
    probe_cols: Sequence[Column], build_cols: Sequence[Column]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack multi-column join keys into one int64 key per row (exact)."""
    assert len(probe_cols) == len(build_cols) and probe_cols
    pk, bk = _as_int_keys(probe_cols[0], build_cols[0])
    if len(probe_cols) == 1:
        # sort-merge matching and the open-addressing hash are sign-agnostic:
        # single-key joins need no normalization, hence zero metadata pulls
        return pk, bk
    base_min, _ = _minmax(pk, bk)
    pk, bk = pk - base_min, bk - base_min
    for pc, bc in zip(probe_cols[1:], build_cols[1:]):
        p2, b2 = _as_int_keys(pc, bc)
        m, mx = _minmax(p2, b2)
        p2, b2 = p2 - m, b2 - m
        card = mx - m + 1
        _, hi = _minmax(pk, bk)
        if hi * card > 2**62:
            # re-factorize to dense ranks to avoid overflow (static size +
            # max-int fill: sorted padding, traceable under jit)
            both = jnp.concatenate([pk, bk])
            uni = jnp.unique(both, size=both.shape[0],
                             fill_value=jnp.iinfo(jnp.int64).max)
            pk = jnp.searchsorted(uni, pk)
            bk = jnp.searchsorted(uni, bk)
        pk = pk * card + p2
        bk = bk * card + b2
    return pk, bk


# ---------------------------------------------------------------------------
# eager join (dynamic shapes)
# ---------------------------------------------------------------------------


@jax.jit
def _join_match(pk: jnp.ndarray, bk: jnp.ndarray):
    """Sort-merge match counting (compiled): → (build order, lo, counts)."""
    order = jnp.argsort(bk, stable=True)
    bk_sorted = bk[order]
    lo = jnp.searchsorted(bk_sorted, pk, side="left")
    hi = jnp.searchsorted(bk_sorted, pk, side="right")
    return order, lo, hi - lo


@functools.partial(jax.jit, static_argnames=("total",))
def _join_expand(order, lo, counts, counts_out, total: int):
    """Expand match runs into gather indices (compiled, bucketed ``total``).

    ``total`` is padded to a bucket; ``jnp.repeat`` fills the tail with the
    last value and the caller slices to the true output size.
    """
    n = lo.shape[0]
    probe_idx = jnp.repeat(jnp.arange(n), counts_out,
                           total_repeat_length=total)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts_out.dtype), jnp.cumsum(counts_out[:-1])])
    intra = jnp.arange(total) - jnp.repeat(starts, counts_out,
                                           total_repeat_length=total)
    build_pos = lo[probe_idx] + intra
    matched = counts[probe_idx] > 0
    nb = order.shape[0]
    build_pos = jnp.where(matched, jnp.clip(build_pos, 0, max(nb - 1, 0)), 0)
    build_idx = order[build_pos]
    return probe_idx, build_idx, matched


def _empty_build_join(probe: Table, build: Table, how: str,
                      mark_name: str) -> Table:
    n = probe.num_rows
    if how == "mark":
        return probe.with_column(mark_name,
                                 Column(jnp.zeros((n,), bool), BOOL))
    if how == "anti":
        return probe
    if how == "left":
        out = dict(probe.columns)
        for name, col in build.columns.items():
            if name not in out:
                out[name] = Column(jnp.zeros((n,), col.data.dtype), col.kind,
                                   col.dictionary)
        out["__matched"] = Column(jnp.zeros((n,), bool), BOOL)
        return Table(out)
    # inner / semi: no matches
    empty = jnp.zeros((0,), jnp.int64)
    out = {name: col.take(empty) for name, col in probe.columns.items()}
    if how == "inner":
        for name, col in build.columns.items():
            if name not in out:
                out[name] = col.take(empty)
    return Table(out)


def hash_join(
    probe: Table,
    build: Table,
    probe_keys: Sequence[str],
    build_keys: Sequence[str],
    how: str = "inner",
    mark_name: str = "__mark",
    backend=None,
) -> Table:
    """Join ``probe`` against ``build``.

    how = inner | left | semi | anti | mark.
    ``left`` adds a ``__matched`` BOOL column; build columns of unmatched rows
    are garbage (gathered at index 0) and must be guarded by ``__matched``.
    ``mark`` returns the probe table + BOOL ``mark_name`` column (EXISTS / IN).

    The dynamic output size is a ``pull_scalar`` — counted on cold runs,
    replayed sync-free by the executable-plan cache on warm runs.  With a
    kernel ``backend`` attached the run expansion routes to the Pallas
    ``join_expand`` kernel (same bucketed shapes, same gather semantics).
    """
    if probe.num_rows == 0 or build.num_rows == 0:
        if probe.num_rows == 0 and how in ("inner", "left"):
            out = {n: c for n, c in probe.columns.items()}
            empty = jnp.zeros((0,), jnp.int64)
            for n, c in build.columns.items():
                if n not in out:
                    out[n] = c.take(empty)
            if how == "left":
                out["__matched"] = Column(jnp.zeros((0,), bool), BOOL)
            return Table(out)
        if build.num_rows == 0:
            return _empty_build_join(probe, build, how, mark_name)

    pk, bk = combine_keys([probe[k] for k in probe_keys], [build[k] for k in build_keys])
    order, lo, counts = _join_match(pk, bk)

    if how == "mark":
        return probe.with_column(mark_name, Column(counts > 0, BOOL))
    if how == "semi":
        sel, k = kops.compact(counts > 0)
        return probe.take(sel[: pull_scalar(k)])
    if how == "anti":
        sel, k = kops.compact(counts == 0)
        return probe.take(sel[: pull_scalar(k)])

    if how == "left":
        counts_out = jnp.maximum(counts, 1)
    elif how == "inner":
        counts_out = counts
    else:
        raise ValueError(f"unknown join type {how}")

    # dynamic output size: the single scalar pull of the eager join
    # (recorded cold / replayed sync-free warm).  The expansion runs
    # compiled with the output padded to a bucket, so repeat executions
    # replay cached programs.
    total = pull_scalar(counts_out.sum())
    t_pad = kops.bucket_size(total)
    probe_idx = build_idx = matched = None
    if backend is not None:
        expanded = backend.try_expand(order, lo, counts, counts_out, t_pad)
        if expanded is not None:
            probe_idx, build_idx, matched = expanded
    if probe_idx is None:
        probe_idx, build_idx, matched = _join_expand(order, lo, counts,
                                                     counts_out, t_pad)
    probe_idx = probe_idx[:total]
    build_idx = build_idx[:total]

    out = {}
    for name, col in probe.columns.items():
        out[name] = col.take(probe_idx)
    for name, col in build.columns.items():
        if name in out:  # key columns equal by definition; keep probe copy
            continue
        out[name] = col.take(build_idx)
    if how == "left":
        out["__matched"] = Column(matched[:total], BOOL)
    return Table(out)


def hash_join_bounded(
    probe: Table,
    build: Table,
    probe_keys: Sequence[str],
    build_keys: Sequence[str],
    capacity: int,
    how: str = "inner",
) -> Tuple[Table, jnp.ndarray, jnp.ndarray]:
    """Sync-free inner/left join under a conservative cardinality cap.

    The stats-layer ``capacity`` (an upper bound on the join's output
    cardinality, e.g. ``optimizer.stats.estimate`` with headroom) replaces
    the dynamic-size pull entirely: the output is allocated at the padded
    cap, surviving rows are flagged by ``valid``, and ``overflow`` is a
    device bool that is true iff the true match count exceeded ``capacity``
    (rows were dropped — the caller must fall back to ``hash_join``).
    Nothing here touches the host and all three return values are lazy
    (multi-column keys are the one exception: packing them pulls per-column
    min/max metadata scalars, recorded/replayed by the plan cache).

    Returns ``(padded_table, valid_mask, overflow_flag)``; the padded table
    has exactly ``bucket_size(capacity)`` rows.
    """
    if how not in ("inner", "left"):
        raise ValueError(f"hash_join_bounded supports inner/left, got {how}")
    if probe.num_rows == 0 or build.num_rows == 0:
        joined = hash_join(probe, build, probe_keys, build_keys, how)
        cap = kops.bucket_size(max(int(capacity), 1))
        if joined.num_rows == 0:
            out = {n: Column(jnp.zeros((cap,), c.data.dtype), c.kind,
                             c.dictionary)
                   for n, c in joined.columns.items()}
        else:
            pad = jnp.minimum(jnp.arange(cap), joined.num_rows - 1)
            out = {n: c.take(pad) for n, c in joined.columns.items()}
        valid = jnp.arange(cap) < joined.num_rows
        return Table(out), valid, jnp.asarray(joined.num_rows > cap)

    pk, bk = combine_keys([probe[k] for k in probe_keys],
                          [build[k] for k in build_keys])
    order, lo, counts = _join_match(pk, bk)
    counts_out = jnp.maximum(counts, 1) if how == "left" else counts
    total = counts_out.sum()
    cap = kops.bucket_size(max(int(capacity), 1))
    overflow = total > cap
    probe_idx, build_idx, matched = _join_expand(order, lo, counts,
                                                 counts_out, cap)
    # rows past the true total are jnp.repeat tail fill: mask them out
    valid = jnp.arange(cap) < total
    out = {}
    for name, col in probe.columns.items():
        out[name] = col.take(probe_idx)
    for name, col in build.columns.items():
        if name in out:
            continue
        out[name] = col.take(build_idx)
    if how == "left":
        out["__matched"] = Column(matched, BOOL)
    return Table(out), valid, overflow


# ---------------------------------------------------------------------------
# static-shape open-addressing hash table (jit / shard_map / kernel oracle)
# ---------------------------------------------------------------------------

_MIX = np.int64(-7046029254386353131)  # 0x9E3779B97F4A7C15 as signed int64
EMPTY = jnp.int32(-1)


def _hash(keys: jnp.ndarray, mask: int) -> jnp.ndarray:
    h = (keys.astype(jnp.int64) * _MIX)
    h = h ^ (h >> 29)
    return (h & mask).astype(jnp.int32)


def next_pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 4)


@dataclasses.dataclass
class StaticHashTable:
    """Open-addressing table over unique int keys; fully static shapes.

    slots_key[i]  = key stored in slot i (or -1)
    slots_row[i]  = build-side row index for that key (or -1)
    Built with deterministic multi-round masked scatter (no atomics):
    every unplaced key scatters its row id into its current candidate slot
    with ``.at[].max``; winners are the rows that read their own id back.
    """

    slots_key: jnp.ndarray
    slots_row: jnp.ndarray
    capacity: int
    max_probes: int
    all_placed: Optional[jnp.ndarray] = None  # bool scalar; debug/assert aid

    @staticmethod
    def build(keys: jnp.ndarray, valid: Optional[jnp.ndarray] = None,
              capacity: Optional[int] = None, max_probes: int = 32) -> "StaticHashTable":
        n = keys.shape[0]
        cap = capacity or next_pow2(2 * n)
        mask = cap - 1
        keys = keys.astype(jnp.int64)
        rows = jnp.arange(n, dtype=jnp.int32)
        if valid is None:
            valid = jnp.ones((n,), bool)

        slots_row = jnp.full((cap,), -1, jnp.int32)
        placed = ~valid  # invalid rows are "already placed" (i.e. skipped)
        h0 = _hash(keys, mask)

        def round_body(i, state):
            slots_row, placed = state
            cand = ((h0 + i) & mask).astype(jnp.int32)
            # Contenders scatter-max their row id into a scratch table; the
            # scratch is merged only into slots that are still empty, so
            # earlier winners are never displaced (atomics-free CAS analogue).
            attempt = jnp.where(placed, -1, rows)
            bids = jnp.full((cap,), -1, jnp.int32).at[cand].max(attempt)
            empty = slots_row == -1
            slots_row = jnp.where(empty & (bids >= 0), bids, slots_row)
            won = (~placed) & (slots_row[cand] == rows)
            placed = placed | won
            return slots_row, placed

        slots_row, placed = jax.lax.fori_loop(
            0, max_probes, round_body, (slots_row, placed))
        slots_key = jnp.where(
            slots_row >= 0, keys[jnp.clip(slots_row, 0, n - 1)], jnp.int64(-1))
        return StaticHashTable(slots_key, slots_row, cap, max_probes,
                               jnp.all(placed))

    def lookup(self, probe_keys: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """→ (build_row_idx int32 [-1 if none], found bool). Fully vectorized."""
        mask = self.capacity - 1
        keys = probe_keys.astype(jnp.int64)
        h0 = _hash(keys, mask)

        def body(i, state):
            found_row, done = state
            cand = ((h0 + i) & mask).astype(jnp.int32)
            k = self.slots_key[cand]
            r = self.slots_row[cand]
            hit = (~done) & (k == keys) & (r >= 0)
            miss_empty = (~done) & (r == -1)  # empty slot ⇒ key absent
            found_row = jnp.where(hit, r, found_row)
            done = done | hit | miss_empty
            return found_row, done

        found_row = jnp.full(keys.shape, -1, jnp.int32)
        done = jnp.zeros(keys.shape, bool)
        found_row, done = jax.lax.fori_loop(
            0, self.max_probes, body, (found_row, done))
        return found_row, found_row >= 0


def static_join_gather(
    probe_data: dict, build_data: dict, row_idx: jnp.ndarray, found: jnp.ndarray
):
    """Gather build columns alongside probe columns under a match mask."""
    safe = jnp.clip(row_idx, 0, None)
    out = dict(probe_data)
    for name, arr in build_data.items():
        if name not in out:
            out[name] = jnp.take(arr, safe, axis=0)
    return out, found
