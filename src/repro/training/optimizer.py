"""AdamW + mixed precision + distributed-optimization tricks (pure JAX).

Includes the large-scale training substrate the assignment requires:
  * FSDP-compatible: optimizer states mirror param shardings (GSPMD shards
    them with the params — ZeRO-equivalent when params are ('data','model')
    sharded).
  * gradient clipping (global norm) and cosine LR schedule;
  * **int8 gradient compression** with error feedback for the data-parallel
    all-reduce (optional) — the distributed-optimization trick recorded in
    EXPERIMENTS.md; the compression is applied around `jax.lax.psum` when the
    train step runs under shard_map, and validated numerically in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import compat


@dataclasses.dataclass
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.betas

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** step)
        nu_hat = nu / (1 - b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (data-parallel all-reduce)
# ---------------------------------------------------------------------------


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization → (q, scale)."""
    absmax = jnp.max(jnp.abs(g)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def psum_compressed(grads, axis: str, error_state):
    """All-reduce int8-compressed grads with error feedback.

    error_state carries the per-tensor quantization residual; adding it back
    before quantizing keeps the compressed optimizer unbiased over steps.
    Returns (mean-reduced grads, new error_state).  8x fewer exchange bytes
    than f32 psum, 2x fewer than bf16.
    """
    n = compat.axis_size(axis)

    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        new_err = g32 - deq
        # int8 payloads sum in int32 to avoid overflow across the axis
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        scale_sum = jax.lax.psum(scale, axis)  # scales differ per shard:
        # use mean scale approximation (error feedback absorbs the bias)
        reduced = summed.astype(jnp.float32) * (scale_sum / n) / n
        return reduced, new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
