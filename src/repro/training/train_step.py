"""Train step assembly + sharding rules for the production mesh.

`make_train_step(cfg)` returns a pure (state, batch) -> (state, metrics)
function; `sharding_rules` maps every param/state leaf to a PartitionSpec for
GSPMD (FSDP over 'data' x TP over 'model'; the optional leading scan/expert
dims stay unsharded or go to 'model' for experts).  The multi-pod mesh adds a
'pod' axis folded into data parallelism.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import lm
from .optimizer import OptConfig, adamw_update, init_opt_state


def init_train_state(rng, cfg: ArchConfig):
    params = lm.init_params(rng, cfg)
    return {"params": params, "opt": init_opt_state(params)}


def make_train_step(cfg: ArchConfig, opt_cfg: Optional[OptConfig] = None):
    opt_cfg = opt_cfg or OptConfig()

    def train_step(state, batch):
        def loss(p):
            return lm.loss_fn(p, cfg, batch)

        l, grads = jax.value_and_grad(loss)(state["params"])
        new_params, new_opt, gnorm = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = {"loss": l, "grad_norm": gnorm}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding rules (GSPMD): path-pattern → PartitionSpec
# ---------------------------------------------------------------------------

_DATA = "data"
_MODEL = "model"


def _spec_for(path: str, ndim: int, fsdp_axes) -> P:
    """Name-based rules; `extra` leading dims (scan periods / experts) map to
    None.  fsdp_axes=None gives TP-only sharding (serving); on the multi-pod
    mesh fsdp_axes=('pod','data') folds the pod axis into FSDP."""
    d = fsdp_axes if fsdp_axes else None
    leaf = path.split("/")[-1]

    def pad(spec_tail):
        return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))

    if leaf in ("embed",):
        return P(_MODEL, None)                      # vocab-sharded
    if leaf in ("head",):
        return P(None, _MODEL) if ndim == 2 else pad([None, _MODEL])
    if leaf in ("wq", "wk", "wv", "wg", "wu", "win", "wx", "router",
                "wdkv", "wuk", "wuv", "w1"):
        return pad([d, _MODEL])                     # col-parallel
    if leaf in ("wo", "wd", "wout", "wdt", "w2"):
        return pad([_MODEL, d])                     # row-parallel
    if leaf in ("bq", "bk", "bv"):
        return pad([_MODEL])
    if leaf in ("conv",):
        return pad([None, _MODEL])
    if leaf in ("dt_bias", "d_skip"):
        return pad([_MODEL])
    if leaf in ("a_log",):
        return pad([_MODEL, None])
    if leaf in ("enc_pos", "dec_pos"):
        return pad([None, None])
    # norms and scalars: replicated (leading scan dims included)
    return P(*([None] * ndim))


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def param_shardings(params, mesh, fsdp: bool = True,
                    n_experts: Optional[int] = None):
    """Pytree of NamedShardings mirroring `params` (also used for opt state).

    MoE expert tensors (..., E, d, ff): experts sharded over 'model' (EP) and
    rows over the fsdp axes.  Disambiguated from scanned dense FFN weights by
    matching the expert-count dim (`n_experts`).
    """
    from jax.sharding import NamedSharding

    fsdp_axes = None
    if fsdp:
        axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        fsdp_axes = axes if len(axes) > 1 else axes[0]

    def leaf_spec(path, x):
        leaf = path.split("/")[-1]
        nd = x.ndim
        base = path.split("/")
        if (leaf in ("wg", "wu", "wd") and nd >= 3 and "ffn" in base
                and n_experts is not None and x.shape[-3] == n_experts):
            # expert-parallel: (..., E, d, ff) → experts on 'model'
            tail = [_MODEL, fsdp_axes, None]
            return P(*([None] * (nd - 3) + tail))
        return _spec_for(path, nd, fsdp_axes)

    flat = list(_walk(params))
    specs = {path: leaf_spec(path, x) for path, x in flat}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(out)
        return NamedSharding(mesh, specs[prefix])

    return rebuild(params)


def state_shardings(state, mesh, fsdp: bool = True,
                    n_experts: Optional[int] = None):
    from jax.sharding import NamedSharding
    p = param_shardings(state["params"], mesh, fsdp, n_experts)
    return {"params": p,
            "opt": {"mu": p, "nu": p,
                    "step": NamedSharding(mesh, P())}}


def batch_shardings(batch_struct, mesh):
    """Batch dims shard over ('pod','data') when the pod axis exists."""
    from jax.sharding import NamedSharding
    axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    data_axes = axes if len(axes) > 1 else axes[0]

    def spec(x):
        return NamedSharding(mesh, P(data_axes, *([None] * (x.ndim - 1))))

    return jax.tree.map(spec, batch_struct)
