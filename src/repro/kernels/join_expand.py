"""Pallas TPU kernel: hash-join run expansion.

The eager join's second stage (``relational.join._join_expand``) turns
per-probe-row match runs into gather indices: output position ``j`` belongs
to the probe row ``p`` whose run ``[starts[p], starts[p] + counts[p])``
covers ``j``.  The jnp formulation leans on ``jnp.repeat`` (a host-lowered
scatter pattern); this kernel is the device-native version widening the
kernel tier's join coverage beyond unique-key probes: each grid step owns a
tile of *output* positions and locates its probe row with a vectorized
binary search over the run-start prefix sums — every search round is a
dense VMEM gather + compare across the tile, no per-row control flow.

Shapes are static: the caller buckets the output length (``total`` padded
to a power of two) exactly like ``_join_expand``, so repeated executions
replay one compiled program.  Tail positions past the true total resolve to
the last run and are sliced/masked off by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
INT32_SENTINEL = 2147483647  # python int: kernels must not capture device constants


def _iota(n: int) -> jnp.ndarray:
    # 2D iota + squeeze: 1D iota fails to lower on real TPUs
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).squeeze(-1)


def _kernel(starts_ref, lo_ref, counts_ref, probe_ref, pos_ref, matched_ref,
            *, search_rounds: int, build_rows: int):
    j = pl.program_id(0) * TILE + _iota(TILE)      # global output positions

    def step(_, state):
        low, high = state                 # invariant: starts[low] <= j
        mid = (low + high) // 2
        s = jnp.take(starts_ref[...], mid)
        go_right = s <= j
        low = jnp.where(go_right, mid, low)
        high = jnp.where(go_right, high, mid)
        return low, high

    low = jnp.zeros((TILE,), jnp.int32)
    high = jnp.full((TILE,), starts_ref.shape[0], jnp.int32)
    low, _ = jax.lax.fori_loop(0, search_rounds, step, (low, high))

    intra = j - jnp.take(starts_ref[...], low)
    matched = jnp.take(counts_ref[...], low) > 0
    pos = jnp.take(lo_ref[...], low) + intra
    pos = jnp.where(matched, jnp.clip(pos, 0, max(build_rows - 1, 0)), 0)
    probe_ref[...] = low
    pos_ref[...] = pos
    matched_ref[...] = matched


@functools.partial(jax.jit, static_argnames=("total", "interpret"))
def join_expand(order, lo, counts, counts_out, total: int,
                interpret: bool = True):
    """Expand match runs into gather indices (kernel-tier ``_join_expand``).

    Same signature and semantics as ``relational.join._join_expand``:
    ``total`` is the bucketed output length; returns
    ``(probe_idx, build_idx, matched)`` of length ``total``, tail garbage
    past the true output size included (the caller slices or masks).
    """
    n = lo.shape[0]
    nb = order.shape[0]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts_out.dtype), jnp.cumsum(counts_out[:-1])])
    n_pad = max(((n + TILE - 1) // TILE) * TILE, TILE)
    # padded runs start past every real position, so the search never lands there
    starts_p = jnp.full((n_pad,), INT32_SENTINEL, jnp.int32).at[:n].set(
        starts.astype(jnp.int32))
    lo_p = jnp.zeros((n_pad,), jnp.int32).at[:n].set(lo.astype(jnp.int32))
    counts_p = jnp.zeros((n_pad,), jnp.int32).at[:n].set(
        counts.astype(jnp.int32))
    out_pad = max(((total + TILE - 1) // TILE) * TILE, TILE)
    search_rounds = max(n_pad.bit_length(), 1)

    probe_idx, pos, matched = pl.pallas_call(
        functools.partial(_kernel, search_rounds=search_rounds,
                          build_rows=nb),
        grid=(out_pad // TILE,),
        in_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),   # starts: whole, VMEM
            pl.BlockSpec((n_pad,), lambda i: (0,)),
            pl.BlockSpec((n_pad,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_pad,), jnp.int32),
            jax.ShapeDtypeStruct((out_pad,), jnp.int32),
            jax.ShapeDtypeStruct((out_pad,), jnp.bool_),
        ],
        interpret=interpret,
    )(starts_p, lo_p, counts_p)
    build_idx = jnp.take(order, pos[:total].astype(jnp.int64))
    return probe_idx[:total].astype(jnp.int64), build_idx, matched[:total]
