"""Pallas TPU kernel: fused multi-column range-predicate filter.

The GPU hot path the paper identifies for filter-heavy queries (Q6/Q19) is a
chain of libcudf calls, each materializing a boolean column in HBM.  The TPU
adaptation fuses the whole conjunction into one VMEM pass: C columns stream
through the VPU, the mask and per-tile selected counts come out in a single
kernel — one read of the data instead of C+1.

Compaction itself (dynamic output size) is done by the ops.py wrapper at the
XLA level (argsort of ~mask — the TPU-idiomatic compaction; GPU engines use
warp-ballot + prefix-sum which has no TPU analogue, see DESIGN.md).

Predicate form: AND over columns of (lo_c <= x_c <= hi_c).  Equality is
lo == hi; one-sided ranges pass ±inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048


def _kernel(cols_ref, lo_ref, hi_ref, mask_ref, count_ref):
    x = cols_ref[...]                      # (TILE, C)
    lo = lo_ref[...]                       # (1, C)
    hi = hi_ref[...]
    m = jnp.all((x >= lo) & (x <= hi), axis=1)   # (TILE,)
    mask_ref[...] = m
    # dtype pinned: with jax_enable_x64 a bare sum promotes to int64 and the
    # int32 output ref rejects the store
    count_ref[0] = jnp.sum(m, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def filter_mask_counts(cols: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                       interpret: bool = True):
    """cols (N, C) f32, lo/hi (C,) → (mask bool[N], per-tile counts)."""
    n, c = cols.shape
    n_pad = ((n + TILE - 1) // TILE) * TILE
    cols_p = jnp.full((n_pad, c), jnp.float32(jnp.inf)).at[:n].set(
        cols.astype(jnp.float32))
    mask, counts = pl.pallas_call(
        _kernel,
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
            jax.ShapeDtypeStruct((n_pad // TILE,), jnp.int32),
        ],
        interpret=interpret,
    )(cols_p, lo.astype(jnp.float32)[None, :], hi.astype(jnp.float32)[None, :])
    return mask[:n], counts
