"""Pallas TPU kernel: partitioned group-by aggregation.

GPU engines (libcudf) aggregate with atomic adds into a hash table — the
paper's §4.2 even observes contention pain for low-cardinality groups.  TPUs
have no atomics; the TPU-native adaptation is **aggregation as matmul**:

    one_hot(gids_tile, G) : (TILE, G)   contributions matrix
    acc += values_tile @ one_hot        -> runs on the MXU

The grid is sequential on TPU, so a single VMEM accumulator block is reused
across grid steps (init at step 0) — deterministic, contention-free, and the
hot loop is systolic-matmul work instead of scattered memory traffic.  Low
cardinality (the GPU's worst case) is the MXU's *best* case.

Layout: TILE rows per grid step; G (group count) padded to a lane multiple
(128).  Invalid rows carry gid == G_pad (one_hot maps them to zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
LANE = 128


def _kernel(gids_ref, vals_ref, acc_ref, *, n_groups_padded: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gids = gids_ref[...]                       # (TILE,)
    vals = vals_ref[...]                       # (TILE, V)
    # (TILE, G) one-hot contribution matrix; out-of-range gids vanish.
    onehot = (gids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (TILE, n_groups_padded), 1)).astype(vals.dtype)
    # (V, TILE) @ (TILE, G) -> (V, G) on the MXU
    acc_ref[...] += jnp.dot(vals.T, onehot,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_groups", "interpret"))
def groupby_sum(gids: jnp.ndarray, values: jnp.ndarray, n_groups: int,
                interpret: bool = True) -> jnp.ndarray:
    """Segment-sum ``values`` (N, V) by ``gids`` (N,) → (n_groups, V).

    Rows with gid outside [0, n_groups) are dropped (use for validity
    masking).  N is padded to TILE internally.
    """
    n = gids.shape[0]
    v = values.shape[1]
    g_pad = ((n_groups + LANE - 1) // LANE) * LANE
    n_pad = ((n + TILE - 1) // TILE) * TILE
    gids_p = jnp.full((n_pad,), g_pad, jnp.int32).at[:n].set(
        gids.astype(jnp.int32))
    vals_p = jnp.zeros((n_pad, v), jnp.float32).at[:n].set(
        values.astype(jnp.float32))

    acc = pl.pallas_call(
        functools.partial(_kernel, n_groups_padded=g_pad),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((v, g_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((v, g_pad), jnp.float32),
        interpret=interpret,
    )(gids_p, vals_p)
    return acc.T[:n_groups]
