"""Pallas TPU kernel: hash-join probe.

The paper's dominant operator (Fig. 5): probing a build-side hash table.
cuDF probes with CAS-free reads but thread-per-row control flow; the TPU
adaptation keeps the whole open-addressing table resident in VMEM (it is the
hot, reused structure) and probes a tile of keys per grid step with
fixed-round vectorized linear probing — every round is a dense VMEM gather +
compare across the tile, no per-row branching.

Table layout: capacity a power of two; `slots_key[i]` int32 key or -1,
`slots_row[i]` build row or -1.  Probe chains terminate at an empty slot
(guaranteed by the deterministic multi-round scatter build, see
relational/join.py).  Keys are int32 — the ops wrapper re-factorizes wider
keys into partition-local int32 space before calling in (documented TPU
adaptation: 32-bit lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
MIX32 = -1640531527  # 0x9E3779B9 golden-ratio mix, 32-bit (python int: pallas
                     # kernels must not capture device constants)


def _hash(keys: jnp.ndarray, mask: int) -> jnp.ndarray:
    h = keys * jnp.int32(MIX32)
    h = h ^ (h >> 15)
    return h & mask


@functools.partial(jax.jit, static_argnames=("capacity", "max_probes"))
def build_table32(keys32: jnp.ndarray, valid: jnp.ndarray | None = None,
                  capacity: int | None = None, max_probes: int = 32):
    """Build the open-addressing table the kernel probes (32-bit hash).

    Same deterministic multi-round masked-scatter as
    relational.join.StaticHashTable.build but over the kernel's hash
    function, so build and probe walk identical chains.  ``valid`` masks
    padding rows (they never place), so callers can bucket input shapes and
    reuse this jit-compiled build across executions.
    Returns (slots_key int32, slots_row int32, all_placed bool).
    """
    n = keys32.shape[0]
    cap = capacity or (1 << max(int(2 * n - 1).bit_length(), 4))
    mask = cap - 1
    keys32 = keys32.astype(jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)
    h0 = _hash(keys32, mask)

    def round_body(i, state):
        slots_row, placed = state
        cand = ((h0 + i) & mask).astype(jnp.int32)
        attempt = jnp.where(placed, -1, rows)
        bids = jnp.full((cap,), -1, jnp.int32).at[cand].max(attempt)
        empty = slots_row == -1
        slots_row = jnp.where(empty & (bids >= 0), bids, slots_row)
        won = (~placed) & (slots_row[cand] == rows)
        placed = placed | won
        return slots_row, placed

    slots_row = jnp.full((cap,), -1, jnp.int32)
    placed = (jnp.zeros((n,), bool) if valid is None else ~valid)
    slots_row, placed = jax.lax.fori_loop(0, max_probes, round_body,
                                          (slots_row, placed))
    slots_key = jnp.where(slots_row >= 0,
                          keys32[jnp.clip(slots_row, 0, n - 1)],
                          jnp.int32(-1))
    return slots_key, slots_row, jnp.all(placed)


def _kernel(probe_ref, slots_key_ref, slots_row_ref, row_ref, found_ref,
            *, capacity: int, max_probes: int):
    keys = probe_ref[...]                          # (TILE,)
    mask = capacity - 1
    h0 = _hash(keys, mask)

    def body(i, state):
        row, done = state
        cand = (h0 + i) & mask
        k = jnp.take(slots_key_ref[...], cand)
        r = jnp.take(slots_row_ref[...], cand)
        hit = (~done) & (k == keys) & (r >= 0)
        empty = (~done) & (r == -1)
        row = jnp.where(hit, r, row)
        done = done | hit | empty
        return row, done

    row = jnp.full((TILE,), -1, jnp.int32)
    done = jnp.zeros((TILE,), jnp.bool_)
    row, done = jax.lax.fori_loop(0, max_probes, body, (row, done))
    row_ref[...] = row
    found_ref[...] = row >= 0


@functools.partial(jax.jit, static_argnames=("max_probes", "interpret"))
def hash_probe(probe_keys: jnp.ndarray, slots_key: jnp.ndarray,
               slots_row: jnp.ndarray, max_probes: int = 32,
               interpret: bool = True):
    """Probe int32 keys against a VMEM-resident table → (row idx, found)."""
    n = probe_keys.shape[0]
    cap = slots_key.shape[0]
    n_pad = ((n + TILE - 1) // TILE) * TILE
    probe_p = jnp.full((n_pad,), -2, jnp.int32).at[:n].set(
        probe_keys.astype(jnp.int32))
    row, found = pl.pallas_call(
        functools.partial(_kernel, capacity=cap, max_probes=max_probes),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((cap,), lambda i: (0,)),   # whole table in VMEM
            pl.BlockSpec((cap,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        ],
        interpret=interpret,
    )(probe_p, slots_key.astype(jnp.int32), slots_row.astype(jnp.int32))
    return row[:n], found[:n]
