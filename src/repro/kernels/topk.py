"""Pallas TPU kernel: tie-stable top-k for ORDER BY ... LIMIT.

Order-by in analytical plans is almost always a small-k selection over a
post-aggregation table (the paper's observation that sort never dominates),
yet the generic path lexsorts the whole input.  This kernel widens the
Pallas tier to that shape: each grid step owns a TILE of rows and selects
its local k smallest ``(key, row)`` pairs with a fixed-round vectorized
argmin loop — ties break toward the smallest original row index, matching
``jnp.lexsort``'s stability so kernel results are row-exact against the
generic sort.  A tiny jnp merge of the per-block candidates (num_blocks*k
elements) picks the global winners.

Keys are f32 (the backend enforces the same 2^24 integer-exactness bound as
the filter kernel); descending orders negate keys on the way in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
INT32_SENTINEL = 2147483647
F32_INF = float("inf")


def _iota(n: int) -> jnp.ndarray:
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).squeeze(-1)


def _kernel(keys_ref, out_keys_ref, out_idx_ref, *, k: int, n: int):
    base = pl.program_id(0) * TILE
    idxs = base + _iota(TILE)
    keys = keys_ref[...]
    # padding rows never win
    keys = jnp.where(idxs < n, keys, F32_INF)

    def step(t, state):
        keys_m, out_keys, out_idx = state
        m = jnp.min(keys_m)
        # smallest row index among the minimum keys: the stable tie-break
        cand = jnp.where(keys_m == m, idxs, INT32_SENTINEL)
        i = jnp.min(cand)
        out_keys = jax.lax.dynamic_update_index_in_dim(out_keys, m, t, 0)
        out_idx = jax.lax.dynamic_update_index_in_dim(out_idx, i, t, 0)
        keys_m = jnp.where(idxs == i, F32_INF, keys_m)
        return keys_m, out_keys, out_idx

    out_keys = jnp.full((k,), F32_INF, jnp.float32)
    out_idx = jnp.full((k,), INT32_SENTINEL, jnp.int32)
    _, out_keys, out_idx = jax.lax.fori_loop(0, k, step,
                                             (keys, out_keys, out_idx))
    out_keys_ref[...] = out_keys
    out_idx_ref[...] = out_idx


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select(keys: jnp.ndarray, k: int, interpret: bool = True):
    """Indices of the k smallest f32 keys, ties broken by row order.

    Returns int32 row indices in ascending ``(key, row)`` order — exactly
    the first k entries a stable ascending sort would produce.
    """
    n = keys.shape[0]
    n_pad = max(((n + TILE - 1) // TILE) * TILE, TILE)
    keys_p = jnp.full((n_pad,), F32_INF, jnp.float32).at[:n].set(
        keys.astype(jnp.float32))
    blocks = n_pad // TILE
    cand_keys, cand_idx = pl.pallas_call(
        functools.partial(_kernel, k=k, n=n),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * k,), jnp.float32),
            jax.ShapeDtypeStruct((blocks * k,), jnp.int32),
        ],
        interpret=interpret,
    )(keys_p)
    # global merge over num_blocks*k candidates (tiny): stable (key, row)
    order = jnp.lexsort((cand_idx, cand_keys))
    return cand_idx[order[:k]]
