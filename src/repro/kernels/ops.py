"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret mode
executes the kernel body exactly).  On real TPU deployments pass
``interpret=False`` — the pallas_call lowering path is identical.

The wrappers own the TPU-adaptation glue documented in DESIGN.md:
  * ``compact``            — argsort-based compaction (the TPU answer to
                             warp-ballot compaction; stable, vectorizes).
  * ``hash_probe_int64``   — re-factorizes int64 packed keys into the int32
                             lane width the kernel wants.
  * ``groupby_sum_large``  — partitions group space when G exceeds the VMEM
                             accumulator budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .decode_attention import decode_attention
from .filter_count import filter_mask_counts
from .groupby_agg import groupby_sum
from .hash_probe import build_table32, hash_probe
from .join_expand import join_expand
from .topk import topk_select

__all__ = [
    "bucket_size", "build_table32", "compact", "decode_attention",
    "direct_build", "direct_lookup", "factorize_keys_int32",
    "factorize_keys_int32_device", "filter_mask_counts", "filter_select",
    "groupby_sum", "groupby_sum_large", "hash_probe", "hash_probe_int64",
    "join_expand", "key_bounds", "map_probe_keys", "pad_rows",
    "sorted_build", "sorted_lookup", "topk_select",
]

_GROUP_BUDGET = 4096  # VMEM accumulator rows per kernel call
KEY_SENTINEL = jnp.iinfo(jnp.int64).max  # pads sorted key arrays


def bucket_size(n: int, minimum: int = 8) -> int:
    """Pad row counts to powers of two so jit shape keys are reused."""
    if n <= minimum:
        return minimum
    return 1 << int(n - 1).bit_length()


def pad_rows(arr: jnp.ndarray, b: int) -> jnp.ndarray:
    """Zero-pad the leading axis to ``b`` rows (device-side)."""
    n = arr.shape[0]
    if n == b:
        return arr
    return jnp.concatenate([arr, jnp.zeros((b - n,) + arr.shape[1:],
                                           arr.dtype)])


@jax.jit
def sorted_build(keys_padded: jnp.ndarray, valid: jnp.ndarray):
    """Sort-based join build over sentinel-padded int64 keys (jit-cached).

    → (sorted keys with KEY_SENTINEL tail, original-row order int32,
       rank per input row int32, duplicate-key flag, sentinel-collision
    flag).  The sorted array doubles as the dense factorization (rank ==
    position), so probe keys map through ``map_probe_keys`` /
    ``sorted_lookup`` with no extra pass.  Both flags come back as device
    scalars so the caller pays a single sync for all build metadata.
    """
    nb = keys_padded.shape[0]
    masked = jnp.where(valid, keys_padded, KEY_SENTINEL)
    order = jnp.argsort(masked)              # valid keys first, pads last
    s = masked[order]
    if nb > 1:
        dup = jnp.any((s[1:] == s[:-1]) & (s[1:] != KEY_SENTINEL))
    else:
        dup = jnp.zeros((), bool)
    sentinel_hit = jnp.any(valid & (keys_padded == KEY_SENTINEL))
    ranks = jnp.zeros((nb,), jnp.int32).at[order].set(
        jnp.arange(nb, dtype=jnp.int32))
    return s, order.astype(jnp.int32), ranks, dup, sentinel_hit


@jax.jit
def key_bounds(keys_padded: jnp.ndarray, valid: jnp.ndarray):
    """(min, max, count) over the valid rows of a padded key column."""
    masked_lo = jnp.where(valid, keys_padded, KEY_SENTINEL)
    masked_hi = jnp.where(valid, keys_padded, jnp.iinfo(jnp.int64).min)
    return masked_lo.min(), masked_hi.max(), valid.sum()


@functools.partial(jax.jit, static_argnames=("domain",))
def direct_build(keys_padded: jnp.ndarray, valid: jnp.ndarray,
                 lo, domain: int):
    """Sort-free direct-address join build for dense key domains.

    TPC-H build keys (PKs, FK ranges) are dense, so the hash table
    degenerates to a perfect direct-address array: scatter each row id into
    ``slot[key - lo]``.  One scatter instead of a sort — XLA's generic sort
    is the slowest primitive on every backend.  → (slot array int32 [-1 =
    empty], duplicate-key flag).  Padding rows scatter into an overflow
    slot that is cut off.
    """
    nb = keys_padded.shape[0]
    idx = jnp.clip(keys_padded - lo, 0, domain - 1)
    pos = jnp.where(valid, idx, domain)          # pads → overflow slot
    slot = jnp.full((domain + 1,), -1, jnp.int32).at[pos].max(
        jnp.arange(nb, dtype=jnp.int32))
    counts = jnp.zeros((domain + 1,), jnp.int32).at[pos].add(1)
    dup = jnp.any(counts[:domain] > 1)
    return slot[:domain], dup


def direct_lookup(slot: jnp.ndarray, lo, probe_keys: jnp.ndarray):
    """Probe a direct-address build → (build row [-1], found). jit-safe."""
    domain = slot.shape[0]
    idx = probe_keys - lo
    ok = (idx >= 0) & (idx < domain)
    row = jnp.take(slot, jnp.clip(idx, 0, domain - 1))
    found = ok & (row >= 0)
    return jnp.where(found, row, -1), found


def sorted_lookup(s_keys: jnp.ndarray, s_order: jnp.ndarray,
                  probe_keys: jnp.ndarray):
    """Probe sentinel-padded sorted build keys → (build row [-1], found).

    Plain jnp (binary search + two gathers) so it inlines into fused
    pipeline regions; first match wins (exact for unique keys, existence
    semantics for semi/anti/mark).
    """
    pos = jnp.clip(jnp.searchsorted(s_keys, probe_keys), 0,
                   s_keys.shape[0] - 1)
    k = jnp.take(s_keys, pos)
    found = (k == probe_keys) & (k != KEY_SENTINEL)
    row = jnp.take(s_order, pos)
    return jnp.where(found, row, -1), found


@jax.jit
def compact(mask: jnp.ndarray):
    """Selection-vector compaction: indices of True, selected-first order.

    Static output size (= len(mask)); count tells how many lead entries are
    valid.  Cumsum-scatter (``nonzero`` with a static size) — pure XLA,
    jit-compiled so repeated shapes replay a cached program, and it fuses
    with the gather that consumes it.
    """
    idx = jnp.nonzero(mask, size=mask.shape[0], fill_value=0)[0]
    return idx, mask.sum()


def filter_select(cols: jnp.ndarray, lo, hi, interpret: bool = True):
    """Fused range filter + compaction → (row indices, count)."""
    mask, _ = filter_mask_counts(cols, jnp.asarray(lo), jnp.asarray(hi),
                                 interpret=interpret)
    return compact(mask)


def groupby_sum_large(gids: jnp.ndarray, values: jnp.ndarray, n_groups: int,
                      interpret: bool = True) -> jnp.ndarray:
    """Group-space-partitioned aggregation for G beyond the VMEM budget."""
    if n_groups <= _GROUP_BUDGET:
        return groupby_sum(gids, values, n_groups, interpret=interpret)
    parts = []
    for base in range(0, n_groups, _GROUP_BUDGET):
        g = min(_GROUP_BUDGET, n_groups - base)
        local = gids.astype(jnp.int32) - base
        parts.append(groupby_sum(local, values, g, interpret=interpret))
    return jnp.concatenate(parts, axis=0)


def hash_probe_int64(probe_keys: jnp.ndarray, build_keys: jnp.ndarray,
                     slots_key32: jnp.ndarray, slots_row: jnp.ndarray,
                     interpret: bool = True):
    """Probe with int64 keys against a table built on int32-factorized keys.

    The caller factorizes build keys to int32 once (see
    ``factorize_keys_int32``); probe keys are mapped through the same
    factorization here (host-side searchsorted, then the kernel).
    """
    row, found = hash_probe(probe_keys, slots_key32, slots_row,
                            interpret=interpret)
    # verify true key equality to reject 32-bit factorization misses
    ok = found & (jnp.take(build_keys, jnp.clip(row, 0, None)) == probe_keys)
    return jnp.where(ok, row, -1), ok


def factorize_keys_int32(build_keys_np: np.ndarray, probe_keys_np: np.ndarray):
    """Map int64 key spaces into dense int32 ranks (host-side, exact)."""
    uni = np.unique(build_keys_np)
    b = np.searchsorted(uni, build_keys_np).astype(np.int32)
    pos = np.searchsorted(uni, probe_keys_np)
    pos = np.clip(pos, 0, len(uni) - 1)
    hit = uni[pos] == probe_keys_np
    p = np.where(hit, pos, -2).astype(np.int32)  # -2 never matches
    return b, p


def factorize_keys_int32_device(build_keys: jnp.ndarray,
                                probe_keys: jnp.ndarray):
    """Device-side analogue of ``factorize_keys_int32`` — no host roundtrip.

    Build keys are ranked against their sorted unique set; probe keys map
    through the same ranking (-2 = key absent, never matches).  Also usable
    under jit when ``probe_keys`` is a tracer and ``build_keys``/``uni`` are
    concrete-shape arguments (``map_probe_keys``)."""
    uni = jnp.unique(build_keys)
    b = jnp.searchsorted(uni, build_keys).astype(jnp.int32)
    p = map_probe_keys(uni, probe_keys)
    return b, p, uni


def map_probe_keys(uni: jnp.ndarray, probe_keys: jnp.ndarray) -> jnp.ndarray:
    """Rank ``probe_keys`` in the sorted-unique build key set (jit-safe).

    ``uni`` may carry a KEY_SENTINEL pad tail; sentinel positions never
    match real keys so padded ranks map to -2 (absent).
    """
    pos = jnp.clip(jnp.searchsorted(uni, probe_keys), 0,
                   max(uni.shape[0] - 1, 0))
    hit = jnp.take(uni, pos) == probe_keys if uni.shape[0] else \
        jnp.zeros(probe_keys.shape, bool)
    return jnp.where(hit, pos, -2).astype(jnp.int32)


map_probe_keys_jit = jax.jit(map_probe_keys)
