"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret mode
executes the kernel body exactly).  On real TPU deployments pass
``interpret=False`` — the pallas_call lowering path is identical.

The wrappers own the TPU-adaptation glue documented in DESIGN.md:
  * ``compact``            — argsort-based compaction (the TPU answer to
                             warp-ballot compaction; stable, vectorizes).
  * ``hash_probe_int64``   — re-factorizes int64 packed keys into the int32
                             lane width the kernel wants.
  * ``groupby_sum_large``  — partitions group space when G exceeds the VMEM
                             accumulator budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .decode_attention import decode_attention
from .filter_count import filter_mask_counts
from .groupby_agg import groupby_sum
from .hash_probe import build_table32, hash_probe

__all__ = [
    "build_table32", "compact", "decode_attention", "factorize_keys_int32",
    "filter_mask_counts", "filter_select", "groupby_sum", "groupby_sum_large",
    "hash_probe", "hash_probe_int64",
]

_GROUP_BUDGET = 4096  # VMEM accumulator rows per kernel call


def compact(mask: jnp.ndarray):
    """Selection-vector compaction: indices of True, selected-first order.

    Static output size (= len(mask)); count tells how many lead entries are
    valid.  Stable argsort of ~mask — pure XLA, fuses with the gather that
    consumes it.
    """
    order = jnp.argsort(~mask, stable=True)
    count = mask.sum()
    return order, count


def filter_select(cols: jnp.ndarray, lo, hi, interpret: bool = True):
    """Fused range filter + compaction → (row indices, count)."""
    mask, _ = filter_mask_counts(cols, jnp.asarray(lo), jnp.asarray(hi),
                                 interpret=interpret)
    return compact(mask)


def groupby_sum_large(gids: jnp.ndarray, values: jnp.ndarray, n_groups: int,
                      interpret: bool = True) -> jnp.ndarray:
    """Group-space-partitioned aggregation for G beyond the VMEM budget."""
    if n_groups <= _GROUP_BUDGET:
        return groupby_sum(gids, values, n_groups, interpret=interpret)
    parts = []
    for base in range(0, n_groups, _GROUP_BUDGET):
        g = min(_GROUP_BUDGET, n_groups - base)
        local = gids.astype(jnp.int32) - base
        parts.append(groupby_sum(local, values, g, interpret=interpret))
    return jnp.concatenate(parts, axis=0)


def hash_probe_int64(probe_keys: jnp.ndarray, build_keys: jnp.ndarray,
                     slots_key32: jnp.ndarray, slots_row: jnp.ndarray,
                     interpret: bool = True):
    """Probe with int64 keys against a table built on int32-factorized keys.

    The caller factorizes build keys to int32 once (see
    ``factorize_keys_int32``); probe keys are mapped through the same
    factorization here (host-side searchsorted, then the kernel).
    """
    row, found = hash_probe(probe_keys, slots_key32, slots_row,
                            interpret=interpret)
    # verify true key equality to reject 32-bit factorization misses
    ok = found & (jnp.take(build_keys, jnp.clip(row, 0, None)) == probe_keys)
    return jnp.where(ok, row, -1), ok


def factorize_keys_int32(build_keys_np: np.ndarray, probe_keys_np: np.ndarray):
    """Map int64 key spaces into dense int32 ranks (host-side, exact)."""
    uni = np.unique(build_keys_np)
    b = np.searchsorted(uni, build_keys_np).astype(np.int32)
    pos = np.searchsorted(uni, probe_keys_np)
    pos = np.clip(pos, 0, len(uni) - 1)
    hit = uni[pos] == probe_keys_np
    p = np.where(hit, pos, -2).astype(np.int32)  # -2 never matches
    return b, p
