"""Pallas TPU kernel: flash-decode attention (single-token GQA decode).

Serving-side hot spot for the LM architecture suite (decode_32k / long_500k
shapes): one query token attends over a long KV cache.  The cache streams
HBM→VMEM in blocks; an online-softmax accumulator (running max / sum / value
accumulation) lives in VMEM scratch across the sequential grid — the TPU
analogue of flash-decoding's split-K reduction, with the cross-block combine
done by the sequential grid instead of a second kernel launch.

Shapes (per batch element, handled by vmap in ops.py):
  q        (H, D)          H = n_q_heads
  k, v     (S, KVH, D)     S padded to BLK multiple; GQA via head grouping
  length   scalar int32    valid cache length (masks the tail)
  out      (H, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, blk: int, groups: int, scale: float):
    s = pl.program_id(0)
    n_steps = pl.num_programs(0)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                # (H, D)
    k = k_ref[...]                                # (BLK, KVH, D)
    v = v_ref[...]
    kvh = k.shape[1]
    d = q.shape[-1]
    qg = q.reshape(kvh, groups, d)

    # scores: (KVH, G, BLK)
    scores = jnp.einsum("kgd,skd->kgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = s * blk + jax.lax.broadcasted_iota(jnp.int32, (kvh, groups, blk), 2)
    scores = jnp.where(pos < len_ref[0], scores, NEG_INF)

    m_prev = m_ref[...]                           # (KVH, G)
    m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(scores - m_cur[..., None])        # (KVH, G, BLK)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgs,skd->kgd", p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(s == n_steps - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / l_ref[...][..., None]).reshape(
            kvh * groups, d).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, interpret: bool = True):
    """Batched flash decode via vmap: q (B,H,D), k/v (B,S,KVH,D), lengths (B,)."""
    b, h, d = q.shape
    _, s, kvh, _ = k.shape
    groups = h // kvh
    s_pad = ((s + BLK - 1) // BLK) * BLK
    k_p = jnp.zeros((b, s_pad, kvh, d), k.dtype).at[:, :s].set(k)
    v_p = jnp.zeros((b, s_pad, kvh, d), v.dtype).at[:, :s].set(v)
    scale = 1.0 / (d ** 0.5)

    call = pl.pallas_call(
        functools.partial(_kernel, blk=BLK, groups=groups, scale=scale),
        grid=(s_pad // BLK,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
            pl.BlockSpec((BLK, kvh, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLK, kvh, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((h, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kvh, groups), jnp.float32),      # running max
            pltpu.VMEM((kvh, groups), jnp.float32),      # running sum
            pltpu.VMEM((kvh, groups, d), jnp.float32),   # value acc
        ],
        interpret=interpret,
    )
    lengths32 = lengths.astype(jnp.int32).reshape(b, 1)
    return jax.vmap(call)(lengths32, q, k_p, v_p)
