"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def groupby_sum_ref(gids: jnp.ndarray, values: jnp.ndarray,
                    n_groups: int) -> jnp.ndarray:
    """Segment-sum (N,V) by gid, dropping out-of-range gids → (G,V) f32."""
    gids = gids.astype(jnp.int32)
    ok = (gids >= 0) & (gids < n_groups)
    safe = jnp.where(ok, gids, n_groups)
    vals = jnp.where(ok[:, None], values.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(vals, safe, n_groups + 1)[:-1]


def filter_mask_counts_ref(cols: jnp.ndarray, lo: jnp.ndarray,
                           hi: jnp.ndarray, tile: int = 2048):
    """Fused conjunctive range filter → (mask, per-tile counts)."""
    cols32 = cols.astype(jnp.float32)
    mask = jnp.all((cols32 >= lo.astype(jnp.float32))
                   & (cols32 <= hi.astype(jnp.float32)), axis=1)
    n = mask.shape[0]
    n_pad = ((n + tile - 1) // tile) * tile
    padded = jnp.zeros((n_pad,), jnp.bool_).at[:n].set(mask)
    counts = padded.reshape(-1, tile).sum(axis=1).astype(jnp.int32)
    return mask, counts


def hash_probe_ref(probe_keys: jnp.ndarray, slots_key: jnp.ndarray,
                   slots_row: jnp.ndarray, max_probes: int = 32):
    """Vectorized linear-probe lookup — same contract as the kernel."""
    cap = slots_key.shape[0]
    mask = cap - 1
    keys = probe_keys.astype(jnp.int32)
    mix = jnp.int32(-1640531527)
    h = keys * mix
    h0 = (h ^ (h >> 15)) & mask

    def body(i, state):
        row, done = state
        cand = (h0 + i) & mask
        k = slots_key[cand]
        r = slots_row[cand]
        hit = (~done) & (k == keys) & (r >= 0)
        empty = (~done) & (r == -1)
        row = jnp.where(hit, r, row)
        done = done | hit | empty
        return row, done

    row = jnp.full(keys.shape, -1, jnp.int32)
    done = jnp.zeros(keys.shape, bool)
    row, done = jax.lax.fori_loop(0, max_probes, body, (row, done))
    return row, row >= 0


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray) -> jnp.ndarray:
    """Masked GQA decode attention: q (B,H,D), k/v (B,S,KVH,D) → (B,H,D)."""
    b, h, d = q.shape
    _, s, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, kf) / (d ** 0.5)
    pos = jnp.arange(s)[None, None, None, :]
    scores = jnp.where(pos < lengths[:, None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)
