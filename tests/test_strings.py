"""Device-resident string subsystem: semantics vs an independent oracle.

LIKE / starts_with / substring are evaluated as one-time host passes over
the sorted dictionary plus device code gathers (DESIGN.md "Strings &
dictionaries").  These tests pin:

1. **LIKE semantics** against an independent recursive matcher (not the
   regex translation under test), across wildcard edge cases: ``%a%b%``,
   escaped ``%``/``_``, the empty pattern, negation, and the prefix/exact
   fast paths that skip the regex entirely.
2. **Dictionary-transform identity stability** — the substring transform
   and merged join dictionaries return the *same object* per input, which
   is what keeps the pipeline compiler's signature cache warm.
3. **Dictionary-informed selectivity** — LIKE/IN/prefix estimates come from
   dictionary hit rates when available and change join orders accordingly
   (the SEL_LIKE=0.1 constant remains the fallback).
"""
from functools import lru_cache

import numpy as np
import pytest

from repro.relational import strings
from repro.relational.expressions import (
    Col, InList, Like, StartsWith, Substr, evaluate,
)
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# independent LIKE oracle (recursive matcher, no regex)
# ---------------------------------------------------------------------------


def like_match(pattern: str, s: str) -> bool:
    toks = []
    i = 0
    while i < len(pattern):
        ch = pattern[i]
        if ch == "\\" and i + 1 < len(pattern):
            toks.append(("lit", pattern[i + 1]))
            i += 2
        elif ch == "%":
            toks.append(("any", None))
            i += 1
        elif ch == "_":
            toks.append(("one", None))
            i += 1
        else:
            toks.append(("lit", ch))
            i += 1

    @lru_cache(maxsize=None)
    def m(ti: int, si: int) -> bool:
        if ti == len(toks):
            return si == len(s)
        kind, v = toks[ti]
        if kind == "any":
            return any(m(ti + 1, sj) for sj in range(si, len(s) + 1))
        if si >= len(s):
            return False
        if kind == "one":
            return m(ti + 1, si + 1)
        return s[si] == v and m(ti + 1, si + 1)

    return m(0, 0)


VALUES = [
    "", "a", "b", "ab", "ba", "aab", "abb", "abc", "acb", "aXbXc",
    "a%b", "a_b", "%", "_", "\\", "hello world", "google",
    "googol", "agoogleb", "https://google.com/x", "http://a.google.b/",
    "special requests", "handle special any requests carefully",
]

PATTERNS = [
    "%a%b%", "a%", "%b", "a_b", "abc", "", "%", "_", "%%",
    "a\\%b", "\\%%", "a\\_b", "%google%", "a%b%c", "%.google.%",
    "%special%requests%", "__", "%\\\\%",
]


def _table():
    return Table.from_pydict({"s": np.array(VALUES)})


@pytest.mark.parametrize("pattern", PATTERNS)
def test_like_matches_independent_oracle(pattern):
    t = _table()
    got = np.asarray(evaluate(Like(Col("s"), pattern), t).data)
    want = np.array([like_match(pattern, s) for s in VALUES])
    assert (got == want).all(), f"pattern {pattern!r}: {got} vs {want}"


@pytest.mark.parametrize("pattern", PATTERNS)
def test_like_negate(pattern):
    t = _table()
    pos = np.asarray(evaluate(Like(Col("s"), pattern), t).data)
    neg = np.asarray(evaluate(Like(Col("s"), pattern, negate=True), t).data)
    assert (pos ^ neg).all()


def test_like_fastpath_classification():
    assert strings.analyze_like("abc%") == ("prefix", "abc")
    assert strings.analyze_like("abc") == ("exact", "abc")
    assert strings.analyze_like("%") == ("prefix", "")
    assert strings.analyze_like("") == ("exact", "")
    assert strings.analyze_like("a\\%b") == ("exact", "a%b")
    # escaped trailing % is a literal, not a prefix marker
    assert strings.analyze_like("ab\\%") == ("exact", "ab%")
    assert strings.analyze_like("%a%b%")[0] == "general"
    assert strings.analyze_like("a_c")[0] == "general"
    assert strings.analyze_like("a%c")[0] == "general"


def test_empty_pattern_matches_only_empty_string():
    t = _table()
    got = np.asarray(evaluate(Like(Col("s"), ""), t).data)
    assert got.sum() == 1 and got[VALUES.index("")]


def test_starts_with_matches_python():
    t = _table()
    for prefix in ["", "a", "ab", "goog", "https://", "z", "a%"]:
        got = np.asarray(evaluate(StartsWith(Col("s"), prefix), t).data)
        want = np.array([s.startswith(prefix) for s in VALUES])
        assert (got == want).all(), prefix
        neg = np.asarray(
            evaluate(StartsWith(Col("s"), prefix, negate=True), t).data)
        assert (got ^ neg).all()


def test_prefix_range_handles_max_codepoint():
    """Entries whose next character is U+10FFFF must still match the
    prefix (a `prefix + max-char` upper probe would exclude them)."""
    vals = ["ab", "abc", "ab\U0010FFFF", "ab\U0010FFFFz", "ac", "b"]
    t = Table.from_pydict({"s": np.array(vals)})
    got = np.asarray(evaluate(StartsWith(Col("s"), "ab"), t).data)
    want = np.array([s.startswith("ab") for s in vals])
    assert (got == want).all()
    like = np.asarray(evaluate(Like(Col("s"), "ab%"), t).data)
    assert (like == want).all()


def test_substr_matches_python_slicing():
    t = _table()
    for start, length in [(1, 2), (2, 3), (1, 100), (5, 1), (50, 2)]:
        col = evaluate(Substr(Col("s"), start, length), t)
        got = col.dictionary[np.asarray(col.data)]
        want = np.array([s[start - 1: start - 1 + length] for s in VALUES])
        assert (got == want).all(), (start, length)


def test_in_list_values_longer_than_dictionary_width():
    """IN values wider than the dictionary's U dtype must not be truncated
    into false positives."""
    t = Table.from_pydict({"s": np.array(["apple", "pear"])})
    got = np.asarray(evaluate(InList(Col("s"), ["apple1"]), t).data)
    assert not got.any()
    got = np.asarray(evaluate(InList(Col("s"), ["apple1", "pear"]), t).data)
    assert (got == np.array([False, True])).all()


def test_in_list_mask_and_negate():
    t = _table()
    vals = ["a", "google", "nope"]
    got = np.asarray(evaluate(InList(Col("s"), vals), t).data)
    want = np.array([s in vals for s in VALUES])
    assert (got == want).all()
    neg = np.asarray(evaluate(InList(Col("s"), vals, negate=True), t).data)
    assert (got ^ neg).all()


# ---------------------------------------------------------------------------
# identity-stable dictionary transforms (the plan-signature-cache contract)
# ---------------------------------------------------------------------------


def test_substr_transform_identity_stable():
    t = _table()
    a = evaluate(Substr(Col("s"), 1, 2), t)
    b = evaluate(Substr(Col("s"), 1, 2), t)
    assert a.dictionary is b.dictionary
    c = evaluate(Substr(Col("s"), 1, 3), t)
    assert c.dictionary is not a.dictionary


def test_merged_dictionary_identity_stable():
    d1 = np.unique(np.array(["a", "b", "c"]))
    d2 = np.unique(np.array(["b", "d"]))
    m1 = strings.merged_dictionary(d1, d2)
    m2 = strings.merged_dictionary(d1, d2)
    assert m1 is m2
    assert list(m1) == ["a", "b", "c", "d"]


def test_host_pass_runs_once_per_dictionary_and_pattern():
    t = _table()
    evaluate(Like(Col("s"), "%unique-probe-xyz%"), t)
    before = dict(strings.stats)
    for _ in range(5):
        evaluate(Like(Col("s"), "%unique-probe-xyz%"), t)
    after = dict(strings.stats)
    assert after["host_passes"] == before["host_passes"]
    assert after["cache_hits"] > before["cache_hits"]


# ---------------------------------------------------------------------------
# dictionary-informed selectivity + the join-reorder consequence
# ---------------------------------------------------------------------------


def _stats_catalog(with_dicts: bool):
    from repro.sql.binder import Catalog

    schema = {
        "fact": {"f_id": "numeric", "f_d1": "numeric", "f_d2": "numeric"},
        "dim1": {"d1_id": "numeric", "d1_name": "string"},
        "dim2": {"d2_id": "numeric", "d2_name": "string"},
    }
    rows = {"fact": 10_000.0, "dim1": 500.0, "dim2": 600.0}
    dicts = None
    if with_dicts:
        # every dim1 name contains 'x'; 1% of dim2 names contain 'zq'
        d1 = np.unique(np.array([f"x{i}" for i in range(100)]))
        d2 = np.unique(np.array(["zq0"] + [f"y{i}" for i in range(99)]))
        dicts = {"dim1": {"d1_name": d1}, "dim2": {"d2_name": d2}}
    return Catalog(schema, rows, dicts)


def test_selectivity_uses_dictionary_hit_rate():
    from repro.optimizer.stats import SEL_LIKE, selectivity

    e = Like(Col("d1_name"), "%x%")
    assert selectivity(e, None) == SEL_LIKE
    assert selectivity(e, _stats_catalog(True)) == 1.0
    rare = Like(Col("d2_name"), "%zq%")
    assert selectivity(rare, _stats_catalog(True)) == pytest.approx(0.01)
    # fallback preserved when the catalog has no dictionaries
    assert selectivity(rare, _stats_catalog(False)) == SEL_LIKE


def test_join_reorder_regression_with_dictionary_stats():
    """With constant stats both dims estimate at 10% of base rows, so the
    *smaller* dim1 is joined first; dictionary stats reveal dim1's LIKE
    matches everything and dim2's almost nothing, flipping the order."""
    from repro.core.plan import JoinRel, ReadRel, walk
    from repro.sql import sql_to_plan

    sql = ("select count(*) as c from fact, dim1, dim2 "
           "where f_d1 = d1_id and f_d2 = d2_id "
           "and d1_name like '%x%' and d2_name like '%zq%'")

    def first_build_table(catalog):
        plan = sql_to_plan(sql, catalog)
        joins = [r for r in walk(plan) if isinstance(r, JoinRel)]
        bottom = [j for j in joins if isinstance(j.probe, ReadRel)
                  and j.probe.table == "fact"]
        assert len(bottom) == 1
        build = bottom[0].build
        while not isinstance(build, ReadRel):
            build = build.inputs()[0]
        return build.table

    assert first_build_table(_stats_catalog(False)) == "dim1"
    assert first_build_table(_stats_catalog(True)) == "dim2"


def test_sql_starts_with_equivalent_to_prefix_like(tpch_db):
    from repro.sql import run_sql

    a = run_sql("select count(*) as c from part "
                "where starts_with(p_name, 'gre')", tpch_db)
    b = run_sql("select count(*) as c from part "
                "where p_name like 'gre%'", tpch_db)
    assert int(a["c"][0]) == int(b["c"][0]) > 0
