"""Fleet-level query journal (DESIGN.md §15): the always-on trace layer.

The contracts under test:
  * TraceContext — wire-able (query_id, span_id) roundtrip and cross-thread
    propagation via ``JOURNAL.activate``;
  * query isolation — N concurrent threads running mixed TPC-H/ClickBench
    queries produce clean per-query trees: disjoint query IDs, no
    interleaved parentage, no duplicate span IDs;
  * always-on overhead — a warm TPC-H query with the journal enabled stays
    within 5% (+epsilon) of disabled, and the one-sync-per-query and
    zero-transfer contracts hold either way;
  * bounded ring — overflow drops oldest and counts ``dropped``;
  * JSONL sink — every line self-describing via ``schema_version``;
  * Chrome export — valid trace-event JSON with coordinator/shard lanes;
  * per-engine metrics scoping — pooled shard engines mirror into the
    process registry under labels; ``aggregate_labeled`` rolls them up;
  * distributed journal + compile attribution — an in-process 1-shard run
    produces a verified span tree and self-consistent timers;
  * profile_diff gates — kernel-hit collapse and dispatch-budget breaks
    flag regressions.
"""
import importlib.util
import json
import os
import threading
import time

import pytest

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.data import clickbench as cb
from repro.data.tpch import generate, load_into_engine
from repro.data.tpch_queries import QUERIES
from repro.observability.dist import (
    exchange_report, skew_ratio, span_tree, verify_tree)
from repro.observability.journal import (
    JOURNAL, JOURNAL_SCHEMA_VERSION, QueryJournal, TraceContext, load_jsonl,
    to_chrome)
from repro.observability.metrics import (
    METRICS, MetricsRegistry, aggregate_labeled)
from repro.sql import sql_to_plan

from conftest import USE_KERNELS


# ---------------------------------------------------------------------------
# context primitives
# ---------------------------------------------------------------------------


def test_trace_context_roundtrip():
    ctx = TraceContext(query_id="q1-7", span_id=42)
    assert TraceContext.from_dict(ctx.to_dict()) == ctx
    assert TraceContext.from_dict({"query_id": "q"}).span_id is None


def test_span_outside_query_context_is_dropped():
    j = QueryJournal(capacity=64)
    with j.span("orphan", "engine"):
        pass
    j.event("orphan_instant", "engine")
    assert j.events() == []


def test_query_span_roots_tree_and_nests():
    j = QueryJournal(capacity=64)
    with j.query_span("sql", text="select 1") as root:
        qid = root.query_id
        with j.span("child", "engine", depth=1) as c:
            assert c.query_id == qid
            j.event("mark", "cache")
    evs = j.events(qid)
    assert {e["name"] for e in evs} == {"sql", "child", "mark"}
    by_name = {e["name"]: e for e in evs}
    assert by_name["child"]["parent_id"] == by_name["sql"]["span_id"]
    assert by_name["mark"]["parent_id"] == by_name["child"]["span_id"]
    assert by_name["sql"]["parent_id"] is None
    # one root, child under it, instant under the child
    roots = span_tree(evs, qid)
    assert len(roots) == 1 and roots[0].name == "sql"


def test_activate_propagates_context_across_threads():
    j = QueryJournal(capacity=64)
    with j.query_span("distributed.query") as root:
        ctx = j.current_context()

        def worker():
            with j.activate(ctx):
                with j.span("fragment@thread", "fragment"):
                    pass
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    evs = j.events(root.query_id)
    frag = next(e for e in evs if e["name"] == "fragment@thread")
    assert frag["query_id"] == root.query_id
    assert frag["parent_id"] == root.span_id
    assert verify_tree(evs, root.query_id) == []


def test_ring_capacity_bounds_and_counts_drops():
    j = QueryJournal(capacity=8)
    with j.query_span("q") as root:
        for i in range(20):
            j.event(f"e{i}")
    assert len(j.events()) == 8
    assert j.dropped > 0
    assert j.summary()["dropped"] == j.dropped
    j.clear()
    assert j.events() == [] and j.dropped == 0


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = QueryJournal(capacity=64)
    j.attach_sink(path)
    with j.query_span("sql") as root:
        j.event("mark", "cache", n=3)
    j.detach_sink()
    lines = load_jsonl(path)
    assert len(lines) == 2
    assert all(l["schema_version"] == JOURNAL_SCHEMA_VERSION for l in lines)
    assert {l["name"] for l in lines} == {"sql", "mark"}
    assert all(l["query_id"] == root.query_id for l in lines)


def test_disabled_journal_is_noop():
    j = QueryJournal(capacity=64, enabled=False)
    with j.query_span("sql") as sp:
        assert sp.query_id is None
        j.event("mark")
    assert j.events() == []


def test_attrs_cleaned_to_host_plain():
    import numpy as np
    j = QueryJournal(capacity=64)
    with j.query_span("q", np_scalar=np.int64(7), arr=np.arange(3)) as sp:
        qid = sp.query_id
    ev = j.events(qid)[0]
    assert ev["attrs"]["np_scalar"] == 7
    assert isinstance(ev["attrs"]["arr"], str)   # repr'd, not a device value
    json.dumps(ev)                                # JSON-able end to end


# ---------------------------------------------------------------------------
# skew + chrome export
# ---------------------------------------------------------------------------


def test_skew_ratio_math():
    assert skew_ratio([]) == 1.0
    assert skew_ratio([0, 0]) == 1.0
    assert skew_ratio([100, 100, 100, 100]) == 1.0
    assert skew_ratio([400, 0, 0, 0]) == 4.0
    assert abs(skew_ratio([300, 100]) - 1.5) < 1e-12


def test_chrome_export_shape():
    j = QueryJournal(capacity=64)
    with j.query_span("distributed.query") as root:
        with j.span("f0@shard1", "shard", shard=1):
            with j.span("engine.execute", "engine"):
                pass
        j.event("speculative_backup", "recovery")
    d = to_chrome(j.events(root.query_id), epoch=j.epoch)
    evs = d["traceEvents"]
    assert d["otherData"]["schema_version"] == JOURNAL_SCHEMA_VERSION
    phs = {e["ph"] for e in evs}
    assert phs == {"X", "i", "M"}
    lanes = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert lanes == {"coordinator", "shard 1"}
    # engine.execute has no shard attr but inherits its ancestor's lane
    engine_ev = next(e for e in evs if e["name"] == "engine.execute")
    assert engine_ev["pid"] == 2
    assert all(e["dur"] > 0 for e in evs if e["ph"] == "X")


# ---------------------------------------------------------------------------
# per-engine metrics scoping
# ---------------------------------------------------------------------------


def test_metrics_registry_scoping_mirrors_and_aggregates():
    parent = MetricsRegistry()
    shard0 = MetricsRegistry(parent=parent, label="pool.shard0")
    shard1 = MetricsRegistry(parent=parent, label="pool.shard1")
    shard0.counter("plan_cache.hits").inc(3)
    shard1.counter("plan_cache.hits").inc(5)
    shard0.histogram("query_seconds").observe(0.5)
    shard1.histogram("query_seconds").observe(1.5)
    # per-engine views are isolated …
    assert shard0.snapshot()["plan_cache.hits"] == 3
    assert shard1.snapshot()["plan_cache.hits"] == 5
    # … while the parent holds the labeled process-global view
    snap = parent.snapshot()
    assert snap["pool.shard0.plan_cache.hits"] == 3
    assert snap["pool.shard1.plan_cache.hits"] == 5
    agg = aggregate_labeled(snap, "pool.shard")
    assert agg["plan_cache.hits"] == 8
    assert agg["query_seconds.count"] == 2
    assert agg["query_seconds.max"] == pytest.approx(1.5)


def test_metrics_registry_label_requires_parent():
    with pytest.raises(ValueError):
        MetricsRegistry(label="pool.shard0")
    with pytest.raises(ValueError):
        MetricsRegistry(parent=MetricsRegistry())


# ---------------------------------------------------------------------------
# engine integration: concurrency, overhead, distributed
# ---------------------------------------------------------------------------

SF = 0.002
CB_ROWS = 2_000


@pytest.fixture(scope="module")
def small_db():
    return generate(SF)


def test_concurrent_queries_journal_isolated(small_db):
    """N threads × mixed TPC-H/ClickBench on per-thread engines: every
    query's events form one clean tree under its own query ID."""
    cdb = cb.generate(CB_ROWS)
    cat = cb.clickbench_catalog(CB_ROWS)
    n_threads = 4
    qids_per_thread = [[] for _ in range(n_threads)]
    errors = []

    def worker(i):
        try:
            eng = SiriusEngine(use_kernels=USE_KERNELS)
            if i % 2 == 0:
                load_into_engine(eng, small_db)
                for qid in (1, 6):
                    eng.execute(QUERIES[qid]())
                    qids_per_thread[i].append(eng.last_query_id)
            else:
                cb.load_into_engine(eng, cdb)
                for q in ("q1", "q12"):
                    eng.execute(sql_to_plan(cb.CLICKBENCH_QUERIES[q], cat))
                    qids_per_thread[i].append(eng.last_query_id)
        except Exception as e:           # surface, don't deadlock the join
            errors.append(f"thread {i}: {e!r}")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    all_qids = [q for qs in qids_per_thread for q in qs]
    assert all(q is not None for q in all_qids)
    assert len(set(all_qids)) == len(all_qids), "query IDs must be unique"
    for qid in all_qids:
        evs = JOURNAL.events(qid)
        assert evs, f"no events for {qid}"
        assert all(e["query_id"] == qid for e in evs)
        span_ids = [e["span_id"] for e in evs]
        assert len(set(span_ids)) == len(span_ids)
        for e in evs:                    # parentage never crosses queries
            pid = e.get("parent_id")
            if pid is not None and any(o["span_id"] == pid for o in evs):
                parent = next(o for o in evs if o["span_id"] == pid)
                assert parent["query_id"] == qid
        assert len(span_tree(evs, qid)) >= 1


def test_journal_overhead_and_sync_contract(small_db):
    """Always-on means *cheap*: warm TPC-H with the journal enabled stays
    within 5% (+2 ms epsilon) of disabled, and the warm path keeps exactly
    one sync barrier and zero buffer-ledger transfer bytes per query."""
    eng = SiriusEngine(use_kernels=USE_KERNELS)
    load_into_engine(eng, small_db)
    plan = QUERIES[6]
    eng.execute(plan())                       # warm the plan cache
    repeats = 15

    def timed(enabled):
        (JOURNAL.enable if enabled else JOURNAL.disable)()
        try:
            eng.execute(plan())               # settle after the toggle
            syncs0 = instrument.sync_barriers.value
            xfer0 = eng.buffers.host_transfer_bytes
            t0 = time.perf_counter()
            for _ in range(repeats):
                eng.execute(plan())
            dt = (time.perf_counter() - t0) / repeats
            syncs = (instrument.sync_barriers.value - syncs0) / repeats
            xfer = eng.buffers.host_transfer_bytes - xfer0
            return dt, syncs, xfer
        finally:
            JOURNAL.enable()

    t_on, syncs_on, xfer_on = timed(True)
    t_off, syncs_off, xfer_off = timed(False)
    assert syncs_on == 1 and syncs_off == 1, \
        "journal must not add sync barriers"
    assert xfer_on == 0 and xfer_off == 0, \
        "journal must not move bytes to the host"
    assert t_on <= t_off * 1.05 + 0.002, \
        f"journal overhead: {t_on*1e3:.3f} ms on vs {t_off*1e3:.3f} ms off"


def test_distributed_journal_tree_and_compile_attribution(small_db):
    """In-process 1-shard distributed run: one verified tree per query,
    fragment/shard/exchange spans present, timers self-consistent."""
    from repro.core.distributed import DistributedEngine
    eng = DistributedEngine(small_db, n_shards=1)
    # suppress speculative backups: a cold run's losing replica would keep
    # running into the warm run and pollute its (reset) phase timers
    eng.speculative.min_budget_s = 1e9
    eng.run_plan(QUERIES[3]())                # cold (compiles)
    eng.run_plan(QUERIES[3]())                # warm — the run under test
    qid = eng.last_query_id
    assert qid is not None
    evs = JOURNAL.events(qid)
    cats = {e["cat"] for e in evs}
    assert {"query", "fragment", "attempt", "shard", "engine"} <= cats
    assert verify_tree(evs, qid) == []
    root = next(e for e in evs if e["parent_id"] is None)
    assert root["name"] == "distributed.query"
    assert root["attrs"]["shards"] == 1
    # timer decomposition: parts never exceed the whole
    t = eng.timers
    assert t["compute"] + t["exchange"] + t["compile"] + t["other"] \
        <= t["total"] + 1e-6
    # exchange spans carry per-shard bytes + skew, mirrored in the summary
    ex = exchange_report(evs, qid)
    summary = eng.exchange_summary()
    if summary:                               # Q3 always exchanges
        assert ex, "exchange spans missing from the journal"
        assert all(r["skew_ratio"] >= 1.0 for r in summary)
        assert all(isinstance(b, int)
                   for r in summary for b in r["bytes_per_shard"])


# ---------------------------------------------------------------------------
# profile_diff gates
# ---------------------------------------------------------------------------


def _load_profile_diff():
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "scripts", "profile_diff.py")
    spec = importlib.util.spec_from_file_location("profile_diff", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_profile_diff_kernel_hits_gate():
    pd = _load_profile_diff()
    old = {"kernel_hits": {"per_query": {
        "q3": {"filter": 2, "probe": 1, "fallback": 0},
        "q6": {"filter": 1, "fallback": 0}}}}
    new = {"kernel_hits": {"per_query": {
        "q3": {"filter": 0, "probe": 0, "fallback": 1},
        "q6": {"filter": 1, "fallback": 0}}}}
    regressions, report = pd._diff_kernel_hits(old, new)
    assert regressions == ["q3"]
    assert any("q3" in line for line in report)
    # fallback-only counts never count as device hits
    regressions, _ = pd._diff_kernel_hits(new, new)
    assert regressions == []


def test_profile_diff_dispatch_budget_gate():
    pd = _load_profile_diff()
    clean = {"queries": {"q1": {"dispatch": {
        "syncs_per_query": 1.0, "transfer_bytes_per_query": 0}}}}
    regressions, _ = pd._check_dispatch_budgets(clean)
    assert regressions == []
    dirty = {"queries": {
        "q1": {"dispatch": {"syncs_per_query": 3.0,
                            "transfer_bytes_per_query": 0}},
        "q2": {"dispatch": {"syncs_per_query": 1.0,
                            "transfer_bytes_per_query": 4096}}}}
    regressions, report = pd._check_dispatch_budgets(dirty)
    assert set(regressions) == {"q1", "q2"}
    assert len(report) == 2


def test_profile_diff_skew_table_rendering():
    pd = _load_profile_diff()
    dist = {"queries": {"q3": {"exchanges": [
        {"fragment": "f1_shuffle", "kind": "shuffle",
         "bytes_per_shard": [300, 100], "skew_ratio": 1.5}]}}}
    lines = pd._render_skew_table(dist)
    assert lines and "f1_shuffle" in "\n".join(lines)
    assert pd._render_skew_table({"queries": {"q3": {}}}) == []
