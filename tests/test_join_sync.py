"""Sync-free hash join: oracle exactness and the zero-sync contract.

Three layers:
* ``hash_join`` row-exact against a plain-numpy join oracle, with and
  without the kernel backend (Pallas run-expansion), across join types and
  multi-match key distributions;
* ``hash_join_bounded`` — the stats-capped variant — touches the host ZERO
  times (no scalar pulls, no barriers) and its valid-masked rows match
  ``hash_join``; overflow is a device flag, not an exception;
* join-bearing TPC-H queries stay row-exact against the numpy fallback
  oracle on the *warm* (replayed, sync-free) path.
"""
import numpy as np
import pytest
from conftest import assert_tables_equal

from repro.core import instrument
from repro.core.kernel_backend import KernelBackend
from repro.relational.join import hash_join, hash_join_bounded
from repro.relational.table import Table


def _make_tables(n_probe, n_build, key_range, seed):
    rng = np.random.default_rng(seed)
    probe = Table.from_pydict({
        "k": rng.integers(0, key_range, n_probe),
        "pv": rng.normal(size=n_probe).astype(np.float32),
    })
    build = Table.from_pydict({
        "k": rng.integers(0, key_range, n_build),
        "bv": rng.integers(0, 1000, n_build),
    })
    return probe, build


def _oracle_join(probe, build, how):
    """Plain-numpy reference: nested loop over probe rows, build order."""
    pk = np.asarray(probe["k"].to_host())
    bk = np.asarray(build["k"].to_host())
    pv = np.asarray(probe["pv"].to_host())
    bv = np.asarray(build["bv"].to_host())
    rows = {"k": [], "pv": [], "bv": []}
    if how == "left":
        rows["__matched"] = []
    for i in range(len(pk)):
        matches = np.nonzero(bk == pk[i])[0]
        if how == "semi":
            if len(matches):
                rows["k"].append(pk[i]); rows["pv"].append(pv[i])
            continue
        if how == "anti":
            if not len(matches):
                rows["k"].append(pk[i]); rows["pv"].append(pv[i])
            continue
        if how == "inner":
            for j in matches:
                rows["k"].append(pk[i]); rows["pv"].append(pv[i])
                rows["bv"].append(bv[j])
        elif how == "left":
            if len(matches):
                for j in matches:
                    rows["k"].append(pk[i]); rows["pv"].append(pv[i])
                    rows["bv"].append(bv[j]); rows["__matched"].append(True)
            else:
                rows["k"].append(pk[i]); rows["pv"].append(pv[i])
                rows["bv"].append(0); rows["__matched"].append(False)
    if how in ("semi", "anti"):
        del rows["bv"]
    return {k: np.asarray(v) for k, v in rows.items()}


def _sorted_rows(cols):
    """Row set as a lexsorted record list (join output order is impl-defined
    within a probe row's run for the oracle — sort both sides)."""
    keys = sorted(cols)
    arrs = [np.asarray(cols[k]) for k in keys]
    order = np.lexsort(tuple(reversed(arrs)))
    return {k: a[order] for k, a in zip(keys, arrs)}


BACKENDS = [None, KernelBackend(interpret=True)]


@pytest.mark.parametrize("how", ["inner", "left", "semi", "anti"])
@pytest.mark.parametrize("backend", BACKENDS,
                         ids=["jnp", "kernel"])
def test_hash_join_matches_numpy_oracle(how, backend):
    seed = {"inner": 1, "left": 2, "semi": 3, "anti": 4}[how]
    probe, build = _make_tables(n_probe=400, n_build=150, key_range=60,
                                seed=seed)
    got = hash_join(probe, build, ["k"], ["k"], how=how, backend=backend)
    want = _oracle_join(probe, build, how)
    host = {k: np.asarray(c.to_host()) for k, c in got.columns.items()}
    if how == "left":
        # build columns of unmatched rows are garbage by contract: zero them
        m = host["__matched"].astype(bool)
        host["bv"] = np.where(m, host["bv"], 0)
    assert_tables_equal(_sorted_rows(host), _sorted_rows(want))


def test_kernel_expand_route_fires():
    backend = KernelBackend(interpret=True)
    probe, build = _make_tables(n_probe=300, n_build=100, key_range=20,
                                seed=7)
    before = backend.expand_hits
    hash_join(probe, build, ["k"], ["k"], how="inner", backend=backend)
    assert backend.expand_hits == before + 1


def test_mark_join_matches_oracle():
    probe, build = _make_tables(n_probe=200, n_build=80, key_range=40,
                                seed=11)
    got = hash_join(probe, build, ["k"], ["k"], how="mark",
                    mark_name="__mark")
    pk = np.asarray(probe["k"].to_host())
    bk = np.asarray(build["k"].to_host())
    want = np.isin(pk, bk)
    assert (np.asarray(got["__mark"].to_host()) == want).all()


# ---------------------------------------------------------------------------
# hash_join_bounded: the zero-sync contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("how", ["inner", "left"])
def test_bounded_join_is_fully_sync_free(how):
    probe, build = _make_tables(n_probe=500, n_build=200, key_range=80,
                                seed=3)
    syncs0 = instrument.scalar_syncs.value
    barriers0 = instrument.sync_barriers.value
    out, valid, overflow = hash_join_bounded(
        probe, build, ["k"], ["k"], capacity=8192, how=how)
    assert instrument.scalar_syncs.value == syncs0, \
        "bounded join pulled a host scalar"
    assert instrument.sync_barriers.value == barriers0, \
        "bounded join issued a barrier"
    # results stay lazy until the caller materializes; do that now and check
    exact = hash_join(probe, build, ["k"], ["k"], how=how)
    assert not bool(overflow)
    sel = np.asarray(valid)
    assert sel.sum() == exact.num_rows
    got = {k: np.asarray(c.to_host())[sel] for k, c in out.columns.items()}
    want = {k: np.asarray(c.to_host()) for k, c in exact.columns.items()}
    assert_tables_equal(_sorted_rows(got), _sorted_rows(want))


def test_bounded_join_overflow_flag():
    probe, build = _make_tables(n_probe=400, n_build=200, key_range=5,
                                seed=5)                    # ~16k true matches
    exact = hash_join(probe, build, ["k"], ["k"], how="inner")
    capacity = exact.num_rows // 4
    out, valid, overflow = hash_join_bounded(
        probe, build, ["k"], ["k"], capacity=capacity, how="inner")
    from repro.kernels import ops as kops
    cap = kops.bucket_size(capacity)
    assert exact.num_rows > cap                           # genuinely over
    assert bool(overflow), "dropped rows must raise the overflow flag"
    assert out.num_rows == cap
    # surviving rows are the deterministic prefix of the full expansion
    sel = np.asarray(valid)
    assert sel.all()
    for name, col in out.columns.items():
        np.testing.assert_array_equal(
            np.asarray(col.to_host()),
            np.asarray(exact.columns[name].to_host())[:cap])


def test_bounded_join_empty_build():
    probe, _ = _make_tables(n_probe=100, n_build=50, key_range=10, seed=9)
    build = Table.from_pydict({"k": np.zeros(0, np.int64),
                               "bv": np.zeros(0, np.int64)})
    out, valid, overflow = hash_join_bounded(
        probe, build, ["k"], ["k"], capacity=64, how="inner")
    assert not np.asarray(valid).any()
    assert not bool(overflow)


# ---------------------------------------------------------------------------
# join-bearing TPC-H queries: warm (replayed) path vs the numpy oracle
# ---------------------------------------------------------------------------

JOIN_QUERIES = [3, 5, 10, 18]          # multi-join, multi-match workloads


@pytest.mark.parametrize("qid", JOIN_QUERIES)
def test_tpch_join_queries_row_exact_on_warm_path(qid, tpch_db, tpch_engine):
    from repro.core.fallback import FallbackEngine
    from repro.data.tpch_queries import QUERIES

    tpch_engine.execute(QUERIES[qid]())            # record
    syncs0 = instrument.scalar_syncs.value
    warm = tpch_engine.execute(QUERIES[qid]())     # replay, sync-free
    assert tpch_engine.executor.last_plan_cache_hit
    assert instrument.scalar_syncs.value == syncs0, \
        f"q{qid}: warm join path pulled a host scalar"
    ref = FallbackEngine(tpch_db).execute(QUERIES[qid]())
    assert_tables_equal(warm.to_host(), ref)
