"""Subprocess worker for distributed tests (needs 8 forced host devices).

Usage: python tests/_dist_worker.py <scenario>
Prints a JSON verdict on the last line.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402

import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.distributed import DistributedEngine  # noqa: E402
from repro.core.fallback import FallbackEngine  # noqa: E402
from repro.data.tpch import generate  # noqa: E402
from repro.data.tpch_queries import QUERIES  # noqa: E402
from repro.runtime.control import FaultInjector, FaultPlan  # noqa: E402


def canon(v):
    v = np.asarray(v)
    if v.dtype.kind == "M":
        return v.astype("datetime64[D]").astype("int64")
    if v.dtype.kind in "UO":
        return np.asarray(v, "U")
    return v


def tables_match(got, ref):
    for k in got:
        a, b = canon(got[k]), canon(ref[k])
        if len(a) != len(b):
            return False, f"{k}: rows {len(a)} vs {len(b)}"
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            if not np.allclose(a.astype(float), b.astype(float),
                               rtol=1e-6, atol=1e-6):
                return False, f"{k}: values"
        elif not (a == b).all():
            return False, f"{k}: values"
    return True, ""


def main():
    scenario = sys.argv[1]
    db = generate(0.005)
    fb = FallbackEngine(db)
    verdict = {"scenario": scenario, "ok": False}

    if scenario == "correctness":
        eng = DistributedEngine(db, n_shards=8)
        oks = []
        for qid in (1, 3, 6, 12):
            got = eng.run_query(qid)
            ref = fb.execute(QUERIES[qid]())
            ok, why = tables_match(got, ref)
            oks.append(ok)
            if not ok:
                verdict["why"] = f"Q{qid} {why}"
        verdict["ok"] = all(oks)

    elif scenario == "node_failure_elastic":
        inj = FaultInjector([FaultPlan(fragment="q3_join", node=3, times=1)])
        eng = DistributedEngine(db, n_shards=8, injector=inj)
        got = eng.run_query(3)
        ref = fb.execute(QUERIES[3]())
        ok, why = tables_match(got, ref)
        verdict["ok"] = (ok and eng.recoveries == 1 and eng.n_shards == 7
                         and inj.tripped == ["q3_join"])
        verdict["recoveries"] = eng.recoveries
        verdict["n_shards_after"] = eng.n_shards
        verdict["why"] = why

    elif scenario == "straggler_speculation":
        inj = FaultInjector([FaultPlan(fragment="q3_join", node=2, times=1,
                                       delay_s=30.0)])
        eng = DistributedEngine(db, n_shards=8, injector=inj)
        eng.run_query(3)  # warm (history for budget)
        got = eng.run_query(3)
        ref = fb.execute(QUERIES[3]())
        ok, why = tables_match(got, ref)
        verdict["ok"] = ok and "q3_join" in eng.speculative.speculated
        verdict["speculated"] = eng.speculative.speculated
        verdict["why"] = why

    elif scenario == "checkpoint_resume":
        with tempfile.TemporaryDirectory() as d:
            eng = DistributedEngine(db, n_shards=8, checkpoint_dir=d)
            ref_out = eng.run_query(3)
            # new engine resumes from the post-q3_join snapshot: only the
            # final host merge should execute
            eng2 = DistributedEngine(db, n_shards=8, checkpoint_dir=d)
            got = eng2.run_query(3, resume=True)
            ok, why = tables_match(got, ref_out)
            verdict["ok"] = ok and eng2.timers.get("resumed_from", 0) == 2
            verdict["resumed_from"] = eng2.timers.get("resumed_from")
            verdict["why"] = why

    elif scenario == "overflow_retry":
        small_db = generate(0.002)
        small_fb = FallbackEngine(small_db)
        eng = DistributedEngine(small_db, n_shards=4, shuffle_slack=0.2)
        got = eng.run_query(3)
        ref = small_fb.execute(QUERIES[3]())
        ok, why = tables_match(got, ref)
        verdict["ok"] = ok and eng.shuffle_slack > 0.2
        verdict["final_slack"] = eng.shuffle_slack
        verdict["why"] = why

    print(json.dumps(verdict))


if __name__ == "__main__":
    main()
