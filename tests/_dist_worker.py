"""Subprocess worker for distributed tests (needs 8 forced host devices).

Usage: python tests/_dist_worker.py <scenario>
Prints a JSON verdict on the last line.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402

import numpy as np  # noqa: E402

# resolve the package from the repo layout regardless of CWD / PYTHONPATH
_SRC = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.core.distributed import DistributedEngine  # noqa: E402
from repro.core.fallback import FallbackEngine  # noqa: E402
from repro.data.tpch import generate  # noqa: E402
from repro.data.tpch_queries import QUERIES  # noqa: E402
from repro.runtime.control import FaultInjector, FaultPlan  # noqa: E402


def canon(v):
    v = np.asarray(v)
    if v.dtype.kind == "M":
        return v.astype("datetime64[D]").astype("int64")
    if v.dtype.kind in "UO":
        return np.asarray(v, "U")
    return v


def tables_match(got, ref):
    if set(got) != set(ref):
        return False, f"columns {sorted(got)} vs {sorted(ref)}"
    for k in got:
        a, b = canon(got[k]), canon(ref[k])
        if len(a) != len(b):
            return False, f"{k}: rows {len(a)} vs {len(b)}"
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            # partial aggregates re-associate float reductions across
            # shards, so the kernel tier drifts a few ulp past 1e-6
            if not np.allclose(a.astype(float), b.astype(float),
                               rtol=2e-5, atol=1e-6):
                return False, f"{k}: values"
        elif not (a == b).all():
            return False, f"{k}: values"
    return True, ""


def mid_fragment(eng, qid):
    """A non-final fragment of the generic program — the injection target
    (fragment names are derived from the plan, so tests discover them
    instead of hard-coding)."""
    names = eng.program_names(qid)
    return names[-2] if len(names) > 1 else names[0], names


def main():
    scenario = sys.argv[1]
    db = generate(0.005)
    fb = FallbackEngine(db)
    verdict = {"scenario": scenario, "ok": False}

    if scenario == "correctness":
        eng = DistributedEngine(db, n_shards=8)
        oks = []
        for qid in (1, 3, 6, 12):
            got = eng.run_query(qid)
            ref = fb.execute(QUERIES[qid]())
            ok, why = tables_match(got, ref)
            oks.append(ok)
            if not ok:
                verdict["why"] = f"Q{qid} {why}"
        verdict["ok"] = all(oks)

    elif scenario == "node_failure_elastic":
        eng = DistributedEngine(db, n_shards=8)
        target, _ = mid_fragment(eng, 3)
        inj = FaultInjector([FaultPlan(fragment=target, node=3, times=1)])
        eng.injector = inj
        got = eng.run_query(3)
        ref = fb.execute(QUERIES[3]())
        ok, why = tables_match(got, ref)
        verdict["ok"] = (ok and eng.recoveries == 1 and eng.n_shards == 7
                         and inj.tripped == [target])
        verdict["recoveries"] = eng.recoveries
        verdict["n_shards_after"] = eng.n_shards
        verdict["why"] = why

    elif scenario == "straggler_speculation":
        eng = DistributedEngine(db, n_shards=8)
        target, _ = mid_fragment(eng, 3)
        inj = FaultInjector([FaultPlan(fragment=target, node=2, times=1,
                                       delay_s=30.0)])
        eng.injector = inj
        eng.run_query(3)  # warm (history for budget)
        got = eng.run_query(3)
        ref = fb.execute(QUERIES[3]())
        ok, why = tables_match(got, ref)
        verdict["ok"] = ok and target in eng.speculative.speculated
        verdict["speculated"] = eng.speculative.speculated
        verdict["why"] = why

    elif scenario == "checkpoint_resume":
        with tempfile.TemporaryDirectory() as d:
            eng = DistributedEngine(db, n_shards=8, checkpoint_dir=d)
            _, names = mid_fragment(eng, 3)
            ref_out = eng.run_query(3)
            # a new engine resumes from the snapshot taken after the
            # second-to-last fragment: only the final fragment re-executes
            eng2 = DistributedEngine(db, n_shards=8, checkpoint_dir=d)
            got = eng2.run_query(3, resume=True)
            ok, why = tables_match(got, ref_out)
            want = len(names) - 1
            verdict["ok"] = ok and eng2.timers.get("resumed_from") == want
            verdict["resumed_from"] = eng2.timers.get("resumed_from")
            verdict["expected_resume"] = want
            verdict["why"] = why

    elif scenario == "overflow_retry":
        # high-cardinality group-by: the partial-aggregate shuffle carries
        # thousands of rows, and slack far below the even-spread
        # requirement makes the first exchange overflow its receive
        # buckets — the coordinator must double its way up until it fits
        from repro.core.plan import AggregateRel, ReadRel, SortRel
        from repro.relational.aggregate import AggSpec
        from repro.relational.expressions import Col
        from repro.relational.sort import SortKey
        rng = np.random.default_rng(7)
        n = 20_000
        sdb = {"t": {"k": rng.integers(0, 9973, n),
                     "p": rng.integers(0, 1 << 30, n),
                     "v": rng.normal(size=n)}}
        plan = SortRel(
            AggregateRel(ReadRel("t"), ["k"],
                         [AggSpec("sum", Col("v"), "s")]),
            [SortKey("k", True)])
        eng = DistributedEngine(sdb, n_shards=4, shuffle_slack=0.01,
                                partition_keys={"t": "p"})
        got = eng.run_plan(plan)
        ref = FallbackEngine(sdb).execute(plan)
        ok, why = tables_match(got, ref)
        verdict["ok"] = ok and eng.shuffle_slack > 0.01
        verdict["final_slack"] = eng.shuffle_slack
        verdict["why"] = why

    elif scenario == "prime_rows":
        # satellite regression: row counts that are prime (and coprime to
        # the mesh) — every pad-and-mask partition boundary is uneven
        primes = {"lineitem": 9973, "orders": 2503, "customer": 251,
                  "part": 331, "supplier": 13, "partsupp": 1327}
        pdb = {t: {c: v[:primes.get(t, len(v))] for c, v in cols.items()}
               for t, cols in db.items()}
        pfb = FallbackEngine(pdb)
        eng = DistributedEngine(pdb, n_shards=8)
        oks = []
        for qid in (1, 3, 6, 12, 18):
            got = eng.run_query(qid)
            ref = pfb.execute(QUERIES[qid]())
            ok, why = tables_match(got, ref)
            oks.append(ok)
            if not ok:
                verdict["why"] = f"Q{qid} {why}"
        verdict["rows"] = {t: len(next(iter(c.values())))
                           for t, c in pdb.items()}
        verdict["ok"] = all(oks)

    elif scenario == "sweep_tpch":
        sdb = generate(0.004)
        sfb = FallbackEngine(sdb)
        eng = DistributedEngine(sdb, n_shards=2)
        failures = []
        for qid in sorted(QUERIES):
            got = eng.run_plan(QUERIES[qid]())
            ref = sfb.execute(QUERIES[qid]())
            ok, why = tables_match(got, ref)
            if not ok:
                failures.append(f"Q{qid} {why}")
        verdict["failures"] = failures
        verdict["n_queries"] = len(QUERIES)
        verdict["ok"] = not failures

    elif scenario == "sweep_clickbench":
        from repro.data import clickbench as cb
        from repro.sql import sql_to_plan
        n_rows = 2000
        cdb = cb.generate(n_rows)
        cat = cb.clickbench_catalog(n_rows)
        cfb = FallbackEngine(cdb)
        eng = DistributedEngine(cdb, n_shards=2)
        failures = []
        for qid, sql in cb.CLICKBENCH_QUERIES.items():
            got = eng.run_plan(sql_to_plan(sql, catalog=cat))
            ref = cfb.execute(sql_to_plan(sql, catalog=cat))
            ok, why = tables_match(got, ref)
            if not ok:
                failures.append(f"{qid} {why}")
        verdict["failures"] = failures
        verdict["n_queries"] = len(cb.CLICKBENCH_QUERIES)
        verdict["ok"] = not failures

    print(json.dumps(verdict))


if __name__ == "__main__":
    main()
