"""Compiled-pipeline / device-residency behaviour tests.

Three claims of the fused execution path are pinned here:

1. **Zero host roundtrips inside pipeline execution** — counted by
   instrumenting ``np.asarray`` over live jax arrays while full SQL queries
   run end to end (scalar syncs are exempt by design, see
   ``repro.core.instrument``).
2. **Compilation is cached across queries** — a second run of the same query
   shape traces nothing and hits the signature-keyed region cache.
3. **The MXU aggregation route** agrees with the numpy oracle and actually
   fires on Q1-style group-bys.
"""
import numpy as np
import pytest

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.core.fallback import FallbackEngine
from repro.data.tpch import load_into_engine
from repro.data.tpch_queries import QUERIES, SQL_QUERIES

from conftest import assert_tables_equal

# end-to-end SQL queries exercised for device residency: a group-by scan
# (Q1), a join-heavy pipeline (Q3), a filter-dominated scan (Q6), and the
# string-heavy trio — LIKE over a left join (Q13), NOT LIKE + IN + anti
# join (Q16), substring group keys (Q22) — which must run on dictionary
# code masks without any device→host column transfer
RESIDENCY_QIDS = (1, 3, 6, 13, 16, 22)


@pytest.fixture(scope="module")
def fused_engine(tpch_db):
    eng = SiriusEngine()
    load_into_engine(eng, tpch_db)
    return eng


@pytest.fixture(scope="module")
def kernel_engine(tpch_db):
    eng = SiriusEngine(use_kernels=True)
    load_into_engine(eng, tpch_db)
    return eng


# ---------------------------------------------------------------------------
# 1. device residency: no column leaves the device mid-pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", RESIDENCY_QIDS)
def test_no_host_transfers_inside_pipelines(qid, fused_engine, tpch_db):
    fused_engine.sql(SQL_QUERIES[qid])          # warm: compile regions
    with instrument.track_transfers() as counter:
        res = fused_engine.sql(SQL_QUERIES[qid])
    assert counter.in_pipeline == 0, (
        f"Q{qid}: {counter.in_pipeline} device→host column transfers "
        f"inside pipeline execution")
    # the result boundary still transfers (to_host) — the counter sees those
    with instrument.track_transfers() as counter:
        res.to_host()
    assert counter.total > 0, "sanity: the counter must detect real transfers"


@pytest.mark.parametrize("qid", RESIDENCY_QIDS)
def test_no_host_transfers_with_kernels(qid, kernel_engine):
    kernel_engine.sql(SQL_QUERIES[qid])
    with instrument.track_transfers() as counter:
        kernel_engine.sql(SQL_QUERIES[qid])
    assert counter.in_pipeline == 0


# ---------------------------------------------------------------------------
# 2. jit-cache behaviour: second run of the same query shape compiles nothing
# ---------------------------------------------------------------------------


def test_second_run_compiles_nothing(fused_engine):
    fused_engine.sql(SQL_QUERIES[3])            # populate the region cache
    stats0 = dict(fused_engine.compiler.stats)
    fused_engine.sql(SQL_QUERIES[3])
    stats1 = dict(fused_engine.compiler.stats)
    assert stats1["traces"] == stats0["traces"], "rerun must not retrace"
    # the rerun is an executable-plan replay: one AOT program dispatch, so
    # it never even consults the region cache (DESIGN.md §13)
    assert fused_engine.executor.last_plan_cache_hit
    # a cold re-lowering (plan cache dropped) must reuse the compiled
    # regions instead of retracing — the original region-cache contract
    fused_engine.executor.plan_cache.clear()
    fused_engine.sql(SQL_QUERIES[3])
    stats2 = dict(fused_engine.compiler.stats)
    assert stats2["traces"] == stats1["traces"], "regions must be reused"
    assert stats2["cache_hits"] > stats1["cache_hits"]
    assert stats2["region_calls"] > stats1["region_calls"]


def test_regions_cached_across_distinct_queries(fused_engine):
    for qid in RESIDENCY_QIDS:
        fused_engine.sql(SQL_QUERIES[qid])
    traces0 = fused_engine.compiler.stats["traces"]
    for qid in RESIDENCY_QIDS:
        fused_engine.sql(SQL_QUERIES[qid])
    assert fused_engine.compiler.stats["traces"] == traces0


# ---------------------------------------------------------------------------
# 3. MXU aggregation route
# ---------------------------------------------------------------------------


def test_agg_kernel_fires_and_matches_oracle(kernel_engine, tpch_db):
    """Q1 is the paper's group-by workhorse: the MXU route must take it."""
    hits0 = kernel_engine.backend.agg_hits
    res = kernel_engine.execute(QUERIES[1]()).to_host()
    assert kernel_engine.backend.agg_hits > hits0
    ref = FallbackEngine(tpch_db).execute(QUERIES[1]())
    assert_tables_equal(res, ref)


def test_agg_kernel_minmax_and_strings(kernel_engine, tpch_db):
    """min/max ride along the MXU route as device segment ops; dictionary
    codes make string min/max exact."""
    from repro.core.plan import AggregateRel, ReadRel
    from repro.relational.aggregate import AggSpec
    from repro.relational.expressions import Col

    plan = AggregateRel(ReadRel("orders"), ["o_orderpriority"], [
        AggSpec("min", Col("o_totalprice"), "mn"),
        AggSpec("max", Col("o_totalprice"), "mx"),
        AggSpec("avg", Col("o_totalprice"), "av"),
        AggSpec("count_star", None, "n"),
    ])
    hits0 = kernel_engine.backend.agg_hits
    res = kernel_engine.execute(plan).to_host()
    assert kernel_engine.backend.agg_hits > hits0
    ref = FallbackEngine(tpch_db).execute(plan)
    assert_tables_equal(res, ref)


def test_agg_kernel_declines_float_keys(kernel_engine):
    """Eligibility is metadata-level: float group keys fall back (None)."""
    from repro.relational.aggregate import AggSpec
    from repro.relational.expressions import Col
    from repro.relational.table import Table

    t = Table.from_pydict({"g": np.array([0.5, 0.5, 1.5]),
                           "v": np.array([1.0, 2.0, 3.0])})
    out = kernel_engine.backend.try_aggregate(
        t, ["g"], [AggSpec("sum", Col("v"), "s")])
    assert out is None


# ---------------------------------------------------------------------------
# fused probe variants vs the eager oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("how", ["inner", "semi", "anti", "mark"])
def test_fused_probe_variants_match(how, tpch_db):
    from repro.core.plan import JoinRel, ReadRel

    plan = JoinRel(ReadRel("orders"), ReadRel("customer"),
                   ["o_custkey"], ["c_custkey"], how)
    eng = SiriusEngine()
    load_into_engine(eng, tpch_db)
    res = eng.execute(plan).to_host()
    ref = FallbackEngine(tpch_db).execute(plan)
    assert_tables_equal(res, ref)
    assert eng.compiler.stats["fused_probes"] >= 1


def test_cached_region_with_regrown_build_table():
    """Regression: a cached fused region replayed with a *larger* build
    table in the same padding bucket must gather the new rows, not clamp
    to the old row count."""
    from repro.core.plan import JoinRel, ReadRel
    from repro.relational.table import Table

    eng = SiriusEngine()
    plan = JoinRel(ReadRel("probe"), ReadRel("build"), ["k"], ["k"], "inner")

    def tables(n_build):
        eng.buffers.cache_table("probe", Table.from_pydict(
            {"k": np.arange(n_build + 20, dtype=np.int64)}))
        eng.buffers.cache_table("build", Table.from_pydict(
            {"k": np.arange(n_build, dtype=np.int64),
             "v": np.arange(n_build, dtype=np.int64) * 10}))

    tables(100)                 # caches the region (bucket 128)
    eng.execute(JoinRel(ReadRel("probe"), ReadRel("build"),
                        ["k"], ["k"], "inner"))
    tables(120)                 # same bucket, 20 more rows
    out = eng.execute(plan).to_host()
    assert len(out["k"]) == 120
    assert (out["v"] == out["k"] * 10).all()   # rows 100-119 must be real


def test_duplicate_build_keys_degrade_to_eager(tpch_db):
    """Multi-match joins are outside the fused contract; results must still
    be correct via the eager segment path."""
    from repro.core.plan import JoinRel, ReadRel

    plan = JoinRel(ReadRel("customer"), ReadRel("orders"),
                   ["c_custkey"], ["o_custkey"], "inner")
    eng = SiriusEngine()
    load_into_engine(eng, tpch_db)
    res = eng.execute(plan).to_host()
    ref = FallbackEngine(tpch_db).execute(plan)
    assert_tables_equal(res, ref)
    assert eng.compiler.stats["eager_ops"] >= 1


# ---------------------------------------------------------------------------
# profile mode keeps the per-op breakdown alive
# ---------------------------------------------------------------------------


def test_profile_mode_records_op_times(tpch_db):
    eng = SiriusEngine(profile=True)
    load_into_engine(eng, tpch_db)
    res = eng.execute(QUERIES[6]()).to_host()
    ref = FallbackEngine(tpch_db).execute(QUERIES[6]())
    assert_tables_equal(res, ref)
    assert sum(eng.executor.op_times.values()) > 0
