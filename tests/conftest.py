import os

import numpy as np
import pytest

# CI matrix tier: REPRO_USE_KERNELS=1 runs the whole suite with the Pallas
# operator backend enabled, so kernel routing is exercised at suite scale.
USE_KERNELS = bool(int(os.environ.get("REPRO_USE_KERNELS", "0")))


@pytest.fixture(scope="session")
def tpch_db():
    from repro.data.tpch import generate
    return generate(scale_factor=0.01, seed=19920101)


@pytest.fixture(scope="session")
def tpch_engine(tpch_db):
    from repro.core.executor import SiriusEngine
    from repro.data.tpch import load_into_engine
    eng = SiriusEngine(use_kernels=USE_KERNELS)
    load_into_engine(eng, tpch_db)
    return eng


def canon(v):
    v = np.asarray(v)
    if v.dtype.kind == "M":
        return v.astype("datetime64[D]")
    if v.dtype.kind in "UO":
        return np.asarray(v, "U")
    return v


def assert_tables_equal(res: dict, ref: dict, rtol=1e-6, atol=1e-6):
    assert set(res) == set(ref), f"columns differ: {set(res)} vs {set(ref)}"
    if res:
        n1 = len(next(iter(res.values())))
        n2 = len(next(iter(ref.values())))
        assert n1 == n2, f"row counts differ: {n1} vs {n2}"
    for k in res:
        a, b = canon(res[k]), canon(ref[k])
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            np.testing.assert_allclose(
                a.astype(float), b.astype(float), rtol=rtol, atol=atol,
                err_msg=f"column {k}")
        else:
            assert (a == b).all(), f"column {k}: {a[:5]} vs {b[:5]}"
