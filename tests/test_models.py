"""Per-architecture smoke tests (assignment: reduced config, same family,
one forward/train step on CPU, output shapes + no NaNs) plus layer unit
tests and training-substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config, reduced
from repro.models import lm
from repro.models import layers as L
from repro.training.optimizer import (
    OptConfig, adamw_update, compress_int8, decompress_int8, init_opt_state,
)
from repro.training.train_step import make_train_step

ARCHS = sorted(all_configs())


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
    }
    if cfg.n_img_tiles:
        n = cfg.n_img_tiles * cfg.img_patches
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(b, n, cfg.d_model)).astype(np.float32))
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert 3.0 < float(loss) < 12.0, f"{arch}: loss implausible {loss}"

    step = make_train_step(cfg, OptConfig(warmup_steps=1, total_steps=10))
    state = {"params": params, "opt": init_opt_state(params)}
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params must actually change
    delta = float(jnp.abs(
        state2["params"]["embed"] - params["embed"]).max())
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = reduced(get_config(arch))
    b = 2
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, b=b)
    cache = lm.init_cache(cfg, b, 16)
    if cfg.enc_layers:
        cache["enc_out"] = lm._encoder(params, cfg, batch["frames"])
    logits, cache = lm.decode_step(params, cfg, cache, batch["tokens"][:, :1])
    assert logits.shape == (b, 1, cfg.padded_vocab)
    logits2, cache = lm.decode_step(params, cfg, cache,
                                    batch["tokens"][:, 1:2])
    assert int(cache["length"][0]) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # padded-vocab tail is masked out
    if cfg.padded_vocab != cfg.vocab:
        assert float(np.asarray(logits2)[..., cfg.vocab:].max()) < -1e20


def test_decode_matches_forward_incrementally():
    """Teacher-forced decode logits must match the parallel forward."""
    cfg = reduced(get_config("qwen3-4b"))
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    b, s = 1, 8
    toks = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab, (b, s)))
    hidden = lm.forward(params, cfg, toks)
    full = lm.logits_fn(params, cfg, hidden)
    cache = lm.init_cache(cfg, b, s + 1)
    outs = []
    for i in range(s):
        lg, cache = lm.decode_step(params, cfg, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, s, h, kvh, d = 2, 256, 8, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    out = L.blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    # naive reference
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qg, k) / (d ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgij,bjkd->bikgd", p, v).reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_cross_attention_unequal_lengths():
    rng = np.random.default_rng(1)
    b, sq, skv, h, d = 1, 64, 100, 4, 16   # skv not divisible by block
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, skv, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, skv, h, d)).astype(np.float32))
    out = L.blockwise_attention(q, k, v, causal=False, block_q=32,
                                block_kv=32)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / (d ** 0.5)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhij,bjhd->bihd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_train_scan():
    cfg = reduced(get_config("falcon-mamba-7b"))
    p = L.init_mamba(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    b, s = 1, 12
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)).astype(np.float32))
    full = L.mamba_train(p, cfg, x)
    mm = cfg.mamba
    din = mm.expand * cfg.d_model
    conv = jnp.zeros((b, mm.d_conv - 1, din), jnp.float32)
    ssm = jnp.zeros((b, din, mm.d_state), jnp.float32)
    outs = []
    for i in range(s):
        y, conv, ssm = L.mamba_decode(p, cfg, x[:, i:i + 1], conv, ssm)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_topk_and_drops_overflow():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 16, cfg.d_model)).astype(np.float32))
    y = L.moe(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_int8_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = compress_int8(g)
    deq = decompress_int8(q, scale)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.01
    # error feedback: accumulated residual keeps the mean unbiased over steps
    err = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_comp = jnp.zeros_like(g)
    for _ in range(50):
        g32 = g + err
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        err = g32 - deq
        acc_comp = acc_comp + deq
        acc_plain = acc_plain + g
    drift = float(jnp.abs(acc_comp - acc_plain).max())
    assert drift < 0.05  # bounded by one quantization step


def test_adamw_converges_on_quadratic():
    w = jnp.asarray([5.0, -3.0])
    state = init_opt_state({"w": w})
    cfg = OptConfig(lr=0.3, warmup_steps=1, total_steps=200, weight_decay=0.0)
    params = {"w": w}
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_param_count_matches_init():
    """Config param_count() must agree with actual initialized tree size."""
    for arch in ("qwen3-4b", "falcon-mamba-7b", "phi3.5-moe-42b-a6.6b"):
        cfg = reduced(get_config(arch))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        n_init = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        n_cfg = cfg.param_count()
        # padded vocab + whisper pos tables aren't in the analytic count
        pad = (cfg.padded_vocab - cfg.vocab) * cfg.d_model * (
            1 if cfg.tie_embeddings else 2)
        assert abs(n_init - pad - n_cfg) / n_cfg < 0.2, (arch, n_init, n_cfg)
