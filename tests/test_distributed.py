"""Distributed execution: correctness, fault tolerance, stragglers, elastic.

Each scenario runs in a subprocess with 8 forced host devices (the main
pytest process keeps the default single device so smoke tests and benches
see 1 device, per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_dist_worker.py")


def run_scenario(name: str, timeout=900) -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(WORKER)), "src")
    env = dict(os.environ)   # propagate the parent env (kernel tier, etc.)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, WORKER, name],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.dirname(os.path.dirname(WORKER)) or ".",
    )
    assert proc.returncode == 0, f"worker failed:\n{proc.stderr[-3000:]}"
    line = proc.stdout.strip().splitlines()[-1]
    return json.loads(line)


@pytest.mark.slow
def test_distributed_correctness():
    v = run_scenario("correctness")
    assert v["ok"], v


@pytest.mark.slow
def test_node_failure_triggers_elastic_recovery():
    v = run_scenario("node_failure_elastic")
    assert v["ok"], v
    assert v["recoveries"] == 1
    assert v["n_shards_after"] == 7


@pytest.mark.slow
def test_straggler_speculative_reexecution():
    v = run_scenario("straggler_speculation")
    assert v["ok"], v
    assert v["speculated"], v


@pytest.mark.slow
def test_checkpoint_restart_resumes_after_last_fragment():
    v = run_scenario("checkpoint_resume")
    assert v["ok"], v
    assert v["resumed_from"] == v["expected_resume"]


@pytest.mark.slow
def test_shuffle_overflow_retry_end_to_end():
    """Real undersized exchange buckets (slack 0.2) overflow and converge."""
    v = run_scenario("overflow_retry")
    assert v["ok"], v
    assert v["final_slack"] > 0.01


@pytest.mark.slow
def test_prime_sized_tables_partition_exactly():
    """Row counts prime (coprime to the mesh): every pad-and-mask boundary
    is uneven, results must still be row-exact."""
    v = run_scenario("prime_rows")
    assert v["ok"], v


@pytest.mark.slow
def test_tpch_sweep_distributed_row_exact():
    """All 22 TPC-H queries through the generic run_plan path."""
    v = run_scenario("sweep_tpch")
    assert v["n_queries"] == 22
    assert v["ok"], v["failures"]


@pytest.mark.slow
def test_clickbench_sweep_distributed_row_exact():
    """All 15 ClickBench queries through the generic run_plan path."""
    v = run_scenario("sweep_clickbench")
    assert v["n_queries"] == 15
    assert v["ok"], v["failures"]


def test_shuffle_overflow_retries_with_bigger_buckets():
    """Coordinator doubles bucket slack and retries the fragment in place
    (in-process: a stub fragment raises ExchangeOverflow until slack grows)."""
    from repro.core.distributed import DistributedEngine, ExchangeOverflow
    from repro.data.tpch import generate

    db = generate(0.002)
    eng = DistributedEngine(db, n_shards=1, shuffle_slack=0.25)
    calls = {"n": 0}

    def fake_program():
        def frag(registry):
            calls["n"] += 1
            if eng.shuffle_slack < 1.0:
                raise ExchangeOverflow
            return {"ok": np.ones(1)}
        return [("fake_frag", frag)]

    eng._program_q6 = fake_program
    out = eng.run_query(6)
    assert out["ok"][0] == 1
    assert eng.shuffle_slack >= 1.0            # 0.25 → 0.5 → 1.0
    assert calls["n"] == 3


# ---------------------------------------------------------------------------
# in-process unit tests (single device, logic only)
# ---------------------------------------------------------------------------


def test_np_partition_hash_matches_device_hash():
    import jax.numpy as jnp
    from repro.core.distributed import np_partition_hash
    from repro.exchange.service import partition_hash
    keys = np.array([0, 1, 2, 7, 123456789, 2**40, -5, 999983], np.int64)
    for n in (2, 3, 8, 16):
        a = np_partition_hash(keys, n)
        b = np.asarray(partition_hash(jnp.asarray(keys), n))
        assert (a == b).all(), n


def test_key_to_int64_is_value_deterministic():
    from repro.core.distributed import key_to_int64
    # strings hash by value, independent of array order / dictionary codes
    a = key_to_int64(np.array(["x", "abc", "x", ""], "U"))
    b = key_to_int64(np.array(["abc", "", "x"], "U"))
    assert a[1] == b[0] and a[0] == b[2] and a[3] == b[1]
    assert a[0] == a[2]
    # float -0.0 and 0.0 must land on the same partition
    f = key_to_int64(np.array([0.0, -0.0]))
    assert f[0] == f[1]
    # dates become day numbers
    d = key_to_int64(np.array(["1970-01-03"], "datetime64[D]"))
    assert d[0] == 2


def test_exchange_placement_cuts_stable_fragments():
    from repro.data.tpch import generate
    from repro.core.distributed import DistributedEngine

    db = generate(0.002)
    eng = DistributedEngine(db, n_shards=1)
    names = eng.program_names(3)
    assert len(names) >= 2                      # at least one exchange + root
    assert names[-1].endswith("final")
    assert names == eng.program_names(3)        # deterministic re-cut


def test_registry_checkpoint_roundtrips_decoded_columns(tmp_path):
    """Registry rows are decoded host columns — strings and dates must
    survive a snapshot without pickling."""
    from repro.runtime.checkpoint import RegistryCheckpointer
    cp = RegistryCheckpointer(str(tmp_path))
    reg = {"t": {"rows": {
        "s": np.array(["a", "bb", ""], "U"),
        "d": np.array(["1995-03-15"] * 3, "datetime64[D]"),
        "x": np.arange(3.0)}, "partition_key": "s"}}
    cp.save("frag1", reg)
    _, loaded = cp.load_latest(["frag1"])
    assert (loaded["t"]["rows"]["s"] == reg["t"]["rows"]["s"]).all()
    assert (loaded["t"]["rows"]["d"] == reg["t"]["rows"]["d"]).all()


def test_heartbeat_failure_detector():
    from repro.runtime.control import HeartbeatMonitor
    hb = HeartbeatMonitor(4, timeout_s=60)
    assert hb.live_nodes() == [0, 1, 2, 3]
    hb.kill(2)
    assert hb.live_nodes() == [0, 1, 3]
    hb.revive_all()
    assert hb.live_nodes() == [0, 1, 2, 3]


def test_speculative_runner_prefers_backup_for_stragglers():
    from repro.runtime.control import SpeculativeRunner
    sr = SpeculativeRunner(min_budget_s=0.1)
    out, who = sr.run("frag", lambda: 42, injected_delay_s=2.0)
    assert out == 42
    assert who == "backup"
    assert sr.speculated == ["frag"]
    out, who = sr.run("frag", lambda: 43)
    assert (out, who) == (43, "primary")


def test_registry_checkpoint_roundtrip(tmp_path):
    from repro.runtime.checkpoint import RegistryCheckpointer
    cp = RegistryCheckpointer(str(tmp_path))
    reg = {"t": {"rows": {"a": np.arange(5), "b": np.ones(5)},
                 "partition_key": "a"}}
    cp.save("frag1", reg)
    frag, loaded = cp.load_latest(["frag1", "frag2"])
    assert frag == "frag1"
    assert (loaded["t"]["rows"]["a"] == np.arange(5)).all()
    assert loaded["t"]["partition_key"] == "a"


def test_local_sort_agg_static():
    import jax.numpy as jnp
    from repro.core.static_ops import local_sort_agg
    from repro.exchange.service import Frame
    key = jnp.asarray(np.array([5, 3, 5, 3, 9, 1, 5, 0], np.int64))
    val = jnp.asarray(np.array([1.0, 2, 3, 4, 5, 6, 7, 0]))
    valid = jnp.asarray(np.array([1, 1, 1, 1, 1, 1, 1, 0], bool))
    fr = Frame({"v": val}, valid)
    out, _ = local_sort_agg(fr, key, sums={"s": val})
    k = np.asarray(out.columns["key"])[np.asarray(out.valid)]
    s = np.asarray(out.columns["s"])[np.asarray(out.valid)]
    got = dict(zip(k.tolist(), s.tolist()))
    assert got == {1: 6.0, 3: 6.0, 5: 11.0, 9: 5.0}


def test_predicate_transfer_q3_matches_oracle():
    """Beyond-paper: Bloom predicate transfer must not change results."""
    import numpy as _np
    from repro.core.distributed import DistributedEngine
    from repro.core.fallback import FallbackEngine
    from repro.data.tpch import generate
    from repro.data.tpch_queries import QUERIES

    db = generate(0.004)
    eng = DistributedEngine(db, n_shards=1, predicate_transfer=True)
    got = eng.run_query(3)
    ref = FallbackEngine(db).execute(QUERIES[3]())
    assert (got["l_orderkey"] == ref["l_orderkey"]).all()
    _np.testing.assert_allclose(got["revenue"], ref["revenue"], rtol=1e-6)


def test_bloom_filter_properties():
    import jax.numpy as jnp
    import numpy as _np
    from repro.exchange.bloom import bloom_build, bloom_maybe_contains
    rng = _np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(10**9, 5000, replace=False))
    valid = jnp.ones((5000,), bool)
    bits = bloom_build(keys, valid, 1 << 16)
    # no false negatives
    assert bool(bloom_maybe_contains(bits, keys).all())
    # low false-positive rate on absent keys
    absent = jnp.asarray(rng.integers(2 * 10**9, 3 * 10**9, 5000))
    fp = float(bloom_maybe_contains(bits, absent).mean())
    assert fp < 0.05, fp
