"""The roofline's HLO parsers must be exact on known programs.

These validate the two analyses the §Roofline deliverable depends on:
loop-corrected matmul FLOPs (XLA's cost_analysis counts while bodies once)
and collective byte accounting with loop multipliers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes, dot_flops


def _compile(f, *shapes):
    return jax.jit(f).lower(*[jax.ShapeDtypeStruct(s, jnp.float32)
                              for s in shapes]).compile()


def test_dot_flops_single_matmul():
    c = _compile(lambda a, b: a @ b, (64, 128), (128, 32))
    assert dot_flops(c.as_text()) == 2 * 64 * 128 * 32


def test_dot_flops_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    c = _compile(f, (128, 128), (128, 128))
    assert dot_flops(c.as_text()) == 7 * 2 * 128 ** 3


def test_dot_flops_nested_scans_multiply():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    c = _compile(g, (128, 128), (128, 128))
    assert dot_flops(c.as_text()) == 15 * 2 * 128 ** 3


def test_dot_flops_batched_einsum():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c = _compile(f, (4, 32, 64), (4, 64, 16))
    assert dot_flops(c.as_text()) == 2 * 4 * 32 * 64 * 16


@pytest.mark.slow
def test_collective_bytes_in_loop(tmp_path):
    """Loop-varying psum must be multiplied by the trip count."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, %r)
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.launch.hlo_analysis import collective_bytes
mesh = Mesh(np.array(jax.devices()), ('data',))
def f(x):
    def body(c, i):
        # loop-varying: cannot be hoisted
        return c + jax.lax.psum((x * i).sum(), 'data'), None
    out, _ = jax.lax.scan(body, 0.0, jnp.arange(6.0))
    return out
from jax.experimental.shard_map import shard_map
g = jax.jit(shard_map(f, mesh=mesh, in_specs=P('data'), out_specs=P(),
                      check_rep=False))
c = g.lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
cb = collective_bytes(c.as_text())
# psum of f32 scalar: 4 bytes x2 (AR) x6 trips = 48
assert cb.get('all-reduce', 0) == 48.0, cb
print('OK')
""" % (os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=300)
    assert "OK" in proc.stdout, proc.stderr[-2000:]
