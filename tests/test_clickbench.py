"""ClickBench workload: engine-vs-oracle equality + device residency.

The second benchmark of the paper's headline claim.  Every query in the set
must produce identical results on the jnp pipeline engine and the numpy
oracle, and the string-predicate queries — the reason this workload exists
in the repro — must execute with **zero** device→host column transfers
inside pipeline execution (the string subsystem's host passes touch only
the small host-side dictionaries, never the device codes).
"""
import numpy as np
import pytest

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.data import clickbench as cb
from repro.sql import run_sql

from conftest import USE_KERNELS, assert_tables_equal

N_ROWS = 20_000


@pytest.fixture(scope="module")
def cb_db():
    return cb.generate(N_ROWS)


@pytest.fixture(scope="module")
def cb_catalog():
    return cb.clickbench_catalog(N_ROWS)


@pytest.fixture(scope="module")
def cb_engine(cb_db):
    eng = SiriusEngine(use_kernels=USE_KERNELS)
    cb.load_into_engine(eng, cb_db)
    return eng


@pytest.mark.parametrize("qid", list(cb.CLICKBENCH_QUERIES))
def test_engine_matches_oracle(qid, cb_engine, cb_db, cb_catalog):
    sql = cb.CLICKBENCH_QUERIES[qid]
    ref = run_sql(sql, cb_db, catalog=cb_catalog)
    got = cb_engine.sql(sql, catalog=cb_catalog).to_host()
    assert_tables_equal(got, ref)


@pytest.mark.parametrize("qid", cb.CLICKBENCH_STRING_QIDS)
def test_string_queries_stay_device_resident(qid, cb_engine, cb_catalog):
    sql = cb.CLICKBENCH_QUERIES[qid]
    cb_engine.sql(sql, catalog=cb_catalog)        # warm: compile regions
    with instrument.track_transfers() as counter:
        cb_engine.sql(sql, catalog=cb_catalog)
    assert counter.in_pipeline == 0, (
        f"{qid}: {counter.in_pipeline} device→host column transfers inside "
        "pipeline execution")


def test_workload_shape_is_dictionary_friendly(cb_db):
    """The property the subsystem exploits: |dictionary| << |rows|."""
    hits = cb_db["hits"]
    for col in ("url", "title", "searchphrase", "mobilephonemodel"):
        n_distinct = len(np.unique(hits[col]))
        assert n_distinct < len(hits[col]) / 3, col


def test_string_filters_return_rows(cb_engine, cb_catalog):
    """The generated sample must exercise the probes (non-trivial hits)."""
    for qid in ("q20", "q21", "q22", "q43x"):
        out = cb_engine.sql(cb.CLICKBENCH_QUERIES[qid], catalog=cb_catalog)
        host = out.to_host()
        first = next(iter(host.values()))
        assert len(first) > 0, qid
        if qid in ("q20", "q43x"):
            assert int(host["c"][0]) > 0, qid


def test_generator_is_deterministic():
    a = cb.generate(1000)["hits"]
    b = cb.generate(1000)["hits"]
    for k in a:
        assert (a[k] == b[k]).all(), k


def test_catalog_matches_generated_schema(cb_db, cb_catalog):
    hits = cb_db["hits"]
    assert set(hits) == set(cb_catalog.columns("hits"))
    for col, kind in cb.CLICKBENCH_SCHEMA["hits"].items():
        npkind = hits[col].dtype.kind
        if kind == "string":
            assert npkind in "UO", col
        elif kind == "date":
            assert npkind == "M", col
        else:
            assert npkind in "iuifb", col
