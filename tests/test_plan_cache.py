"""Executable-plan cache: the warm-path contract (DESIGN.md §13).

The headline guarantee: the *second* run of every TPC-H query is a plan-cache
replay — zero new compiler traces, zero host scalar syncs, at most one sync
barrier (the final result materialization) — and row-exact against the cold
run.  Plus the safety rails: register() and direct table re-caches invalidate,
corrupted recordings fall back to a cold re-run, and the SQL / wire front
doors key into the same cache.
"""
import numpy as np
import pytest
from conftest import USE_KERNELS, assert_tables_equal

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.core.plan_cache import ExecutablePlan, PlanCache, plan_signature
from repro.data.tpch import load_into_engine
from repro.data.tpch_queries import QUERIES


@pytest.fixture(scope="module")
def engine(tpch_db):
    eng = SiriusEngine(use_kernels=USE_KERNELS)
    load_into_engine(eng, tpch_db)
    return eng


def _host(table):
    return {k: np.asarray(v) for k, v in table.to_host().items()}


# ---------------------------------------------------------------------------
# the warm-path contract, all 22 queries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_warm_run_is_trace_free_and_sync_free(qid, engine):
    cold = _host(engine.execute(QUERIES[qid]()))

    traces0 = engine.compiler.stats["traces"]
    syncs0 = instrument.scalar_syncs.value
    barriers0 = instrument.sync_barriers.value
    warm = engine.execute(QUERIES[qid]())          # fresh plan object

    assert engine.executor.last_plan_cache_hit, f"q{qid}: expected cache hit"
    assert engine.compiler.stats["traces"] == traces0, \
        f"q{qid}: warm run traced new regions"
    assert instrument.scalar_syncs.value == syncs0, \
        f"q{qid}: warm run pulled a host scalar"
    assert instrument.sync_barriers.value - barriers0 <= 1, \
        f"q{qid}: warm run issued more than the final-result barrier"
    assert engine.executor.last_compile_seconds == 0.0
    assert_tables_equal(_host(warm), cold)


def test_cold_run_attributes_compile_time(tpch_db):
    eng = SiriusEngine(use_kernels=False)
    load_into_engine(eng, tpch_db)
    eng.execute(QUERIES[3]())                      # q3 traces fused regions
    assert eng.executor.last_compile_seconds > 0.0, \
        "first-ever run must attribute its trace time"
    assert not eng.executor.last_plan_cache_hit
    eng.execute(QUERIES[3]())
    assert eng.executor.last_compile_seconds == 0.0


# ---------------------------------------------------------------------------
# signatures: structural, not identity or text
# ---------------------------------------------------------------------------


def test_signature_stable_across_fresh_plan_objects():
    assert plan_signature(QUERIES[3]()) == plan_signature(QUERIES[3]())


def test_signature_distinguishes_queries():
    sigs = {plan_signature(QUERIES[qid]()) for qid in sorted(QUERIES)}
    assert len(sigs) == len(QUERIES)


# ---------------------------------------------------------------------------
# invalidation: register(), direct re-caches, corrupted recordings
# ---------------------------------------------------------------------------


def test_register_clears_cache(tpch_db):
    eng = SiriusEngine(use_kernels=False)
    load_into_engine(eng, tpch_db)
    eng.execute(QUERIES[6]())
    eng.execute(QUERIES[6]())
    assert eng.executor.last_plan_cache_hit
    assert len(eng.executor.plan_cache) > 0
    from repro.relational.table import Table
    eng.register("lineitem", Table.from_pydict(tpch_db["lineitem"]),
                 tpch_db["lineitem"])
    assert len(eng.executor.plan_cache) == 0
    eng.execute(QUERIES[6]())
    assert not eng.executor.last_plan_cache_hit


def test_direct_recache_bumps_epoch_and_invalidates(tpch_db):
    eng = SiriusEngine(use_kernels=False)
    load_into_engine(eng, tpch_db)
    cold = _host(eng.execute(QUERIES[6]()))
    eng.execute(QUERIES[6]())
    assert eng.executor.last_plan_cache_hit
    # re-cache a scanned table *without* going through register(): the
    # epoch bump must invalidate the entry even though the signature matches
    eng.buffers.cache_table("lineitem", eng.buffers.get("lineitem"))
    inval0 = eng.executor.plan_cache.stats["invalidations"]
    again = _host(eng.execute(QUERIES[6]()))
    assert not eng.executor.last_plan_cache_hit
    assert eng.executor.plan_cache.stats["invalidations"] == inval0 + 1
    assert_tables_equal(again, cold)
    eng.execute(QUERIES[6]())                      # fresh entry is usable
    assert eng.executor.last_plan_cache_hit


def test_replay_mismatch_falls_back_to_cold_run(tpch_db):
    eng = SiriusEngine(use_kernels=False)
    load_into_engine(eng, tpch_db)
    cold = _host(eng.execute(QUERIES[6]()))
    sig = eng.executor.last_plan_signature
    entry = eng.executor.plan_cache._entries[sig]
    # the AOT replay program bakes the recording in as trace-time constants
    # (its flags compare those against live data, not against this list), so
    # value-poisoning exercises the closure-loop rail — force that path
    entry.compiled = None
    corrupted = False
    for rp in entry.pipelines:
        if rp.values:
            rp.values[0] = rp.values[0] + 1        # poison the recording
            corrupted = True
            break
    assert corrupted, "q6 should record at least one scalar pull"
    mism0 = eng.executor.plan_cache.stats["replay_mismatches"]
    out = _host(eng.execute(QUERIES[6]()))
    assert eng.executor.plan_cache.stats["replay_mismatches"] == mism0 + 1
    assert not eng.executor.last_plan_cache_hit    # served by the cold re-run
    assert_tables_equal(out, cold)
    eng.execute(QUERIES[6]())                      # re-recorded entry works
    assert eng.executor.last_plan_cache_hit


# ---------------------------------------------------------------------------
# front doors: engine.sql text keys, engine.accelerate wire keys
# ---------------------------------------------------------------------------

_SQL = ("SELECT l_returnflag, sum(l_quantity) AS sum_qty FROM lineitem "
        "GROUP BY l_returnflag ORDER BY l_returnflag")


def test_sql_text_cache_skips_parser(engine):
    cold = _host(engine.sql(_SQL))
    traces0 = engine.compiler.stats["traces"]
    # warm: different whitespace, same normalized text → same entry
    warm = _host(engine.sql("  " + _SQL.replace(" FROM", "\n  FROM") + " ;"))
    assert engine.executor.last_plan_cache_hit
    assert engine.compiler.stats["traces"] == traces0
    assert_tables_equal(warm, cold)


def test_accelerate_wire_cache(engine):
    from repro.substrait import emit
    wire = emit(QUERIES[6]())
    cold = _host(engine.accelerate(wire))
    assert not engine.last_accelerate_report.get("plan_cache_hit", False)
    warm = _host(engine.accelerate(emit(QUERIES[6]())))
    assert engine.last_accelerate_report.get("plan_cache_hit", False)
    assert_tables_equal(warm, cold)


# ---------------------------------------------------------------------------
# PlanCache unit behavior
# ---------------------------------------------------------------------------


def test_plan_cache_lru_eviction():
    cache = PlanCache(max_entries=2)
    for sig in ("a", "b", "c"):
        cache.store(sig, ExecutablePlan([], None))
    assert len(cache) == 2
    assert cache.stats["evictions"] == 1
    assert cache.lookup("a") is None               # evicted, counts a miss
    assert cache.lookup("c") is not None
    assert cache.stats == dict(cache.stats, hits=1, misses=1, inserts=3)


def test_plan_cache_invalidate_and_clear():
    cache = PlanCache()
    cache.store("x", ExecutablePlan([], None))
    cache.invalidate("x", mismatch=True)
    assert cache.stats["invalidations"] == 1
    assert cache.stats["replay_mismatches"] == 1
    cache.invalidate("x")                          # absent: no double count
    assert cache.stats["invalidations"] == 1
    cache.store("y", ExecutablePlan([], None))
    cache.store("z", ExecutablePlan([], None))
    cache.clear()
    assert len(cache) == 0
    assert cache.stats["invalidations"] == 3
